"""Render EXPERIMENTS.md tables from the dry-run JSONL artifacts.

    PYTHONPATH=src python -m benchmarks.render_experiments > /tmp/tables.md
"""
from __future__ import annotations

import json
import pathlib
import sys
from typing import Dict, List


def load(path: str) -> List[Dict]:
    p = pathlib.Path(path)
    if not p.exists():
        return []
    rows = []
    seen = set()
    for line in p.read_text().splitlines():
        if not line.strip():
            continue
        r = json.loads(line)
        key = (r["arch"], r["shape"], r["mesh"])
        if key in seen:
            continue
        seen.add(key)
        rows.append(r)
    return rows


def fmt_bytes(b):
    if b is None:
        return "-"
    if b >= 1e12:
        return f"{b/1e12:.1f}T"
    if b >= 1e9:
        return f"{b/1e9:.1f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b:.0f}"


def dryrun_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | mode | compile s | XLA temp/dev | modeled resident/dev | fits 16G |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['mode']} "
            f"| {r['compile_seconds']} | {fmt_bytes(r['memory']['temp_bytes'])} "
            f"| {fmt_bytes(r.get('modeled_resident_bytes_per_device'))} "
            f"| {'yes' if r.get('modeled_fits_16g') else 'NO'} |")
    return "\n".join(out)


def roofline_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | t_comp s | t_mem s | t_coll s | bottleneck | MODEL/HLO flops | HLO flops | coll bytes |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['bottleneck']}** "
            f"| {r['useful_flops_ratio']:.2f} | {r['hlo_flops']:.3g} "
            f"| {fmt_bytes(r['collective_bytes'])} |")
    return "\n".join(out)


def main():
    single = load("experiments/dryrun_single.jsonl")
    multi = load("experiments/dryrun_multi.jsonl")
    print(f"## Generated tables ({len(single)} single-pod, "
          f"{len(multi)} multi-pod rows)\n")
    print("### Dry-run (single pod 16x16 = 256 chips)\n")
    print(dryrun_table(single))
    if multi:
        print("\n### Dry-run (multi-pod 2x16x16 = 512 chips)\n")
        print(dryrun_table(multi))
    print("\n### Roofline (single pod)\n")
    print(roofline_table(single))
    if multi:
        print("\n### Roofline (multi-pod)\n")
        print(roofline_table(multi))


if __name__ == "__main__":
    main()
