"""Benchmark the paged continuous-batching serving engine.

Two claims, measured against the dense-cache reference path
(``launch/serve.py --engine dense``):

1. **Correctness for free** — the paged engine's greedy generations are
   token-identical to the dense oracle on a mixed-length request mix
   (checked exactly; any divergence fails the benchmark).
2. **Memory** — dense caching reserves ``lanes * max_context`` KV per
   layer regardless of what requests actually use, so under a fixed KV
   byte cap it *under-batches*: fewer concurrent lanes fit than the paged
   pool supports at equal bytes.  The accounting is deterministic (exact
   byte arithmetic, not wall-clock), so the comparison is stable in CI.

Also runs the SLO-axis serving search (smoke scale) and lints the emitted
v3 plan — a plan that fails the verifier fails the benchmark.

Results land in ``BENCH_serve.json`` at the repo root.

Usage:  PYTHONPATH=src python benchmarks/bench_serve.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.models.common import ModelConfig          # noqa: E402

GB = 1024 ** 3


def tiny_cfg(n_layers: int) -> ModelConfig:
    return ModelConfig(name=f"serve-bench-{n_layers}L", arch_type="dense",
                       n_layers=n_layers, d_model=64, n_heads=4,
                       n_kv_heads=2, d_ff=128, vocab_size=256)


def kv_bytes_per_token(cfg) -> int:
    """K+V bytes cached per token across all layers (cache dtype)."""
    import jax.numpy as jnp
    itemsize = jnp.dtype(cfg.dtype).itemsize
    return cfg.n_layers * 2 * cfg.n_kv_heads * cfg.dh * itemsize


def request_mix(cfg, n: int, max_context: int, seed: int = 0):
    """Mixed-length mix: short chat-style turns plus a few long prompts."""
    from repro.launch.serve import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        if i % 4 == 3:                      # every 4th request is long
            plen = int(rng.integers(max_context // 2, max_context - 8))
        else:
            plen = int(rng.integers(2, max_context // 8))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).tolist()
        reqs.append(Request(i, prompt, int(rng.integers(4, 9))))
    return reqs


def clone(reqs):
    from repro.launch.serve import Request
    return [Request(r.rid, list(r.prompt), r.max_new) for r in reqs]


def run_paged(cfg, reqs, *, page_size, n_pages, slots, max_context):
    from repro.launch.serve import serve_paged
    from repro.serving import EngineConfig
    ecfg = EngineConfig(page_size=page_size, n_pages=n_pages,
                        decode_slots=slots, max_context=max_context,
                        prefill_batch=min(4, slots),
                        prefill_chunk=min(32, max_context))
    t0 = time.perf_counter()
    metrics = serve_paged(cfg, reqs, ecfg, seed=0, verbose=False)
    return metrics, time.perf_counter() - t0


def run_dense(cfg, reqs, *, batch, max_context):
    from repro.launch.serve import serve
    t0 = time.perf_counter()
    serve(cfg, reqs, batch, max_context, seed=0, verbose=False)
    return time.perf_counter() - t0


def lane_accounting(cfg, reqs, *, max_context, page_size, paged_slots):
    """Deterministic under-batching comparison at a fixed KV byte cap.

    The cap is what the paged engine actually needs to hold ``paged_slots``
    concurrent lanes of this mix (pool pages sized from the mix's peak
    per-lane usage).  Dense caching must reserve full ``max_context`` per
    lane, so the same cap admits fewer lanes.
    """
    per_tok = kv_bytes_per_token(cfg)
    # paged pool: enough pages for the peak concurrent footprint — the
    # paged_slots longest requests growing to prompt + max_new tokens
    need = sorted((len(r.prompt) + r.max_new for r in reqs), reverse=True)
    peak_tokens = sum(need[:paged_slots])
    pool_pages = -(-peak_tokens // page_size) + paged_slots  # +1 page slack
    cap_bytes = pool_pages * page_size * per_tok
    dense_bytes_per_lane = max_context * per_tok
    dense_lanes = int(cap_bytes // dense_bytes_per_lane)
    return {
        "kv_cap_bytes": int(cap_bytes),
        "kv_bytes_per_token": int(per_tok),
        "pool_pages": int(pool_pages),
        "paged_lanes": int(paged_slots),
        "dense_bytes_per_lane": int(dense_bytes_per_lane),
        "dense_lanes_at_cap": dense_lanes,
    }


def slo_plan_lint(smoke: bool):
    """SLO-axis search -> v3 plan -> verifier.  Lint errors fail the run."""
    from repro.analysis import verify_plan_json
    from repro.core import galvatron_variant, paper_8gpu
    from repro.core.layerspec import dense_layer
    from repro.serving import ServingPlanSearch

    n = 8 if smoke else 16
    specs = [dense_layer(f"l{i}", 512, 1024, 16, 16, 4096,
                         store_attn_matrix=True) for i in range(n)]
    ocfg = galvatron_variant("bmw")
    ocfg.batch_grid = [8, 16]
    ocfg.n_bins = 64
    ocfg.micro_candidates = 2
    search = ServingPlanSearch(specs, paper_8gpu(), config=ocfg)
    points, _ = search.sweep_slos([20.0, 60.0], max_context=512)
    feasible = [p for p in points if p.feasible]
    rows, errors = [], []
    for pt in feasible:
        diags = verify_plan_json(pt.plan.to_json())
        errs = [d.format() for d in diags if d.severity == "error"]
        errors += errs
        sv = pt.plan.serving
        rows.append({"slo_ms": pt.slo_ms,
                     "budget_gb": round(pt.budget_bytes / GB, 2),
                     "decode_batch": sv.decode_batch,
                     "page_size": sv.page_size,
                     "est_tok_ms": round(sv.est_tok_ms, 3),
                     "est_tok_per_s": round(sv.est_tok_per_s, 1),
                     "lint_errors": errs})
    return rows, len(feasible) > 0 and not errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small config for CI")
    ap.add_argument("--out", default=str(REPO / "BENCH_serve.json"))
    args = ap.parse_args(argv)

    if args.smoke:
        cfg, n_req, max_context, page_size, slots = \
            tiny_cfg(2), 10, 64, 8, 3
    else:
        cfg, n_req, max_context, page_size, slots = \
            tiny_cfg(4), 24, 128, 8, 6

    reqs = request_mix(cfg, n_req, max_context)
    acct = lane_accounting(cfg, reqs, max_context=max_context,
                           page_size=page_size, paged_slots=slots)

    # ---- paged engine at the accounted pool size -----------------------
    paged_reqs = clone(reqs)
    metrics, t_paged = run_paged(
        cfg, paged_reqs, page_size=page_size,
        n_pages=acct["pool_pages"], slots=slots, max_context=max_context)
    summ = metrics.summary()

    # ---- dense oracle (full batch, uncapped — the correctness ref) -----
    dense_reqs = clone(reqs)
    t_dense = run_dense(cfg, dense_reqs, batch=slots,
                        max_context=max_context)
    identical = all(p.generated == d.generated
                    for p, d in zip(paged_reqs, dense_reqs))

    # ---- SLO search plan lint ------------------------------------------
    slo_rows, slo_ok = slo_plan_lint(args.smoke)

    under_batched = acct["dense_lanes_at_cap"] < acct["paged_lanes"]
    occupancy_ok = 0.0 < summ["page_occupancy_max"] <= 1.0
    ok = bool(identical and under_batched and occupancy_ok and slo_ok)

    out = {
        "benchmark": "paged continuous-batching serve vs dense-cache "
                     "reference (token identity + KV under-batching at a "
                     "fixed byte cap) + SLO-axis plan lint",
        "smoke": args.smoke,
        "model": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                  "kv_heads": cfg.n_kv_heads},
        "mix": {"requests": n_req, "max_context": max_context,
                "prompt_tokens": sum(len(r.prompt) for r in reqs),
                "new_tokens": sum(r.max_new for r in reqs)},
        "paged": {"tok_per_s": round(summ["tok_per_s"], 2),
                  "wall_s": round(t_paged, 3),
                  "decode_steps": summ["decode_steps"],
                  "prefill_chunks": summ["prefill_chunks"],
                  "ttft_ms_p50": round(summ["ttft_ms_p50"], 3),
                  "ttft_ms_p99": round(summ["ttft_ms_p99"], 3),
                  "page_occupancy_mean": round(
                      summ["page_occupancy_mean"], 4),
                  "page_occupancy_max": round(summ["page_occupancy_max"], 4)},
        "dense": {"wall_s": round(t_dense, 3),
                  "tok_per_s": round(
                      sum(r.max_new for r in reqs) / t_dense, 2)},
        "kv_accounting": acct,
        "tokens_identical": bool(identical),
        "dense_under_batches_at_cap": bool(under_batched),
        "slo_plans": slo_rows,
        "ok": ok,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"paged: {summ['tok_per_s']:.1f} tok/s "
          f"({summ['decode_steps']} decode steps, "
          f"ttft p50 {summ['ttft_ms_p50']:.1f} ms, "
          f"peak occupancy {summ['page_occupancy_max']:.2f})  "
          f"dense: {out['dense']['tok_per_s']:.1f} tok/s")
    print(f"KV cap {acct['kv_cap_bytes'] / 1e6:.2f} MB: paged serves "
          f"{acct['paged_lanes']} lanes, dense fits "
          f"{acct['dense_lanes_at_cap']} "
          f"(under-batched={under_batched}); tokens identical={identical}; "
          f"SLO plans lint clean={slo_ok}")
    print(f"wrote {args.out}")
    if not ok:
        print("ERROR: serving benchmark invariants violated", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
