"""Benchmark the pipeline-schedule subsystem's executed schedules.

Runs one tiny homogeneous LM on a host-device ``(pipe, data)`` mesh and
times a full loss+grad step under each compiled schedule — ``gpipe``,
``1f1b`` (remat tick body), ``1f1b-interleaved`` (V=2) and the
zero-bubble ``zb-h1`` (three-phase F/B/W table; the runtime executes its
forward projection) — and checks that all of them agree with the
non-pipelined executor-path reference loss (they run the same math; only
the tick program and memory profile differ).  On a CPU host the
wall-clock ranking mostly reflects the remat recompute and the V×
hand-off count rather than real bubble savings (no parallel stage
execution on fake devices); the analytic bubble model the search uses is
recorded alongside (``bubble_fraction``).

Results land in ``BENCH_pipeline.json`` at the repo root.

Usage:  PYTHONPATH=src python benchmarks/bench_pipeline.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller model / fewer timed steps (CI)")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--micro", type=int, default=8)
    ap.add_argument("--out", default=str(REPO / "BENCH_pipeline.json"))
    args = ap.parse_args(argv)

    # fake pipeline devices — must be set before jax initializes
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.stages}")
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.cost_model import bubble_fraction
    from repro.launch.mesh import make_pipeline_mesh
    from repro.models import init_lm, lm_loss
    from repro.runtime import (compile_schedule, make_pipeline_loss,
                               stage_split_params)

    P, m = args.stages, args.micro
    d_model = 64 if args.smoke else 128
    steps = 2 if args.smoke else 5
    Bm, S = 2 if args.smoke else 4, 16 if args.smoke else 32
    mesh = make_pipeline_mesh(P, 1)
    cfg = get_config("qwen3-4b").reduced(n_layers=2 * P, d_model=d_model)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    k = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(k, (m, Bm, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (m, Bm, S), 0, cfg.vocab_size),
    }
    flat = {k2: v.reshape(m * Bm, S) for k2, v in batch.items()}
    ref = float(lm_loss(params, flat, cfg))

    results = {}
    ok = True
    for sched, V in [("gpipe", 1), ("1f1b", 1), ("1f1b-interleaved", 2),
                     ("zb-h1", 1)]:
        prog = compile_schedule(sched, P, m, V if V > 1 else None)
        exec_prog = prog.forward_program()
        with mesh:
            ps = stage_split_params(params, P, V)
            fn = jax.jit(make_pipeline_loss(cfg, mesh, m, schedule=sched,
                                            n_chunks=V))
            t0 = time.perf_counter()
            loss, _ = jax.block_until_ready(fn(ps, batch))
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            for _ in range(steps):
                loss, _ = jax.block_until_ready(fn(ps, batch))
            step_s = (time.perf_counter() - t0) / steps
        diff = abs(float(loss) - ref)
        match = diff < 5e-3
        ok = ok and match
        results[sched] = {
            "vpp_degree": V,
            "n_ticks": prog.n_ticks,
            "executed_ticks": exec_prog.n_ticks,
            "three_phase": bool(prog.is_three_phase),
            "bubble_ticks": prog.bubble_ticks,
            "bubble_fraction_model": round(
                bubble_fraction(P, m, V, schedule=sched), 4),
            "step_seconds": round(step_s, 4),
            "compile_seconds": round(compile_s, 2),
            "loss": round(float(loss), 6),
            "matches_reference": bool(match),
        }
        print(f"{sched:18s} V={V}  ticks={prog.n_ticks:3d} "
              f"(exec {exec_prog.n_ticks:3d})  "
              f"{step_s*1e3:8.1f} ms/step  Δref={diff:.2e}")
        if not match:
            print(f"ERROR: {sched} diverged from reference "
                  f"({float(loss)} vs {ref})", file=sys.stderr)

    out = {
        "benchmark": "pipeline schedule runtime (gpipe vs 1f1b vs "
                     "1f1b-interleaved) on a host-device pipe mesh",
        "smoke": args.smoke,
        "n_stages": P,
        "n_micro": m,
        "n_layers": cfg.n_layers,
        "d_model": d_model,
        "reference_loss": round(ref, 6),
        "schedules": results,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
