"""Benchmark the Galvatron-BMW strategy-search engine.

Times ``GalvatronOptimizer.optimize()`` on the paper model configs twice per
config:

  * **seed** — both speed knobs off (``enable_stage_cache=False``,
    ``vectorized_cost=False``), which routes every stage search through the
    seed reference implementation (per-(layer, strategy) scalar cost calls +
    per-strategy Python DP loops) with no memoization anywhere; and
  * **optimized** — the defaults: batched (L, S) NumPy cost tables cached
    per (strategy set, micro-batch, inflight) and stage-search results
    memoized on (layer-signature range, B_m, inflight, n_micro, set id).

Both must return identical plans (checked); the wall-clock ratio is the
tentpole speedup.  Results land in ``BENCH_search.json`` at the repo root.

Usage:  PYTHONPATH=src python benchmarks/bench_search.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from repro.configs.paper_models import paper_model_specs
from repro.core import GalvatronOptimizer, galvatron_variant, paper_8gpu

try:
    from benchmarks.common import bert_huge_like
except ImportError:          # invoked as a plain script
    from common import bert_huge_like

GB = 1024 ** 3
REPO = pathlib.Path(__file__).resolve().parent.parent


def bench_configs(smoke: bool):
    if smoke:
        return [("bert-huge-like-8L-8dev", bert_huge_like(8),
                 paper_8gpu().with_budget(8 * GB), dict(batch_grid=[16]))]
    common = dict(batch_grid=[8, 16, 32], micro_candidates=3)
    return [
        ("bert-huge-like-16L-8dev", bert_huge_like(16),
         paper_8gpu().with_budget(8 * GB), dict(common)),
        ("bert-huge-32-8dev", paper_model_specs("bert-huge-32"),
         paper_8gpu().with_budget(8 * GB), dict(common)),
    ]


def run_once(specs, cluster, tweaks, *, seed_mode: bool):
    cfg = galvatron_variant("bmw")
    cfg.micro_candidates = 2
    for k, v in tweaks.items():
        setattr(cfg, k, v)
    if seed_mode:
        cfg.enable_stage_cache = False
        cfg.vectorized_cost = False
    opt = GalvatronOptimizer(specs, cluster, cfg)
    t0 = time.perf_counter()
    plan = opt.optimize()
    return plan, time.perf_counter() - t0, dict(opt.stats)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single small config (CI)")
    ap.add_argument("--repeats", type=int, default=2,
                    help="timed repetitions per mode (min is reported)")
    ap.add_argument("--out", default=str(REPO / "BENCH_search.json"))
    args = ap.parse_args(argv)

    results = {}
    worst = float("inf")
    for name, specs, cluster, tweaks in bench_configs(args.smoke):
        t_new, t_seed = float("inf"), float("inf")
        p_new = p_seed = None
        stats = {}
        for _ in range(max(1, args.repeats)):
            p_new, t, stats = run_once(specs, cluster, tweaks,
                                       seed_mode=False)
            t_new = min(t_new, t)
            p_seed, t, _ = run_once(specs, cluster, tweaks, seed_mode=True)
            t_seed = min(t_seed, t)
        same_plan = p_new == p_seed
        same_tpt = (p_new is None and p_seed is None) or (
            p_new is not None and p_seed is not None
            and p_new.est_throughput == p_seed.est_throughput)
        speedup = t_seed / t_new if t_new > 0 else float("inf")
        worst = min(worst, speedup)
        results[name] = {
            "n_layers": len(specs),
            "n_devices": cluster.n_devices,
            "seed_seconds": round(t_seed, 4),
            "optimized_seconds": round(t_new, 4),
            "speedup": round(speedup, 2),
            "identical_plan": bool(same_plan),
            "identical_throughput": bool(same_tpt),
            "est_throughput": p_new.est_throughput if p_new else None,
            "stage_cache_hits": stats.get("stage_cache_hits"),
            "stage_cache_misses": stats.get("stage_cache_misses"),
            "table_builds": stats.get("table_builds"),
        }
        print(f"{name}: seed {t_seed:.3f}s  optimized {t_new:.3f}s  "
              f"speedup {speedup:.1f}x  identical_plan={same_plan}")
        if not (same_plan and same_tpt):
            print(f"ERROR: {name}: plans diverged between modes",
                  file=sys.stderr)
            return 1

    out = {
        "benchmark": "strategy-search engine (stage memoization + "
                     "vectorized cost tables) vs seed",
        "smoke": args.smoke,
        "min_speedup": round(worst, 2),
        "configs": results,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}  (min speedup {worst:.1f}x)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
