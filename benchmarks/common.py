"""Shared benchmark machinery: run the Galvatron engine in every baseline
mode the paper compares and tabulate estimated throughput."""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.configs.paper_models import paper_model_specs
from repro.core import (ClusterSpec, GalvatronOptimizer, OptimizerConfig,
                        deepspeed_3d, galvatron_variant, pure_baseline)
from repro.core.optimizer import alpa_like, alpa_like_sdp

GB = 1024 ** 3


def bert_huge_like(n_layers: int):
    """Homogeneous BERT-Huge-like stack (paper Table I geometry) — shared
    by the search and frontier benchmarks so both measure the same model."""
    from repro.core.layerspec import dense_layer
    return [dense_layer(f"l{i}", 512, 1280, 20, 20, 5120,
                        causal=False, store_attn_matrix=True)
            for i in range(n_layers)]


STRATEGY_ORDER = [
    "PyTorch DDP (DP)", "Megatron (TP)", "PyTorch GPipe (PP)",
    "FSDP/ZeRO-3 (SDP)", "DeepSpeed 3D", "Galvatron (DP+TP)",
    "Galvatron (DP+PP)", "Galvatron", "Galvatron-Base",
    "Galvatron (1F1B+Bi-obj)", "Alpa (est.)", "Galvatron-BMW",
]


def strategy_config(name: str, n_devices: int) -> OptimizerConfig:
    return {
        "PyTorch DDP (DP)": lambda: pure_baseline("dp", n_devices),
        "Megatron (TP)": lambda: pure_baseline("tp", n_devices),
        "PyTorch GPipe (PP)": lambda: pure_baseline("pp", n_devices),
        "FSDP/ZeRO-3 (SDP)": lambda: pure_baseline("sdp", n_devices),
        "DeepSpeed 3D": lambda: deepspeed_3d(n_devices),
        "Galvatron (DP+TP)": lambda: galvatron_variant("dp+tp"),
        "Galvatron (DP+PP)": lambda: galvatron_variant("dp+pp"),
        "Galvatron": lambda: galvatron_variant("galvatron"),
        "Galvatron-Base": lambda: galvatron_variant("base"),
        "Galvatron (1F1B+Bi-obj)": lambda: galvatron_variant("1f1b-biobj"),
        "Alpa (est.)": lambda: alpa_like(),
        "Galvatron-BMW": lambda: galvatron_variant("bmw"),
    }[name]()


def run_row(model: str, cluster: ClusterSpec, strategies: Sequence[str],
            *, batch_grid=None, n_bins: int = 128,
            micro_candidates: int = 3) -> Dict[str, Dict]:
    specs = paper_model_specs(model)
    out = {}
    for name in strategies:
        t0 = time.time()
        plan = None
        cfg_list = ([alpa_like(), alpa_like_sdp()] if name == "Alpa (est.)"
                    else [strategy_config(name, cluster.n_devices)])
        for cfg in cfg_list:
            cfg.batch_grid = batch_grid or [8, 16, 32, 64, 128]
            cfg.n_bins = n_bins
            cfg.micro_candidates = micro_candidates
            p = GalvatronOptimizer(specs, cluster, cfg).optimize()
            if p and (plan is None or p.est_throughput > plan.est_throughput):
                plan = p
        out[name] = {
            "tpt": plan.est_throughput if plan else 0.0,
            "batch": plan.global_batch if plan else 0,
            "plan": plan.summary() if plan else "OOM",
            "search_s": time.time() - t0,
        }
    return out


def print_table(title: str, rows: Dict[str, Dict[str, Dict]],
                csv_prefix: str) -> List[str]:
    """rows: {model: {strategy: result}}; also returns CSV lines."""
    csv: List[str] = []
    print(f"\n=== {title} ===")
    models = list(rows)
    width = max(len(s) for s in STRATEGY_ORDER) + 2
    header = " " * width + "  ".join(f"{m:>18}" for m in models)
    print(header)
    strategies = [s for s in STRATEGY_ORDER if any(s in rows[m] for m in models)]
    for s in strategies:
        cells = []
        for m in models:
            r = rows[m].get(s)
            if r is None:
                cells.append(f"{'-':>18}")
                continue
            txt = "OOM" if r["tpt"] == 0 else f"{r['tpt']:.2f} ({r['batch']})"
            cells.append(f"{txt:>18}")
            csv.append(f"{csv_prefix}/{m}/{s},{r['search_s']*1e6:.0f},"
                       f"{r['tpt']:.3f}")
        print(f"{s:<{width}}" + "  ".join(cells))
    return csv
