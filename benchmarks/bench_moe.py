"""MoE expert-parallelism benchmark: dispatch identity + the EP flip.

Three claims, all deterministic (fixed-seed jax on CPU / exact cost-model
arithmetic), recorded in ``BENCH_moe.json``:

1. **Dispatch identity** — the capacity-bounded sort dispatch
   (sort + searchsorted + batched expert matmuls) is token-identical to
   the dense einsum oracle that routes every token through every expert:
   fp32 allclose plus exact per-token argmax agreement, including
   capacity overflow (``capacity_factor < 1`` drops the same tokens) and
   the shared-expert / dense-residual branches.  Any divergence fails the
   benchmark (non-zero exit).
2. **EP identity** — the same forward sharded over an ``"expert"`` mesh
   axis (expert weights split across ranks, tokens exchanged with tiled
   ``all_to_all`` dispatch/combine) is token-identical to the
   single-device sort dispatch on a fake-device CPU mesh.
3. **Acceptance flip** — on a pinned 4-layer 8-expert workload under a
   6 GB budget on the PCIe cluster, the best ``ep=1`` plan is *strictly
   slower* than the certified (lint-clean, format v5) ``ep_degree > 1``
   plan the EP-enabled search emits: sharding expert slabs frees memory
   that buys back a cheaper non-expert layout.  A missing flip (no
   ``ep_degree > 1`` plan, or no strict throughput win) fails the
   benchmark.

Usage:  PYTHONPATH=src python benchmarks/bench_moe.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

GB = 1024 ** 3


def _cfg(E, k, cf=1.25, **kw):
    import jax.numpy as jnp
    from repro.models.common import ModelConfig
    return ModelConfig(name="bench", arch_type="moe", n_layers=1,
                       d_model=16, n_heads=4, n_kv_heads=4, d_ff=32,
                       vocab_size=64, n_experts=E, top_k=k,
                       capacity_factor=cf, dtype=jnp.float32, **kw)


# ---------------------------------------------------------------------------
# 1. sort dispatch vs dense einsum oracle (single device)
# ---------------------------------------------------------------------------

def dispatch_identity(cases):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models import moe as M

    rows, all_ok = [], True
    for i, (E, k, cf, extras) in enumerate(cases):
        cfg = _cfg(E, k, cf, **extras)
        p = M.init_moe(jax.random.PRNGKey(i), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(100 + i), (4, 16, 16),
                              jnp.float32)
        t0 = time.perf_counter()
        out, aux = M.moe_ffn(p, x, cfg, dispatch="sort")
        t_sort = time.perf_counter() - t0
        ref, aux_ref = M.moe_ffn(p, x, cfg, dispatch="einsum")
        out, ref = np.asarray(out), np.asarray(ref)
        max_abs = float(np.max(np.abs(out - ref)))
        argmax_same = bool((np.argmax(out.reshape(-1, 16), -1)
                            == np.argmax(ref.reshape(-1, 16), -1)).all())
        aux_close = abs(float(aux) - float(aux_ref)) < 2e-5
        ok = max_abs < 2e-5 and argmax_same and aux_close
        all_ok &= ok
        rows.append({"n_experts": E, "top_k": k, "capacity_factor": cf,
                     **{key: v for key, v in extras.items()},
                     "max_abs_diff": max_abs,
                     "argmax_identical": argmax_same,
                     "aux_loss_matches": aux_close,
                     "sort_wall_s": round(t_sort, 3), "ok": ok})
    return rows, all_ok


# ---------------------------------------------------------------------------
# 2. EP-sharded forward vs single-device sort (fake multi-device CPU mesh)
# ---------------------------------------------------------------------------

def ep_identity(n_dev, cases):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from repro.models import flags
    from repro.models import moe as M

    assert jax.device_count() == n_dev, (
        f"expected {n_dev} fake devices, got {jax.device_count()} — "
        "XLA_FLAGS must be set before jax initializes")
    devs = np.array(jax.devices())
    rows, all_ok = [], True
    for i, (E, k, cf, extras, shape, axes, bt) in enumerate(cases):
        cfg = _cfg(E, k, cf, **extras)
        mesh = Mesh(devs.reshape(shape), axes)
        p = M.init_moe(jax.random.PRNGKey(i), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(200 + i), (8, 16, 16),
                              jnp.float32)
        ref, aux_ref = M.moe_ffn(p, x, cfg, dispatch="sort")
        gate = M.expert_axis_usable(cfg, mesh, 8, bt)
        t0 = time.perf_counter()
        with flags.batch_sharding(bt, mesh=mesh):
            out, aux = M.moe_ffn(p, x, cfg, dispatch="sort")
        t_ep = time.perf_counter() - t0
        out, ref = np.asarray(out), np.asarray(ref)
        max_abs = float(np.max(np.abs(out - ref)))
        argmax_same = bool((np.argmax(out.reshape(-1, 16), -1)
                            == np.argmax(ref.reshape(-1, 16), -1)).all())
        aux_close = abs(float(aux) - float(aux_ref)) < 2e-5
        ok = gate and max_abs < 2e-5 and argmax_same and aux_close
        all_ok &= ok
        rows.append({"n_experts": E, "top_k": k, "capacity_factor": cf,
                     "mesh": "x".join(str(s) for s in shape),
                     "ep_degree": mesh.shape["expert"],
                     "gate_open": bool(gate), "max_abs_diff": max_abs,
                     "argmax_identical": argmax_same,
                     "aux_loss_matches": aux_close,
                     "ep_wall_s": round(t_ep, 3), "ok": ok})
    return rows, all_ok


# ---------------------------------------------------------------------------
# 3. throughput flip: ep=1 strictly slower than the certified ep>1 plan
# ---------------------------------------------------------------------------

def acceptance_flip():
    from repro.analysis import verify_plan_json
    from repro.core import CLUSTERS, GalvatronOptimizer
    from repro.core.layerspec import moe_layer
    from repro.core.optimizer import OptimizerConfig

    specs = [moe_layer(f"l{i}", 2048, 2048, 16, 16, 8192, 8, 2,
                       capacity_factor=1.25) for i in range(4)]
    cluster = CLUSTERS["8x-rtx-titan-pcie"]
    base = dict(batch_grid=(8,), micro_candidates=2, n_bins=64)
    budget = [6 * GB]

    t0 = time.perf_counter()
    p1 = GalvatronOptimizer(specs, cluster, OptimizerConfig(**base)) \
        .sweep_budgets(budget).points[0].plan
    t1 = time.perf_counter()
    p2 = GalvatronOptimizer(specs, cluster,
                            OptimizerConfig(use_ep=True, **base)) \
        .sweep_budgets(budget).points[0].plan
    t2 = time.perf_counter()

    lint_errs = []
    if p2 is not None:
        lint_errs = [d.format() for d in verify_plan_json(p2.to_json())
                     if d.severity == "error"]
    ok = (p1 is not None and p2 is not None and p1.ep_degree == 1
          and p2.ep_degree > 1
          and p2.est_throughput > p1.est_throughput and not lint_errs)

    def _row(p):
        if p is None:
            return None
        return {"ep_degree": p.ep_degree, "pp_degree": p.pp_degree,
                "global_batch": p.global_batch, "n_micro": p.n_micro,
                "est_throughput": round(p.est_throughput, 4),
                "format_version": p.to_json()["format_version"],
                "summary": p.summary()}

    return {
        "workload": "4x moe_layer(seq=2048, d=2048, heads=16, d_ff=8192, "
                    "E=8, top_k=2, cf=1.25)",
        "cluster": cluster.name, "budget_gb": 6,
        "ep1_plan": _row(p1), "ep_plan": _row(p2),
        "throughput_gain": (round(p2.est_throughput / p1.est_throughput, 4)
                            if p1 is not None and p2 is not None else None),
        "lint_errors": lint_errs,
        "search_s_ep1": round(t1 - t0, 2),
        "search_s_ep": round(t2 - t1, 2),
        "ok": ok,
    }, ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small config for CI")
    ap.add_argument("--out", default=str(REPO / "BENCH_moe.json"))
    args = ap.parse_args(argv)

    n_dev = 4 if args.smoke else 8
    # fake CPU devices for the expert mesh — must precede any jax import
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_dev}")

    if args.smoke:
        dispatch_cases = [(8, 2, 1.25, {}),
                          (8, 2, 0.5, {})]            # capacity drops
        ep_cases = [(8, 2, 1.25, {}, (4,), ("expert",), None)]
    else:
        dispatch_cases = [
            (8, 1, 1.25, {}),                          # top-1
            (8, 2, 1.25, {}),                          # top-2
            (8, 2, 0.5, {}),                           # capacity drops
            (16, 2, 1.25, {"shared_expert_ff": 24,     # extra branches
                           "dense_residual_ff": 16}),
        ]
        ep_cases = [
            (8, 2, 1.25, {}, (2, 4), ("data", "expert"), ("data",)),
            (8, 1, 1.25, {}, (8,), ("expert",), None),
            (8, 2, 0.5, {}, (2, 4), ("data", "expert"), ("data",)),
            (16, 2, 1.25, {}, (1, 8), ("data", "expert"), ("data",)),
        ]

    disp_rows, disp_ok = dispatch_identity(dispatch_cases)
    ep_rows, ep_ok = ep_identity(n_dev, ep_cases)
    flip, flip_ok = acceptance_flip()

    ok = bool(disp_ok and ep_ok and flip_ok)
    out = {
        "benchmark": "MoE expert parallelism: sort-dispatch vs einsum-"
                     "oracle token identity, EP-sharded all-to-all vs "
                     "single-device identity, and the 6 GB ep>1 "
                     "throughput flip",
        "smoke": args.smoke,
        "ep_devices": n_dev,
        "dispatch_identity": disp_rows,
        "ep_identity": ep_rows,
        "acceptance_flip": flip,
        "ok": ok,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")

    worst_d = max(r["max_abs_diff"] for r in disp_rows)
    worst_e = max(r["max_abs_diff"] for r in ep_rows)
    print(f"sort vs einsum oracle: {len(disp_rows)} configs, "
          f"max |diff| {worst_d:.2e}")
    print(f"EP identity on {n_dev} devices: {len(ep_rows)} configs, "
          f"max |diff| {worst_e:.2e}, argmax identical="
          f"{all(r['argmax_identical'] for r in ep_rows)}")
    ep1 = flip["ep1_plan"]["est_throughput"] if flip["ep1_plan"] else 0
    epn = flip["ep_plan"]["est_throughput"] if flip["ep_plan"] else 0
    epd = flip["ep_plan"]["ep_degree"] if flip["ep_plan"] else 0
    print(f"flip @{flip['budget_gb']} GB: ep1 {ep1} samples/s -> "
          f"ep{epd} {epn} samples/s "
          f"(lint errors: {len(flip['lint_errors'])})")
    print(f"wrote {args.out}")
    if not ok:
        print("ERROR: MoE benchmark invariants violated", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
