"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows at the end (derived = the
table's key metric: estimated samples/s throughput, counts, ratios).

    PYTHONPATH=src python -m benchmarks.run [--full]

``--quick`` (default) runs reduced grids so the whole harness finishes in
minutes on CPU; ``--full`` sweeps every memory budget of the paper tables.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List

from benchmarks.common import GB, print_table, run_row
from repro.core import (construct_search_space, paper_8gpu, paper_16gpu_high,
                        paper_16gpu_low, paper_32gpu_80g, paper_64gpu)

CSV: List[str] = []


def bench_search_space() -> None:
    """§III-B: decision-tree counts (68 -> 44 @ 8 GPUs) and growth."""
    t0 = time.time()
    n44 = construct_search_space(8).total_leaves()
    n68 = construct_search_space(8, prune_dp_sdp=False).total_leaves()
    n16 = construct_search_space(16).total_leaves()
    n64 = construct_search_space(64).total_leaves()
    us = (time.time() - t0) * 1e6
    print(f"\n=== Search space (paper §III-B) ===\n"
          f"8 GPUs: {n68} before T#3, {n44} after (paper: 68/44)\n"
          f"16 GPUs: {n16} leaves; 64 GPUs: {n64} leaves")
    assert (n68, n44) == (68, 44)
    CSV.append(f"search_space/8gpu_after_t3,{us:.0f},{n44}")
    CSV.append(f"search_space/8gpu_before_t3,{us:.0f},{n68}")


def bench_table2(full: bool) -> None:
    """Table II: 8x RTX-TITAN, throughput under memory budgets."""
    budgets = [8, 12, 16, 20] if full else [8, 16]
    models = ["bert-huge-32", "vit-huge-32", "t5-large-32", "swin-huge-32"]
    strategies = None
    from benchmarks.common import STRATEGY_ORDER
    for budget in budgets:
        cluster = paper_8gpu().with_budget(budget * GB)
        rows = {m: run_row(m, cluster, STRATEGY_ORDER) for m in models}
        CSV.extend(print_table(f"Table II @ {budget}G", rows,
                               f"table2/{budget}G"))
        for m in models:
            bmw = rows[m]["Galvatron-BMW"]["tpt"]
            others = [rows[m][s]["tpt"] for s in STRATEGY_ORDER
                      if s != "Galvatron-BMW"]
            assert bmw >= max(others) * 0.999, (m, budget)


def bench_table3(full: bool) -> None:
    """Table III: 16-GPU low-perf and high-perf clusters."""
    models = ["bert-huge-32", "vit-huge-32", "t5-512/4-32"]
    if full:
        models += ["bert-huge-48", "vit-huge-48", "t5-512/4-48"]
    from benchmarks.common import STRATEGY_ORDER
    for name, cluster in [("low-perf", paper_16gpu_low()),
                          ("high-perf", paper_16gpu_high())]:
        c = cluster.with_budget(8 * GB)
        rows = {m: run_row(m, c, STRATEGY_ORDER,
                           batch_grid=[16, 32, 64, 128, 256])
                for m in models}
        CSV.extend(print_table(f"Table III {name} @ 8G", rows,
                               f"table3/{name}"))


def bench_table4(full: bool) -> None:
    """Table IV: 64 GPUs, xHuge (10B) models."""
    models = ["bert-xhuge"] + (["vit-xhuge"] if full else [])
    from benchmarks.common import STRATEGY_ORDER
    cluster = paper_64gpu().with_budget(16 * GB)
    strategies = STRATEGY_ORDER if full else [
        "Megatron (TP)", "PyTorch GPipe (PP)", "FSDP/ZeRO-3 (SDP)",
        "DeepSpeed 3D", "Galvatron", "Galvatron-Base", "Galvatron-BMW"]
    rows = {m: run_row(m, cluster, strategies,
                       batch_grid=[16, 32, 64, 128], n_bins=96)
            for m in models}
    CSV.extend(print_table("Table IV (64 GPUs, 16G)", rows, "table4"))


def bench_table5() -> None:
    """Table V ablation: memory- vs time-balanced vs bi-objective pipeline
    partitions (16x A100, BERT-Huge / T5-512/4)."""
    import numpy as np
    from repro.configs.paper_models import paper_model_specs
    from repro.core import GalvatronOptimizer, galvatron_variant
    from repro.core.optimizer import OptimizerConfig

    cluster = paper_16gpu_high().with_budget(8 * GB)
    print("\n=== Table V: bi-objective ablation (16 A100 @ 8G) ===")
    for model in ["bert-huge-48", "t5-512/4-48"]:
        specs = paper_model_specs(model)
        results = {}
        for mode, biobj in [("1F1B+Mem", False), ("1F1B+Bi-obj", True)]:
            cfg = galvatron_variant("1f1b-biobj")
            cfg.bi_objective = biobj
            cfg.batch_grid = [16, 32, 64]
            cfg.n_bins = 96
            cfg.micro_candidates = 2
            plan = GalvatronOptimizer(specs, cluster, cfg).optimize()
            results[mode] = plan
            t = plan.est_throughput if plan else 0.0
            part = plan.partition if plan else []
            a_t = plan.alpha_t if plan else 0.0
            a_m = plan.alpha_m if plan else 0.0
            print(f"{model:14} {mode:12} tpt={t:8.2f} p={part} "
                  f"alpha_t={a_t:.3f} alpha_m={a_m:.3f}")
            CSV.append(f"table5/{model}/{mode},0,{t:.3f}")
        pm = results["1F1B+Mem"]
        bi = results["1F1B+Bi-obj"]
        if pm and bi:
            assert bi.est_throughput >= pm.est_throughput * 0.999


def bench_table6(full: bool) -> None:
    """Table VI: GPT-3 15B/39B/65B on 32x A100-80G."""
    models = ["gpt3-15b"] + (["gpt3-39b", "gpt3-65b"] if full else [])
    from benchmarks.common import STRATEGY_ORDER
    cluster = paper_32gpu_80g().with_budget(72 * GB)
    strategies = ["Megatron (TP)", "PyTorch GPipe (PP)", "FSDP/ZeRO-3 (SDP)",
                  "DeepSpeed 3D", "Galvatron", "Galvatron-Base",
                  "Alpa (est.)", "Galvatron-BMW"]
    rows = {m: run_row(m, cluster, strategies,
                       batch_grid=[8, 16, 32, 64, 128, 256], n_bins=96,
                       micro_candidates=2) for m in models}
    CSV.extend(print_table("Table VI (32x A100-80G)", rows, "table6"))
    for m in models:   # paper: Galvatron-BMW > Alpa (CKPT + DP/SDP mixing)
        assert rows[m]["Galvatron-BMW"]["tpt"] >= rows[m]["Alpa (est.)"]["tpt"] * 0.999


def bench_search_time() -> None:
    """Fig. 5: search-time scaling with #layers and #strategy dims."""
    from repro.configs.paper_models import paper_model_specs
    from repro.core import GalvatronOptimizer, galvatron_variant
    from repro.core.layerspec import dense_layer
    cluster = paper_8gpu().with_budget(8 * GB)
    print("\n=== Fig. 5: search-time scaling ===")
    times = {}
    for n_layers in [8, 16, 32, 64]:
        specs = [dense_layer(f"l{i}", 512, 768, 12, 12, 3072,
                             store_attn_matrix=True) for i in range(n_layers)]
        cfg = galvatron_variant("base")
        cfg.batch_grid = [16]
        cfg.n_bins = 128
        t0 = time.time()
        GalvatronOptimizer(specs, cluster, cfg).optimize()
        times[n_layers] = time.time() - t0
        print(f"L={n_layers:3d}: {times[n_layers]*1000:8.1f} ms")
        CSV.append(f"fig5/layers_{n_layers},{times[n_layers]*1e6:.0f},"
                   f"{times[n_layers]:.4f}")
    # linear-ish growth: 8x layers < ~24x time
    assert times[64] < 24 * max(times[8], 1e-3)


def bench_overlap() -> None:
    """Fig. 7 analogue: effect of modeling the comp/comm overlap slowdown
    on the estimated iteration time (ignoring it under-estimates ~15-30%)."""
    import dataclasses
    from repro.configs.paper_models import paper_model_specs
    from repro.core import CostModel, Strategy, paper_8gpu
    cluster = paper_8gpu()
    no_slow = dataclasses.replace(
        cluster, device=dataclasses.replace(cluster.device,
                                            overlap_slowdown=1.0))
    specs = paper_model_specs("bert-huge-32")
    s = Strategy((("dp", 8),))
    t_with = sum(CostModel(cluster).layer_costs(sp, s, 64.0).time
                 for sp in specs)
    t_without = sum(CostModel(no_slow).layer_costs(sp, s, 64.0).time
                    for sp in specs)
    ratio = t_with / t_without
    print(f"\n=== Fig. 7: overlap slowdown ===\n"
          f"estimated iter time with slowdown = {ratio:.3f}x the naive "
          f"estimate (paper: ignoring it gives >15% error)")
    CSV.append(f"fig7/overlap_ratio,0,{ratio:.4f}")
    assert ratio > 1.1


def bench_roofline() -> None:
    """Surface the dry-run roofline table if the sweep has been run."""
    import json
    import pathlib
    p = pathlib.Path("experiments/dryrun_single.jsonl")
    if not p.exists():
        print("\n(roofline: experiments/dryrun_single.jsonl not present — "
              "run `python -m repro.launch.dryrun --all` first)")
        return
    rows = [json.loads(l) for l in p.read_text().splitlines() if l.strip()]
    print(f"\n=== Roofline (from {len(rows)} dry-run rows) ===")
    for r in rows[-10:]:
        print(f"{r['arch']:20} {r['shape']:12} {r['bottleneck']:10} "
              f"c={r['t_compute_s']:.4f}s m={r['t_memory_s']:.4f}s "
              f"x={r['t_collective_s']:.4f}s useful={r['useful_flops_ratio']:.2f}")
        CSV.append(f"roofline/{r['arch']}/{r['shape']},0,"
                   f"{r['useful_flops_ratio']:.4f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    bench_search_space()
    bench_table2(args.full)
    bench_table3(args.full)
    bench_table4(args.full)
    bench_table5()
    bench_table6(args.full)
    bench_search_time()
    bench_overlap()
    bench_roofline()
    print(f"\nAll benchmarks done in {time.time()-t0:.1f}s\n")
    print("name,us_per_call,derived")
    for line in CSV:
        print(line)


if __name__ == "__main__":
    main()
