"""Benchmark the cluster-scale search engine against the PR-4 baseline.

The PR-4 thread-pool sweep (``parallel=True``) fans every (B, P) outer
candidate of the configured grid eagerly — including everything past the
per-budget two-consecutive-OOM stopping point.  This benchmark times a
full batch x PP x schedule sweep in that baseline mode and in the new
engine modes (``search_backend`` x ``prune_batch_axis``):

  * **threads (PR-4 baseline)** — eager thread-pool fan-out of the whole
    candidate grid;
  * **vectorized + prune** — each partition's stage DPs batched into one
    stacked NumPy evaluation, frontier-guided pruning skipping (B, P)
    candidates whose certified optimistic bound is dominated or provably
    over-budget;
  * **processes + prune** — process-pool fan-out of the surviving
    candidates.

Every engine mode must return plans *byte-identical* to the serial oracle
(``ParallelPlan.canonical_dumps``) — any divergence fails the benchmark
(exit 1) — and the pruned modes must report nonzero skip counts.  A
candidate-count scaling curve (prefixes of the linear Alg. 1 grid) shows
the baseline growing linearly with the grid while the pruned engine
flattens once the feasible region is exhausted.

Results land in ``BENCH_scale.json`` at the repo root.

Usage:  PYTHONPATH=src python benchmarks/bench_scale.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from repro.core import GalvatronOptimizer, OptimizerConfig, paper_8gpu

try:
    from benchmarks.common import bert_huge_like
except ImportError:          # invoked as a plain script
    from common import bert_huge_like

GB = 1024 ** 3
REPO = pathlib.Path(__file__).resolve().parent.parent

#: engine modes timed against the serial oracle; "threads" is the PR-4
#: eager thread-pool baseline the speedup is quoted against
MODES = (
    ("threads", dict(backend="threads", prune=False)),
    ("vectorized+prune", dict(backend="vectorized", prune=True)),
    ("processes+prune", dict(backend="processes", prune=True)),
)


def bench_configs(smoke: bool):
    """(name, specs, budgets, grid, cfg-tweaks) benchmark settings."""
    if smoke:
        return [(
            "linear-grid-8L-8dev",
            bert_huge_like(8),
            [2.0 * GB, 3.0 * GB],
            list(range(8, 129, 8)),
            dict(micro_candidates=2),
        )]
    return [
        # paper Alg. 1 linear batch grid (B += 8): the feasible region ends
        # early, the eager baseline grinds the whole grid anyway
        (
            "linear-grid-32L-8dev",
            bert_huge_like(32),
            [2.0 * GB, 2.6 * GB, 3.4 * GB],
            list(range(8, 513, 8)),
            dict(micro_candidates=3),
        ),
        # geometric grid with the engine-default micro-batch axis: feasible
        # throughout, pruning certifies away the over-budget candidates
        (
            "geometric-grid-32L-8dev",
            bert_huge_like(32),
            [2.0 * GB, 2.6 * GB, 3.4 * GB],
            None,                       # default_batch_grid(max_batch)
            dict(max_batch=65536),
        ),
    ]


def run_once(specs, budgets, grid, tweaks, *, backend, prune,
             parallel=False):
    cfg = OptimizerConfig(
        batch_grid=grid, allow_ckpt=False,
        schedules=("1f1b", "gpipe", "zb-h1", "1f1b-interleaved"),
        search_backend=backend, prune_batch_axis=prune)
    for k, v in tweaks.items():
        setattr(cfg, k, v)
    opt = GalvatronOptimizer(specs, paper_8gpu(), cfg)
    t0 = time.perf_counter()
    frontier = opt.sweep_budgets(budgets, parallel=parallel)
    dt = time.perf_counter() - t0
    dumps = [p.plan.canonical_dumps() if p.plan is not None else None
             for p in frontier.points]
    return dumps, dt, dict(opt.stats)


def scaling_curve(smoke: bool):
    """Wall-clock vs candidate count: prefixes of the linear Alg. 1 grid."""
    specs = bert_huge_like(8 if smoke else 16)
    budgets = [2.0 * GB, 3.0 * GB]
    lengths = (4, 8, 16) if smoke else (8, 16, 32, 64)
    curve = []
    for n in lengths:
        grid = list(range(8, 8 * n + 1, 8))
        point = {"grid_points": n}
        base, t_ser, _ = run_once(specs, budgets, grid, {},
                                  backend="serial", prune=False)
        point["serial_seconds"] = round(t_ser, 4)
        for name, mode in (("threads", dict(backend="threads", prune=False)),
                           ("vectorized+prune",
                            dict(backend="vectorized", prune=True))):
            dumps, t, stats = run_once(specs, budgets, grid, {}, **mode)
            if dumps != base:
                print(f"ERROR: scaling curve n={n} {name}: plans diverged "
                      "from serial", file=sys.stderr)
                return None
            point[f"{name}_seconds"] = round(t, 4)
            if mode["prune"]:
                point["pruned_candidates"] = int(
                    stats["bp_pruned_infeasible"]
                    + stats["bp_pruned_dominated"] - stats["bp_forced"])
        curve.append(point)
        print(f"scaling n={n:3d}: serial {point['serial_seconds']:.3f}s  "
              f"threads {point['threads_seconds']:.3f}s  "
              f"vectorized+prune {point['vectorized+prune_seconds']:.3f}s")
    return curve


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single small config + short curve (CI)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="timed repetitions per mode (min is reported)")
    ap.add_argument("--out", default=str(REPO / "BENCH_scale.json"))
    args = ap.parse_args(argv)

    results = {}
    headline = 0.0
    for name, specs, budgets, grid, tweaks in bench_configs(args.smoke):
        base, t_ser, _ = run_once(specs, budgets, grid, tweaks,
                                  backend="serial", prune=False)
        for _ in range(args.repeats - 1):
            _, t, _ = run_once(specs, budgets, grid, tweaks,
                               backend="serial", prune=False)
            t_ser = min(t_ser, t)
        row = {
            "n_layers": len(specs),
            "budgets_gb": [round(b / GB, 2) for b in budgets],
            "grid_points": len(grid) if grid else "default",
            "feasible": [d is not None for d in base],
            "serial_seconds": round(t_ser, 4),
            "modes": {},
        }
        t_baseline = None
        for mode_name, mode in MODES:
            t_mode = float("inf")
            dumps, stats = None, {}
            for _ in range(max(1, args.repeats)):
                dumps, t, stats = run_once(specs, budgets, grid, tweaks,
                                           **mode)
                t_mode = min(t_mode, t)
            identical = dumps == base
            skipped = int(stats["bp_pruned_infeasible"]
                          + stats["bp_pruned_dominated"]
                          - stats["bp_forced"])
            entry = {
                "seconds": round(t_mode, 4),
                "identical_to_serial": bool(identical),
                "pruned_infeasible": int(stats["bp_pruned_infeasible"]),
                "pruned_dominated": int(stats["bp_pruned_dominated"]),
                "forced": int(stats["bp_forced"]),
                "candidates": int(stats["bp_candidates"]),
                "stage_cache_hits": int(stats["stage_cache_hits"]),
                "stage_cache_misses": int(stats["stage_cache_misses"]),
            }
            if mode_name == "threads":
                t_baseline = t_mode
            else:
                speedup = (t_baseline / t_mode if t_mode > 0
                           else float("inf"))
                entry["speedup_vs_pr4_threads"] = round(speedup, 2)
                headline = max(headline, speedup)
                if mode["prune"] and skipped <= 0:
                    print(f"WARNING: {name} {mode_name}: pruning skipped "
                          "no candidates", file=sys.stderr)
            row["modes"][mode_name] = entry
            print(f"{name} {mode_name}: {t_mode:.3f}s  "
                  f"identical={identical}  pruned={skipped}")
            if not identical:
                print(f"ERROR: {name} {mode_name}: plans diverged from the "
                      "serial oracle", file=sys.stderr)
                return 1
        results[name] = row

    curve = scaling_curve(args.smoke)
    if curve is None:
        return 1

    out = {
        "benchmark": "cluster-scale sweep (backend fan-out + frontier-"
                     "guided batch-axis pruning) vs PR-4 eager thread pool",
        "smoke": args.smoke,
        "headline_speedup": round(headline, 2),
        "configs": results,
        "scaling_curve": curve,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}  (headline speedup {headline:.1f}x)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
