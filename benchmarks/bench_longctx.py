"""Long-context benchmark: ring-attention sequence parallelism.

Three claims, all deterministic (exact arithmetic or fixed-seed jax on
fake CPU devices), recorded in ``BENCH_longctx.json``:

1. **Token identity** — ring attention executed over a ``seq`` mesh axis
   (K/V panels rotated with ``lax.ppermute``) is token-identical to the
   single-device flash kernel: fp32 allclose plus exact per-token argmax
   agreement.  Any divergence fails the benchmark (non-zero exit).
2. **Memory** — per-device activation bytes from the cost model divide by
   exactly ``sp_degree`` (parameters replicate, so model states do not),
   which is the entire long-context story: DP/TP/PP shard batch and
   hidden dims, only SP shards the sequence dim.
3. **Feasibility flip** — the search on a >=64k-token config under a
   fixed per-device budget (with the physical ``min_samples_per_device``
   floor, so data parallelism cannot pretend to split one sequence) is
   infeasible at sp=1 but emits a certified (lint-clean) ``sp_degree>1``
   plan with ``--sp``.

Usage:  PYTHONPATH=src python benchmarks/bench_longctx.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

GB = 1024 ** 3


# ---------------------------------------------------------------------------
# 1. ring vs dense token identity (fake multi-device CPU mesh)
# ---------------------------------------------------------------------------

def ring_identity(n_dev: int, cases):
    import jax
    import numpy as np
    from repro.kernels.flash_attention import flash_attention
    from repro.launch.mesh import make_ring_mesh
    from repro.runtime import ring_attention_on_mesh

    assert jax.device_count() == n_dev, (
        f"expected {n_dev} fake devices, got {jax.device_count()} — "
        "XLA_FLAGS must be set before jax initializes")
    mesh = make_ring_mesh(n_dev)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    rows, all_ok = [], True
    for (B, S, H, KV, dh, causal, window) in cases:
        q = jax.random.normal(ks[0], (B, S, H, dh))
        k = jax.random.normal(ks[1], (B, S, KV, dh))
        v = jax.random.normal(ks[2], (B, S, KV, dh))
        fn = ring_attention_on_mesh(mesh, causal=causal, window=window,
                                    block_q=32, block_k=32)
        t0 = time.perf_counter()
        out = np.asarray(fn(q, k, v))
        t_ring = time.perf_counter() - t0
        ref = np.asarray(flash_attention(q, k, v, causal=causal,
                                         window=window, block_q=32,
                                         block_k=32, interpret=True))
        max_abs = float(np.max(np.abs(out - ref)))
        argmax_same = bool((np.argmax(out.reshape(-1, dh), -1)
                            == np.argmax(ref.reshape(-1, dh), -1)).all())
        ok = max_abs < 2e-5 and argmax_same
        all_ok &= ok
        rows.append({"B": B, "S": S, "H": H, "KV": KV, "dh": dh,
                     "causal": causal, "window": window,
                     "max_abs_diff": max_abs, "argmax_identical": argmax_same,
                     "ring_wall_s": round(t_ring, 3), "ok": ok})
    return rows, all_ok


# ---------------------------------------------------------------------------
# 2. per-device activation bytes vs sp_degree (pure cost model, no jax)
# ---------------------------------------------------------------------------

def activation_scaling(seq: int, sp_degrees):
    from repro.core import CLUSTERS, CostModel, Strategy
    from repro.core.layerspec import dense_layer

    cm = CostModel(CLUSTERS["16x-a100-nvlink-ib100"])
    spec = dense_layer("l", seq, 2048, 16, 4, 8192)
    base = cm.layer_costs(spec, Strategy((("dp", 1),), ckpt=False), 1.0)
    rows, ok = [], True
    for sp in sp_degrees:
        c = cm.layer_costs(spec, Strategy((("sp", sp),), ckpt=False), 1.0)
        exact = c.mem_f == base.mem_f / sp and c.mem_ms == base.mem_ms
        ok &= exact
        rows.append({"sp_degree": sp,
                     "activation_bytes_per_device": c.mem_f,
                     "model_state_bytes_per_device": c.mem_ms,
                     "divides_exactly": exact})
    return rows, ok


# ---------------------------------------------------------------------------
# 3. >=64k feasibility flip under the physical per-device batch floor
# ---------------------------------------------------------------------------

def feasibility_flip(smoke: bool):
    from repro.analysis import verify_plan_json
    from repro.configs import get_config
    from repro.configs.specs import layerspecs_for
    from repro.core import CLUSTERS, GalvatronOptimizer
    from repro.core.cost_model import CostModelConfig
    from repro.core.optimizer import OptimizerConfig

    seq = 131072
    specs = layerspecs_for(get_config("qwen3-4b"), seq)
    cluster = CLUSTERS["16x-a100-nvlink-ib100"]
    cc = CostModelConfig(min_samples_per_device=1.0)
    base = dict(batch_grid=(1, 2) if smoke else (1, 2, 4),
                micro_candidates=2, n_bins=64)
    budget = [32 * GB]

    t0 = time.perf_counter()
    sp1 = GalvatronOptimizer(specs, cluster, OptimizerConfig(**base),
                             cc).sweep_budgets(budget).points[0].plan
    t1 = time.perf_counter()
    sp_on = GalvatronOptimizer(specs, cluster,
                               OptimizerConfig(use_sp=True, **base),
                               cc).sweep_budgets(budget).points[0].plan
    t2 = time.perf_counter()

    lint_errs = []
    if sp_on is not None:
        lint_errs = [d.format() for d in verify_plan_json(sp_on.to_json())
                     if d.severity == "error"]
    ok = (sp1 is None and sp_on is not None and sp_on.sp_degree > 1
          and sp_on.seq_len == seq and not lint_errs)
    return {
        "config": "qwen3-4b", "seq_len": seq, "cluster": cluster.name,
        "budget_gb": 32, "min_samples_per_device": 1.0,
        "sp1_feasible": sp1 is not None,
        "sp_plan": None if sp_on is None else {
            "sp_degree": sp_on.sp_degree, "pp_degree": sp_on.pp_degree,
            "global_batch": sp_on.global_batch, "n_micro": sp_on.n_micro,
            "est_throughput": round(sp_on.est_throughput, 4),
            "summary": sp_on.summary()},
        "lint_errors": lint_errs,
        "search_s_sp1": round(t1 - t0, 2),
        "search_s_sp": round(t2 - t1, 2),
        "ok": ok,
    }, ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small config for CI")
    ap.add_argument("--out", default=str(REPO / "BENCH_longctx.json"))
    args = ap.parse_args(argv)

    n_dev = 4 if args.smoke else 8
    # fake CPU devices for the seq mesh — must precede any jax import
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_dev}")

    if args.smoke:
        cases = [(1, 128, 2, 2, 32, True, None),
                 (1, 128, 2, 1, 32, True, 48)]
    else:
        cases = [(1, 256, 2, 2, 32, True, None),     # causal MHA
                 (2, 512, 4, 2, 32, True, 96),       # window crossing shards
                 (1, 256, 4, 1, 64, False, None),    # bidirectional MQA
                 (1, 64, 2, 2, 32, True, 5)]         # tiny window shards

    ident_rows, ident_ok = ring_identity(n_dev, cases)
    act_rows, act_ok = activation_scaling(65536, (1, 2, 4, 8))
    flip, flip_ok = feasibility_flip(args.smoke)

    ok = bool(ident_ok and act_ok and flip_ok)
    out = {
        "benchmark": "ring-attention sequence parallelism: token identity "
                     "vs the dense kernel, activation-memory / sp_degree "
                     "scaling, and the >=64k-token feasibility flip",
        "smoke": args.smoke,
        "ring_devices": n_dev,
        "token_identity": ident_rows,
        "activation_scaling_seq": 65536,
        "activation_scaling": act_rows,
        "feasibility_flip": flip,
        "ok": ok,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")

    worst = max(r["max_abs_diff"] for r in ident_rows)
    print(f"ring identity on {n_dev} devices: {len(ident_rows)} configs, "
          f"max |diff| {worst:.2e}, argmax identical="
          f"{all(r['argmax_identical'] for r in ident_rows)}")
    mb = act_rows[0]["activation_bytes_per_device"] / (1 << 20)
    print(f"activation bytes @65536 tokens: {mb:.0f} MiB at sp=1, "
          f"/sp exactly={act_ok}")
    sp_deg = flip["sp_plan"]["sp_degree"] if flip["sp_plan"] else 0
    print(f"flip @{flip['seq_len']} tokens, {flip['budget_gb']} GB: "
          f"sp1 feasible={flip['sp1_feasible']}, sp plan sp_degree={sp_deg} "
          f"(lint errors: {len(flip['lint_errors'])})")
    print(f"wrote {args.out}")
    if not ok:
        print("ERROR: long-context benchmark invariants violated",
              file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
