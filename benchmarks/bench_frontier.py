"""Benchmark the budget-sweep frontier engine (DESIGN.md §6).

Times an 8-point ``sweep_budgets`` against 8 independent serial
``optimize()`` calls (one fresh optimizer per budget, pinned to the sweep's
quantization grid so plans are comparable bin-for-bin).  The sweep must
return byte-identical plans at every budget — it is a pure restructuring of
the same search — and the wall-clock ratio is the tentpole win: the stage
DP runs once with a budget axis and the budget-independent memo caches
(cost tables, reference costs, seed partitions) are shared across budgets
instead of rebuilt per call.

Also times the ``parallel=True`` (B, P) fan-out and checks its frontier,
plans and aggregated cache telemetry (hits + misses == lookups) against
the serial sweep.

Results land in ``BENCH_frontier.json`` at the repo root.

Usage:  PYTHONPATH=src python benchmarks/bench_frontier.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from repro.core import GalvatronOptimizer, galvatron_variant, paper_8gpu

try:
    from benchmarks.common import bert_huge_like
except ImportError:          # invoked as a plain script
    from common import bert_huge_like

GB = 1024 ** 3
REPO = pathlib.Path(__file__).resolve().parent.parent


def bench_configs(smoke: bool):
    budgets = [b * GB for b in (4, 6, 8, 10, 12, 14, 16, 18)]
    if smoke:
        return [("bert-huge-like-8L-8dev", bert_huge_like(8), paper_8gpu(),
                 dict(batch_grid=[16]), budgets)]
    common = dict(batch_grid=[8, 16, 32], micro_candidates=3)
    return [
        ("bert-huge-like-16L-8dev", bert_huge_like(16), paper_8gpu(),
         dict(common), budgets),
        ("bert-huge-like-32L-8dev", bert_huge_like(32), paper_8gpu(),
         dict(common), budgets),
    ]


def make_opt(specs, cluster, tweaks, *, budget=None, quant=None):
    cfg = galvatron_variant("bmw")
    cfg.micro_candidates = 2
    cfg.n_bins = 128
    for k, v in tweaks.items():
        setattr(cfg, k, v)
    cfg.budget_bytes = budget
    cfg.quant_bytes = quant
    return GalvatronOptimizer(specs, cluster, cfg)


def canonical(plan):
    return plan.canonical_dumps() if plan is not None else None


def run_config(name, specs, cluster, tweaks, budgets, repeats):
    quant = max(budgets)
    t_serial = t_sweep = t_parallel = float("inf")
    serial_plans = frontier = par_frontier = None
    stats = par_stats = {}
    for _ in range(max(1, repeats)):
        # ---- N independent serial optimize() calls ---------------------
        t0 = time.perf_counter()
        serial_plans = {}
        for b in budgets:
            opt = make_opt(specs, cluster, tweaks, budget=b, quant=quant)
            serial_plans[b] = opt.optimize()
        t_serial = min(t_serial, time.perf_counter() - t0)
        # ---- one budget-axis sweep -------------------------------------
        opt = make_opt(specs, cluster, tweaks)
        t0 = time.perf_counter()
        frontier = opt.sweep_budgets(budgets)
        t_sweep = min(t_sweep, time.perf_counter() - t0)
        stats = dict(opt.stats)
        # ---- parallel (B, P) fan-out -----------------------------------
        opt = make_opt(specs, cluster, tweaks)
        t0 = time.perf_counter()
        par_frontier = opt.sweep_budgets(budgets, parallel=True)
        t_parallel = min(t_parallel, time.perf_counter() - t0)
        par_stats = dict(opt.stats)

    identical = all(
        canonical(p.plan) == canonical(serial_plans[p.budget_bytes])
        for p in frontier.points)
    par_identical = all(
        canonical(p.plan) == canonical(q.plan)
        for p, q in zip(par_frontier.points, frontier.points))
    counters_ok = all(
        s["stage_cache_hits"] + s["stage_cache_misses"] == s["stage_searches"]
        for s in (stats, par_stats))
    speedup = t_serial / t_sweep if t_sweep > 0 else float("inf")
    return {
        "n_layers": len(specs),
        "n_devices": cluster.n_devices,
        "budgets_gb": [b / GB for b in budgets],
        "serial_seconds": round(t_serial, 4),
        "sweep_seconds": round(t_sweep, 4),
        "parallel_seconds": round(t_parallel, 4),
        "speedup": round(speedup, 2),
        "identical_plans": bool(identical),
        "parallel_identical": bool(par_identical),
        "cache_counters_consistent": bool(counters_ok),
        "throughputs": frontier.throughputs(),
        "knee_budgets_gb": [p.budget_bytes / GB
                            for p in frontier.knee_points()],
        "sweep_stats": {k: stats.get(k) for k in
                        ("stage_searches", "stage_cache_hits",
                         "stage_cache_misses", "table_builds", "table_hits")},
        "parallel_stats": {k: par_stats.get(k) for k in
                           ("stage_searches", "stage_cache_hits",
                            "stage_cache_misses", "table_builds",
                            "table_hits")},
    }, identical and par_identical and counters_ok, speedup


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single small config (CI)")
    ap.add_argument("--repeats", type=int, default=2,
                    help="timed repetitions (min is reported)")
    ap.add_argument("--out", default=str(REPO / "BENCH_frontier.json"))
    args = ap.parse_args(argv)

    results = {}
    worst = float("inf")
    ok = True
    for name, specs, cluster, tweaks, budgets in bench_configs(args.smoke):
        row, row_ok, speedup = run_config(name, specs, cluster, tweaks,
                                          budgets, args.repeats)
        results[name] = row
        worst = min(worst, speedup)
        ok = ok and row_ok
        print(f"{name}: serial {row['serial_seconds']:.3f}s  "
              f"sweep {row['sweep_seconds']:.3f}s  "
              f"parallel {row['parallel_seconds']:.3f}s  "
              f"speedup {speedup:.1f}x  identical={row['identical_plans']}")
        if not row_ok:
            print(f"ERROR: {name}: sweep diverged from serial optimizes "
                  f"(or cache counters inconsistent)", file=sys.stderr)

    out = {
        "benchmark": "budget-sweep frontier engine (one budget-axis search) "
                     "vs N independent serial optimize() calls",
        "smoke": args.smoke,
        "n_budgets": len(bench_configs(args.smoke)[0][4]),
        "min_speedup": round(worst, 2),
        "configs": results,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}  (min speedup {worst:.1f}x)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
