"""Analytic per-layer workload descriptions for the cost estimator (§V).

The estimator needs, per layer: parameter count, forward FLOPs, and the
activation footprint split into *boundary* activations ``bnd`` (layer inputs,
kept even under CKPT) and *intermediate* activations ``int`` (released by
CKPT during forward, recomputed and held during backward).

All byte numbers are per *sample* (one sequence) so the cost model can scale
them by the per-device micro-batch.  ``ACT_CALIBRATION`` is a single global
constant fitted against the paper's profiled Table I activation sizes
(dropout masks, optimizer workspace, fragmentation); parameter counts are
exact analytic values.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

BYTES_ACT = 2          # bf16 / fp16 activations
ACT_CALIBRATION = 2.1  # fitted once against paper Table I (see tests)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Workload of one model layer (full, unsharded)."""

    name: str
    kind: str                     # attn_mlp | moe | ssm | embed | head | conv
    param_count: float            # total parameters
    flops_per_sample: float       # forward FLOPs for one sample (full seq)
    bnd_bytes_per_sample: float   # boundary (input) activation bytes
    int_bytes_per_sample: float   # intermediate activation bytes
    seq_len: int = 0
    # fraction of params that TP can shard (embeddings/norms are replicated)
    tp_frac: float = 1.0
    # K+V panel bytes per sample (full sequence) — the payload one ring-
    # attention hand-off moves per sp shard; 0 for layers without attention
    kv_bytes_per_sample: float = 0.0
    # MoE bookkeeping (expert params can additionally be expert-sharded)
    n_experts: int = 0
    top_k: int = 0
    expert_param_frac: float = 0.0   # fraction of params living in experts
    # fraction of intermediate activation bytes / forward FLOPs spent in the
    # routed experts — the parts EP shards across the expert group
    expert_act_frac: float = 0.0
    expert_flops_frac: float = 0.0
    # router capacity factor: each expert processes up to
    # ceil(T * top_k / E * capacity_factor) tokens (padding overhead EP pays)
    capacity_factor: float = 1.0

    def active_param_count(self) -> float:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.n_experts > 1:
            dense = self.param_count * (1.0 - self.expert_param_frac)
            expert = self.param_count * self.expert_param_frac
            return dense + expert * self.top_k / self.n_experts
        return self.param_count


# --------------------------------------------------------------------------
# Builders
# --------------------------------------------------------------------------

def _attn_flops(seq: int, d: int, n_heads: int, n_kv: int, causal: bool,
                window: Optional[int] = None) -> float:
    d_head = d // n_heads
    kv_dim = n_kv * d_head
    proj = 2 * seq * (d * d + 2 * d * kv_dim + d * d)      # q, kv, o
    attn_span = seq if window is None else min(seq, window)
    score = 2 * seq * attn_span * d                         # QK^T
    av = 2 * seq * attn_span * d                            # PV
    if causal and window is None:
        score /= 2
        av /= 2
    return proj + score + av


def _attn_act(seq: int, d: int, n_heads: int, n_kv: int,
              store_attn_matrix: bool, window: Optional[int]) -> float:
    """Intermediate activation bytes of one attention block per sample."""
    d_head = d // n_heads
    kv_dim = n_kv * d_head
    toks = seq * BYTES_ACT
    acts = toks * (d            # normed input
                   + d + 2 * kv_dim   # q, k, v
                   + d          # attn context
                   + d)         # o-proj output / residual
    if store_attn_matrix:
        span = seq if window is None else min(seq, window)
        acts += 2 * n_heads * seq * span * BYTES_ACT   # probs + mask/softmax
    else:
        acts += n_heads * seq * 4 * 2                  # flash: m & l stats fp32
    return acts


def _mlp_flops(seq: int, d: int, d_ff: int, gated: bool) -> float:
    mats = 3 if gated else 2
    return 2 * seq * d * d_ff * mats


def _mlp_act(seq: int, d: int, d_ff: int, gated: bool) -> float:
    toks = seq * BYTES_ACT
    if gated:
        return toks * (d + 3 * d_ff + d)   # normed in, gate, up, act, out
    return toks * (d + 2 * d_ff + d)


def dense_layer(name: str, seq: int, d: int, n_heads: int, n_kv: int,
                d_ff: int, *, causal: bool = True, gated: bool = True,
                qkv_bias: bool = False, store_attn_matrix: bool = False,
                window: Optional[int] = None) -> LayerSpec:
    """One pre-norm transformer block (attention + MLP)."""
    d_head = d // n_heads
    kv_dim = n_kv * d_head
    p_attn = d * d + 2 * d * kv_dim + d * d
    if qkv_bias:
        p_attn += d + 2 * kv_dim
    p_mlp = d * d_ff * (3 if gated else 2)
    p_norm = 2 * d
    params = p_attn + p_mlp + p_norm
    flops = _attn_flops(seq, d, n_heads, n_kv, causal, window) + \
        _mlp_flops(seq, d, d_ff, gated)
    bnd = seq * d * BYTES_ACT
    inter = (_attn_act(seq, d, n_heads, n_kv, store_attn_matrix, window) +
             _mlp_act(seq, d, d_ff, gated)) * ACT_CALIBRATION
    return LayerSpec(name=name, kind="attn_mlp", param_count=params,
                     flops_per_sample=flops, bnd_bytes_per_sample=bnd,
                     int_bytes_per_sample=inter, seq_len=seq,
                     tp_frac=(p_attn + p_mlp) / params,
                     kv_bytes_per_sample=2 * seq * kv_dim * BYTES_ACT)


def moe_layer(name: str, seq: int, d: int, n_heads: int, n_kv: int,
              d_ff_expert: int, n_experts: int, top_k: int, *,
              d_ff_shared: int = 0, dense_residual_ff: int = 0,
              causal: bool = True, store_attn_matrix: bool = False,
              window: Optional[int] = None,
              capacity_factor: float = 1.0) -> LayerSpec:
    """Transformer block whose MLP is a top-k routed mixture of experts.

    ``d_ff_shared`` adds always-on shared experts (Kimi-K2 style);
    ``dense_residual_ff`` adds a dense FFN residual branch (Arctic style).
    """
    d_head = d // n_heads
    kv_dim = n_kv * d_head
    p_attn = d * d + 2 * d * kv_dim + d * d
    p_router = d * n_experts
    p_expert = 3 * d * d_ff_expert * n_experts
    p_shared = 3 * d * d_ff_shared if d_ff_shared else 0
    p_dense = 3 * d * dense_residual_ff if dense_residual_ff else 0
    p_norm = 2 * d
    params = p_attn + p_router + p_expert + p_shared + p_dense + p_norm

    flops = _attn_flops(seq, d, n_heads, n_kv, causal, window)
    flops += 2 * seq * d * n_experts                       # router
    f_expert = _mlp_flops(seq, d, d_ff_expert, True) * top_k  # routed experts
    flops += f_expert
    if d_ff_shared:
        flops += _mlp_flops(seq, d, d_ff_shared, True)
    if dense_residual_ff:
        flops += _mlp_flops(seq, d, dense_residual_ff, True)

    bnd = seq * d * BYTES_ACT
    inter = _attn_act(seq, d, n_heads, n_kv, store_attn_matrix, window)
    a_expert = _mlp_act(seq, d, d_ff_expert, True) * top_k
    inter += a_expert
    if d_ff_shared:
        inter += _mlp_act(seq, d, d_ff_shared, True)
    if dense_residual_ff:
        inter += _mlp_act(seq, d, dense_residual_ff, True)
    inter += seq * n_experts * BYTES_ACT                    # router logits
    a_frac = a_expert / inter      # ratio unaffected by ACT_CALIBRATION
    inter *= ACT_CALIBRATION
    return LayerSpec(name=name, kind="moe", param_count=params,
                     flops_per_sample=flops, bnd_bytes_per_sample=bnd,
                     int_bytes_per_sample=inter, seq_len=seq,
                     tp_frac=(p_attn + p_expert + p_shared + p_dense) / params,
                     n_experts=n_experts, top_k=top_k,
                     expert_param_frac=p_expert / params,
                     expert_act_frac=a_frac,
                     expert_flops_frac=f_expert / flops,
                     capacity_factor=capacity_factor,
                     kv_bytes_per_sample=2 * seq * kv_dim * BYTES_ACT)


def ssm_layer(name: str, seq: int, d: int, *, d_state: int = 128,
              expand: int = 2, n_heads: int | None = None,
              d_conv: int = 4, has_mlp_ff: int = 0) -> LayerSpec:
    """Mamba2 (SSD) block; optionally followed by a gated MLP."""
    d_inner = expand * d
    headdim = 64
    nheads = n_heads if n_heads is not None else d_inner // headdim
    n_groups = 1
    d_in_proj = 2 * d_inner + 2 * n_groups * d_state + nheads
    p_in = d * d_in_proj
    p_conv = d_conv * (d_inner + 2 * n_groups * d_state)
    p_dt = nheads * 2                                     # dt bias, A_log
    p_out = d_inner * d
    p_norm = 2 * d + d_inner                              # pre-norm + gated norm
    p_mlp = 3 * d * has_mlp_ff if has_mlp_ff else 0
    params = p_in + p_conv + p_dt + p_out + p_norm + p_mlp

    flops = 2 * seq * d * d_in_proj
    flops += 2 * seq * d_conv * (d_inner + 2 * n_groups * d_state)
    # SSD chunked scan ~ 6 * seq * d_inner * d_state (state update + output)
    flops += 6 * seq * d_inner * d_state
    flops += 2 * seq * d_inner * d
    if has_mlp_ff:
        flops += _mlp_flops(seq, d, has_mlp_ff, True)

    bnd = seq * d * BYTES_ACT
    inter = seq * BYTES_ACT * (d + d_in_proj + 2 * d_inner + d)
    inter += seq * nheads * d_state * BYTES_ACT / 8       # chunk states (1/chunk)
    if has_mlp_ff:
        inter += _mlp_act(seq, d, has_mlp_ff, True)
    inter *= ACT_CALIBRATION
    return LayerSpec(name=name, kind="ssm", param_count=params,
                     flops_per_sample=flops, bnd_bytes_per_sample=bnd,
                     int_bytes_per_sample=inter, seq_len=seq,
                     tp_frac=(p_in + p_out + p_mlp) / params)


def embed_layer(name: str, seq: int, d: int, vocab: int, *,
                tied_head: bool = False) -> LayerSpec:
    params = vocab * d
    flops = 0.0    # gather
    bnd = seq * d * BYTES_ACT
    inter = seq * d * BYTES_ACT * ACT_CALIBRATION
    return LayerSpec(name=name, kind="embed", param_count=params,
                     flops_per_sample=flops, bnd_bytes_per_sample=bnd,
                     int_bytes_per_sample=inter, seq_len=seq, tp_frac=1.0)


def head_layer(name: str, seq: int, d: int, vocab: int) -> LayerSpec:
    params = vocab * d
    flops = 2 * seq * d * vocab
    bnd = seq * d * BYTES_ACT
    inter = seq * vocab * 4 * ACT_CALIBRATION   # logits fp32
    return LayerSpec(name=name, kind="head", param_count=params,
                     flops_per_sample=flops, bnd_bytes_per_sample=bnd,
                     int_bytes_per_sample=inter, seq_len=seq, tp_frac=1.0)


def cross_attn_extra(seq_q: int, seq_kv: int, d: int, n_heads: int,
                     n_kv: int, store_attn_matrix: bool) -> LayerSpec:
    """Extra cross-attention sublayer for encoder-decoder decoders."""
    d_head = d // n_heads
    kv_dim = n_kv * d_head
    params = d * d + 2 * d * kv_dim + d * d + 2 * d
    flops = 2 * seq_q * (d * d + d * d) + 2 * seq_kv * 2 * d * kv_dim
    flops += 2 * seq_q * seq_kv * d * 2
    bnd = seq_q * d * BYTES_ACT
    inter = (seq_q * (2 * d) + seq_kv * 2 * kv_dim) * BYTES_ACT
    if store_attn_matrix:
        inter += n_heads * seq_q * seq_kv * 2 * BYTES_ACT
    inter *= ACT_CALIBRATION
    return LayerSpec(name="cross_attn", kind="attn_mlp", param_count=params,
                     flops_per_sample=flops, bnd_bytes_per_sample=bnd,
                     int_bytes_per_sample=inter, seq_len=seq_q,
                     tp_frac=(params - 2 * d) / params)


def merge(name: str, *specs: LayerSpec) -> LayerSpec:
    """Fuse sublayer specs into one search-granularity layer."""
    return LayerSpec(
        name=name,
        kind=specs[0].kind,
        param_count=sum(s.param_count for s in specs),
        flops_per_sample=sum(s.flops_per_sample for s in specs),
        bnd_bytes_per_sample=specs[0].bnd_bytes_per_sample,
        int_bytes_per_sample=sum(s.int_bytes_per_sample for s in specs),
        seq_len=specs[0].seq_len,
        tp_frac=(sum(s.tp_frac * s.param_count for s in specs)
                 / max(1.0, sum(s.param_count for s in specs))),
        kv_bytes_per_sample=sum(s.kv_bytes_per_sample for s in specs),
        n_experts=max(s.n_experts for s in specs),
        top_k=max(s.top_k for s in specs),
        expert_param_frac=(sum(s.expert_param_frac * s.param_count for s in specs)
                           / max(1.0, sum(s.param_count for s in specs))),
        expert_act_frac=(sum(s.expert_act_frac * s.int_bytes_per_sample
                             for s in specs)
                         / max(1.0, sum(s.int_bytes_per_sample for s in specs))),
        expert_flops_frac=(sum(s.expert_flops_frac * s.flops_per_sample
                               for s in specs)
                           / max(1.0, sum(s.flops_per_sample for s in specs))),
        capacity_factor=max(s.capacity_factor for s in specs),
    )


def total_params(specs: List[LayerSpec]) -> float:
    return sum(s.param_count for s in specs)


def total_activation_bytes(specs: List[LayerSpec]) -> float:
    return sum(s.bnd_bytes_per_sample + s.int_bytes_per_sample for s in specs)
