"""Per-layer hybrid parallelism strategies (decision-tree leaves).

A strategy for one layer, given a device group of size ``n`` (the devices of
one pipeline stage), is an *ordered* sequence of ``(paradigm, degree)`` levels
— the path of one decision tree in Fig. 3 — plus the CKPT bit.  Order matters
because outer levels communicate over slower/wider device groupings (the tree
captures the bandwidth hierarchy); e.g. 2-way DP over 2-way TP places TP on
the innermost (fastest) links.

Paradigms: ``dp`` (data parallel), ``sdp`` (sharded data parallel / ZeRO-3),
``tp`` (tensor parallel), ``sp`` (sequence parallel — ring attention over a
sequence-sharded axis; opt-in, see ``SP_PARADIGMS``), ``ep`` (expert
parallel — MoE experts sharded over an expert axis with all-to-all
dispatch/combine; opt-in, see ``EP_PARADIGMS``).  PP is handled one level up
(it partitions the model into stages before per-layer search — Takeaway #1).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Sequence, Tuple

DP = "dp"
SDP = "sdp"
TP = "tp"
SP = "sp"
PARADIGMS = (DP, SDP, TP)
# SP widens the tree with a sequence-parallel branch.  It is opt-in (the
# paper's 8-device leaf counts that tests pin are defined over DP/SDP/TP);
# ``OptimizerConfig(use_sp=True)`` passes this tuple through instead.
SP_PARADIGMS = (DP, SDP, TP, SP)
EP = "ep"
# EP widens the tree further with an expert-parallel branch for MoE layers.
# Also opt-in: ``OptimizerConfig(use_ep=True)`` appends EP to whatever
# paradigm tuple is otherwise in effect (so EP composes with use_sp).
EP_PARADIGMS = (DP, SDP, TP, SP, EP)


@dataclasses.dataclass(frozen=True)
class Strategy:
    """One decision-tree leaf: ordered parallelism levels + ckpt flag."""

    levels: Tuple[Tuple[str, int], ...]   # ((paradigm, degree), ...) outer→inner
    ckpt: bool = False

    # ---- derived degrees -------------------------------------------------
    def degree(self, paradigm: str) -> int:
        d = 1
        for p, k in self.levels:
            if p == paradigm:
                d *= k
        return d

    @property
    def dp(self) -> int:
        return self.degree(DP)

    @property
    def sdp(self) -> int:
        return self.degree(SDP)

    @property
    def tp(self) -> int:
        return self.degree(TP)

    @property
    def sp(self) -> int:
        return self.degree(SP)

    @property
    def ep(self) -> int:
        return self.degree(EP)

    @property
    def total(self) -> int:
        d = 1
        for _, k in self.levels:
            d *= k
        return d

    @property
    def data_degree(self) -> int:
        """Replication factor of the batch dimension (DP and SDP both split data)."""
        return self.dp * self.sdp

    def with_ckpt(self, ckpt: bool = True) -> "Strategy":
        return dataclasses.replace(self, ckpt=ckpt)

    def name(self) -> str:
        parts = [f"{p}{k}" for p, k in self.levels] or ["serial"]
        if self.ckpt:
            parts.append("ckpt")
        return "-".join(parts)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.name()

    def to_json(self) -> Dict:
        return {"levels": [list(l) for l in self.levels], "ckpt": self.ckpt}

    @staticmethod
    def from_json(d: Dict) -> "Strategy":
        return Strategy(tuple((p, int(k)) for p, k in d["levels"]), bool(d["ckpt"]))


# --------------------------------------------------------------------------
# strategy-set identity (memo-cache keys)
# --------------------------------------------------------------------------

_SET_IDS: Dict[Tuple[Strategy, ...], int] = {}


def strategy_set_id(strategies: Sequence[Strategy]) -> int:
    """Small interned token identifying an ordered strategy list.

    Equal lists (same strategies, same order) always map to the same token,
    so search caches can key on one int instead of re-hashing the whole
    list on every lookup.  The intern table is tiny: one entry per distinct
    search space actually constructed in the process.
    """
    key = tuple(strategies)
    sid = _SET_IDS.get(key)
    if sid is None:
        sid = len(_SET_IDS)
        _SET_IDS[key] = sid
    return sid


def _factorizations(n: int, max_parts: int) -> Iterable[Tuple[int, ...]]:
    """Ordered compositions of ``n`` into ≤ max_parts factors, each ≥ 2.

    Degrees are powers of two by the decision-tree rule (non-leaf node degree
    ∈ {2,4,8,...}); since ``n`` itself is a power of two, any factorization
    into integers ≥2 automatically uses powers of two.
    """
    if n == 1:
        yield ()
        return

    def rec(rem: int, parts: Tuple[int, ...]):
        if rem == 1:
            yield parts
            return
        if len(parts) == max_parts:
            return
        f = 2
        while f <= rem:
            if rem % f == 0:
                yield from rec(rem // f, parts + (f,))
            f *= 2

    yield from rec(n, ())


def enumerate_strategies(
    group_size: int,
    *,
    paradigms: Sequence[str] = PARADIGMS,
    allow_ckpt: bool = True,
    prune_dp_sdp: bool = True,
) -> List[Strategy]:
    """All decision-tree leaves for one stage's device group.

    Implements the construction rules of §III-B:
      * tree height = number of distinct paradigms used (each used once),
      * node degrees are powers of two multiplying to ``group_size``,
      * order matters (bandwidth hierarchy),
      * each tree optionally applies CKPT (S_i vs S_i'),
      * Takeaway #3 prunes any tree containing both DP and SDP.
    """
    out: List[Strategy] = []
    seen = set()
    for factors in _factorizations(group_size, max_parts=len(paradigms)):
        for assign in itertools.permutations(paradigms, len(factors)):
            if prune_dp_sdp and DP in assign and SDP in assign:
                continue
            levels = tuple(zip(assign, factors))
            if levels in seen:
                continue
            seen.add(levels)
            out.append(Strategy(levels, ckpt=False))
            if allow_ckpt:
                out.append(Strategy(levels, ckpt=True))
    # Deterministic ordering: by (#levels, name) for reproducible DP search.
    out.sort(key=lambda s: (len(s.levels), s.name()))
    return out
