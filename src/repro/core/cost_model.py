"""Cost estimator (paper §V + Appendix C/D).

Estimates, for one layer under one hybrid strategy:
  * ``O_f``  — forward activation memory per device,
  * ``O_b``  — extra backward peak memory per device (CKPT recompute),
  * ``O_ms`` — model-state memory per device (params + grads + optimizer),
  * ``c``    — execution time (fwd + bwd, incl. communication, the CKPT
               recompute forward, and the computation/communication
               *overlap slowdown* the paper emphasizes).

Two time variants are produced: ``time`` (last micro-batch — includes DP/SDP
gradient synchronization) and ``time_nosync`` (earlier micro-batches), used
by the 1F1B pipeline cost Eq. 9.

Communication volume factors follow §III-A2:
  DP   all-reduce(grads)            : 2 (N-1)/N * bytes
  SDP  2x all-gather + reduce-scatter: 3 (N-1)/N * bytes  (1.5x DP)
  TP   all-reduce(activations) fwd+bwd
  MoE  all-to-all dispatch/combine when experts are sharded over TP level
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .hardware import ClusterSpec
from .layerspec import LayerSpec
from .strategy import DP, EP, SDP, SP, TP, Strategy

# which profiled collective prices which paradigm's traffic
_PARADIGM_COLLECTIVE = {
    TP: "all_reduce",        # activation all-reduce (fwd + bwd)
    DP: "all_reduce",        # gradient all-reduce
    SDP: "all_gather",       # param all-gather (reduce-scatter priced apart)
    SP: "ppermute",          # ring-attention K/V panel hand-off
    EP: "all_to_all",        # MoE token dispatch/combine (no profile kind is
                             # recorded for it, so collective_coeffs always
                             # returns the analytic (0.0, bandwidth) pair)
}

# finite poison for (layer, strategy) pairs SP cannot execute (sequence not
# divisible, recurrent kind, no sequence axis).  Kept finite — a true inf
# would turn the DP objective's ``t_ns + (t_s - t_ns)/m`` into NaN — but
# large enough that any plan containing one loses to every real plan.
_SP_INVALID_TIME = 1e30


def _sp_applicable(spec: LayerSpec, sp: int) -> bool:
    """Can this layer run sequence-sharded at degree ``sp``?

    SSM layers carry a sequential state scan that the ring hand-off does
    not implement, and a layer without a sequence axis (or one ``sp``
    does not divide) cannot shard tokens evenly."""
    if sp <= 1:
        return True
    return (spec.seq_len > 0 and spec.seq_len % sp == 0
            and spec.kind != "ssm")


def _ep_applicable(spec: LayerSpec, ep: int) -> bool:
    """Can this layer run expert-sharded at degree ``ep``?

    Only MoE layers carry experts, and the expert axis must divide the
    expert count evenly (ragged expert placement is not modeled)."""
    if ep <= 1:
        return True
    return spec.n_experts > 1 and spec.n_experts % ep == 0


# --------------------------------------------------------------------------
# pipeline-schedule time terms (paper Eq. 5/9, generalized with interleaved
# virtual stages — DESIGN.md §5)
# --------------------------------------------------------------------------

def _drain_divisor(vpp: int, schedule: str) -> float:
    """By how much a schedule shrinks the non-critical drain/bubble term.

    Interleaving splits the drain into ``V×`` smaller chunks; ZB-H1 fills
    two thirds of the flush bubble with deferred W ticks under the
    unit-tick assumption ``T_F = T_B = T_W`` (forward : activation-grad :
    weight-grad = 1 : 1 : 1 — the compiled program's bubble is exactly
    ``P - 1`` of ``3(P-1)`` 1F1B-equivalent unit ticks, see
    ``runtime/schedules.py::_compile_zb_h1``)."""
    return 3.0 * vpp if schedule == "zb-h1" else float(vpp)


def bubble_fraction(n_stages: int, n_micro: int, vpp: int = 1,
                    schedule: str = "1f1b") -> float:
    """Pipeline fill/drain overhead relative to the ideal per-stage work.

    ``(P - 1) / (m · V)`` for the flush family — ``vpp = 1`` recovers the
    classic ``(P - 1) / m`` of GPipe / 1F1B; interleaving V virtual
    chunks per device shrinks the bubble by ``V×``.  ``zb-h1`` fills the
    remaining bubble with deferred weight-gradient ticks, leaving
    ``(P - 1) / (3·m)`` — one third of 1F1B's (near zero as ``m`` grows).

    Args:
      n_stages: pipeline depth ``P``.
      n_micro: micro-batches per iteration ``m``.
      vpp: virtual chunks per stage ``V`` (only > 1 for interleaved).
      schedule: schedule name; only ``"zb-h1"`` changes the formula.
    """
    return (n_stages - 1) / (n_micro * _drain_divisor(vpp, schedule))


def pipeline_iter_time(stage_times: Sequence[float],
                       stage_times_nosync: Sequence[float],
                       n_micro: int, vpp: int = 1,
                       schedule: str = "1f1b") -> float:
    """Eq. 9 generalized over virtual-chunk degree ``V = vpp`` and the
    zero-bubble backward split.

    ``V = 1``: ``(m-1) · max(C_nosync) + Σ C_sync`` — the slowest stage
    paces the ``m-1`` steady-state micro-batches and the last micro-batch
    drains through every stage.

    ``V > 1``: the drain traverses ``P·V`` *chunks* of ``1/V`` a stage's
    work each, so the non-critical stages' drain contribution divides by
    ``V`` (the critical stage still runs its full per-micro-batch work):
    ``(m-1) · max(C_nosync) + max(C_sync) + (Σ C_sync - max(C_sync)) / V``.
    For homogeneous stages of cost ``t`` this is ``m·t + (P-1)·t/V`` —
    exactly the ``(P-1)/(m·V)`` bubble of :func:`bubble_fraction`.

    ``schedule="zb-h1"``: deferred W ticks refill two thirds of the
    flush drain (unit-tick model), so the non-critical term divides by 3
    instead — homogeneous stages cost ``m·t + (P-1)·t/3``.

    Args:
      stage_times: per-stage cost incl. gradient sync (last micro-batch).
      stage_times_nosync: per-stage cost without DP/SDP gradient sync.
      n_micro: micro-batches ``m``.
      vpp: virtual-chunk degree ``V``.
      schedule: schedule name; only ``"zb-h1"`` changes the formula.

    Returns:
      Modeled seconds per training iteration.
    """
    mx = max(stage_times)
    return ((n_micro - 1) * max(stage_times_nosync)
            + mx + (sum(stage_times) - mx) / _drain_divisor(vpp, schedule))


@dataclasses.dataclass(frozen=True)
class LayerCosts:
    time: float           # seconds, fwd+bwd incl. grad sync (last micro-batch)
    time_nosync: float    # seconds, fwd+bwd without DP/SDP grad sync
    mem_f: float          # O_f bytes per device
    mem_b: float          # O_b bytes per device
    mem_ms: float         # O_ms bytes per device
    time_fwd: float = 0.0


@dataclasses.dataclass(frozen=True)
class CostTables:
    """Batched per-(layer, strategy) cost arrays, all shaped (L, S).

    Produced by :meth:`CostModel.layer_cost_tables` with NumPy broadcasting —
    numerically identical to calling :meth:`CostModel.layer_costs` /
    :meth:`CostModel.reshard_cost` for every pair, but one vectorized pass
    instead of ``L x S`` Python calls (the strategy-search hot path).
    """

    time_sync: np.ndarray     # LayerCosts.time
    time_nosync: np.ndarray   # LayerCosts.time_nosync
    time_fwd: np.ndarray      # LayerCosts.time_fwd
    mem_f: np.ndarray
    mem_b: np.ndarray
    mem_ms: np.ndarray
    reshard: np.ndarray       # CostModel.reshard_cost per (layer, strategy)

    def rows(self, a: int, b: int) -> "CostTables":
        """Zero-copy view of the layer range [a, b) — per-layer costs do not
        depend on neighbouring layers, so full-model tables slice freely."""
        return CostTables(*(getattr(self, f.name)[a:b]
                            for f in dataclasses.fields(self)))


@dataclasses.dataclass(frozen=True)
class CostModelConfig:
    bytes_per_param_states: float = 16.0  # fp16 p + fp16 g + fp32 (p, m, v)
    bytes_per_param: float = 2.0          # live copy used in compute
    act_bytes: float = 2.0
    mfu: float = 0.45                     # achieved fraction of peak compute
    # TP-replicated activation bytes per layer = this many boundary-sized
    # tensors (Megatron keeps LN inputs + residuals replicated — a fixed
    # ~2 x (seq x hidden), NOT a fraction of the intermediate, which would
    # wildly overcharge attention-matrix-heavy layers)
    tp_act_replicated_bnd: float = 2.0
    # when True expert weights are sharded along the TP level (expert
    # parallelism) and token dispatch uses all-to-all
    moe_expert_parallel_tp: bool = True
    # physical per-device batch floor: strategies whose DP/SDP span leaves
    # fewer than this many samples per device are marked infeasible (poison
    # time, finite memory).  The paper's linear model admits fractional
    # b_dev — 8 devices "sharing" one sequence — which data parallelism
    # cannot execute; with the floor at 1.0, sequence parallelism becomes
    # the only axis that splits a single long sequence (the long-context
    # regime, docs/architecture.md §SP).  0.0 (default) keeps the
    # unconstrained paper model, bit-identical to prior searches.
    min_samples_per_device: float = 0.0
    # expert-imbalance slowdown fed into the workload-balance objective:
    # the hot EP rank is modeled as carrying (1 + ep_imbalance * (ep-1)/ep)x
    # its fair token share (routing skew grows with the expert-group size),
    # inflating both the expert compute and the all-to-all payload of
    # ep > 1 strategies.  0.0 (default) models perfectly balanced routing —
    # bit-identical to searches that never price EP.
    ep_imbalance: float = 0.0


class CostModel:
    def __init__(self, cluster: ClusterSpec,
                 config: Optional[CostModelConfig] = None,
                 profiled_times: Optional[dict] = None):
        self.cluster = cluster
        self.cfg = config or CostModelConfig()
        # {layer name: measured forward seconds/sample} — paper §V profiling
        self.profiled_times = profiled_times or {}
        # (kind, group_size) -> (latency_s, bandwidth); tiny, but sits on
        # the per-(layer, strategy) hot path.  Part of the clear_cache()
        # contract: GalvatronOptimizer.clear_cache() calls clear_cache()
        # here too so swapping cluster profiles under a live instance
        # cannot serve stale coefficients.
        self._coeff_cache: Dict[Tuple[str, int], Tuple[float, float]] = {}

    def clear_cache(self) -> None:
        """Drop the collective-coefficient memo (profiled-constants cache)."""
        self._coeff_cache.clear()

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _group_coeffs(self, kind: str, group_size: int) -> Tuple[float, float]:
        """Memoized ``ClusterSpec.collective_coeffs`` (``group_size == -1``
        selects the pipeline hand-off pair, ``ClusterSpec.p2p_coeffs``)."""
        key = (kind, group_size)
        out = self._coeff_cache.get(key)
        if out is None:
            if group_size == -1:
                out = self.cluster.p2p_coeffs()
            else:
                out = self.cluster.collective_coeffs(kind, group_size)
            self._coeff_cache[key] = out
        return out

    def _level_span(self, strat: Strategy, paradigm: str) -> int:
        """Device-group size a paradigm's collective spans (1 if absent).

        Levels are ordered outer→inner; a level's collective runs between
        device blocks of size = product of inner degrees, so its *span* is
        its degree times everything inside it.  Outer levels straddle slower
        boundaries on hierarchical clusters.
        """
        span = 1
        for p, k in reversed(strat.levels):
            span *= k
            if p == paradigm:
                return span
        return 1

    def _level_coeffs(self, strat: Strategy, paradigm: str,
                      kind: Optional[str] = None) -> Tuple[float, float]:
        """(latency_s, bandwidth) for a paradigm's collective under this
        strategy — profiled when the cluster carries measurements for
        ``kind`` and the group fits in an island, analytic otherwise."""
        return self._group_coeffs(kind or _PARADIGM_COLLECTIVE[paradigm],
                                  self._level_span(strat, paradigm))

    def _level_bandwidth(self, strat: Strategy, paradigm: str) -> float:
        """Bandwidth of the device group a paradigm's collective spans."""
        return self._level_coeffs(strat, paradigm)[1]

    @staticmethod
    def _ring_factor(n: int) -> float:
        return (n - 1) / n if n > 1 else 0.0

    def _overlap(self, comp: float, comm: float) -> float:
        """Overlapped comp & comm with the paper's contention slowdown."""
        if comp <= 0.0:
            return comm
        if comm <= 0.0:
            return comp
        sd = self.cluster.device.overlap_slowdown
        return max(comp * sd, comm * sd)

    # ------------------------------------------------------------------
    # main entry
    # ------------------------------------------------------------------
    def layer_costs(self, spec: LayerSpec, strat: Strategy,
                    micro_batch_size: float, *,
                    inflight: float = 1) -> LayerCosts:
        cfg = self.cfg
        dev = self.cluster.device
        dp, sdp, tp, sp = strat.dp, strat.sdp, strat.tp, strat.sp
        ep = strat.ep
        data_deg = dp * sdp
        b_dev = micro_batch_size / data_deg
        # hot-rank routing skew (1.0 when ep == 1 or imbalance not modeled)
        ep_imb = 1.0 + cfg.ep_imbalance * self._ring_factor(ep)

        # ---- memory: model states -------------------------------------
        p_tp = spec.param_count * spec.tp_frac
        p_rep = spec.param_count * (1.0 - spec.tp_frac)
        params_dev = p_tp / tp + p_rep          # after TP sharding
        # EP shards the expert slab (a subset of the TP-shardable params)
        # ep ways; everything else is replicated across the expert group
        p_exp_dev = spec.param_count * spec.expert_param_frac / tp
        if ep > 1:
            params_dev = params_dev - p_exp_dev + p_exp_dev / ep
        ms = cfg.bytes_per_param_states * params_dev / sdp

        # ---- memory: activations ---------------------------------------
        # SP shards the sequence axis: every activation tensor holds S/sp
        # tokens per device — the workload-balance lever long context needs
        bnd_dev = spec.bnd_bytes_per_sample * b_dev / sp
        int_dev = spec.int_bytes_per_sample * b_dev / sp / tp
        if ep > 1:
            # the expert group also shards tokens (DP-like for the dense
            # part); routed-expert activations are capacity-padded
            int_exp = (spec.int_bytes_per_sample * spec.expert_act_frac
                       * b_dev / sp / tp)
            bnd_dev = bnd_dev / ep
            int_dev = ((int_dev - int_exp) / ep
                       + int_exp * spec.capacity_factor / ep)
        if tp > 1:
            int_dev += cfg.tp_act_replicated_bnd * bnd_dev
        if strat.ckpt:
            mem_f = bnd_dev * inflight
            mem_b = int_dev
        else:
            mem_f = (bnd_dev + int_dev) * inflight
            mem_b = 0.0

        # ---- compute time ----------------------------------------------
        if spec.name in self.profiled_times:
            # profiled per-sample forward time (paper: batch x per-sample)
            comp_fwd = self.profiled_times[spec.name] * b_dev / sp / tp
        else:
            flops_dev = spec.flops_per_sample * b_dev / sp / tp
            comp_fwd = flops_dev / (dev.peak_flops * cfg.mfu)
        if ep > 1:
            # expert group shards tokens; the routed-expert share pays the
            # capacity padding and any modeled hot-rank imbalance
            ep_scale = ((1.0 - spec.expert_flops_frac)
                        + spec.expert_flops_frac * spec.capacity_factor
                        * ep_imb)
            comp_fwd = comp_fwd * ep_scale / ep
        comp_bwd = 2.0 * comp_fwd
        recompute = comp_fwd if strat.ckpt else 0.0

        # ---- communication ---------------------------------------------
        # Each collective is charged latency + bytes/bandwidth; with no
        # profiles attached the latency is exactly 0.0 and the bandwidth the
        # analytic one, so the pre-profiling numbers are reproduced ulp-for-
        # ulp (0.0 + x == x in IEEE arithmetic).
        # TP: all-reduce of hidden states, twice per layer direction
        tp_time_fwd = tp_time_bwd = 0.0
        if tp > 1:
            lat, bw = self._level_coeffs(strat, TP)
            msg = bnd_dev        # per-device hidden states (sp- and ep-sharded)
            ar = lat + 2.0 * self._ring_factor(tp) * msg / bw
            tp_time_fwd = 2.0 * ar
            tp_time_bwd = 2.0 * ar
            if spec.n_experts > 1 and cfg.moe_expert_parallel_tp:
                # token dispatch + combine all-to-all (fwd and bwd)
                a2a = lat + 2.0 * self._ring_factor(tp) / tp * msg * spec.top_k / bw
                tp_time_fwd += 2.0 * a2a
                tp_time_bwd += 2.0 * a2a

        # SDP: param all-gather before fwd and before bwd (per micro-batch),
        # grad reduce-scatter with the last micro-batch.
        sdp_ag_fwd = sdp_ag_bwd = sdp_rs = 0.0
        if sdp > 1:
            lat_ag, bw_ag = self._level_coeffs(strat, SDP, "all_gather")
            lat_rs, bw_rs = self._level_coeffs(strat, SDP, "reduce_scatter")
            pbytes = cfg.bytes_per_param * params_dev  # already TP-sharded
            sdp_ag_fwd = lat_ag + self._ring_factor(sdp) * pbytes / bw_ag
            sdp_ag_bwd = lat_ag + self._ring_factor(sdp) * pbytes / bw_ag
            sdp_rs = lat_rs + self._ring_factor(sdp) * pbytes / bw_rs

        # DP: grad all-reduce with the last micro-batch only.  Per the
        # paper's Takeaway-#3 accounting, DP synchronizes the FULL
        # (TP-sharded) gradient bytes — the all-reduce happens on unsharded
        # gradients before any ZeRO reduce-scatter, so no /sdp here.
        dp_ar = 0.0
        if dp > 1:
            lat, bw = self._level_coeffs(strat, DP)
            gbytes = cfg.bytes_per_param * params_dev
            dp_ar = lat + 2.0 * self._ring_factor(dp) * gbytes / bw

        # SP: ring attention rotates the local K/V panel sp−1 times per
        # forward (priced from the profiled ppermute pair); backward runs
        # the ring again carrying dK/dV accumulators (~2x the traffic).
        # Params are replicated across the sp group, so the last micro-
        # batch also all-reduces gradients over it (DP-like term).
        sp_ring_fwd = sp_ring_bwd = sp_ar = 0.0
        if sp > 1:
            lat_pp, bw_pp = self._level_coeffs(strat, SP)
            panel = spec.kv_bytes_per_sample * b_dev / sp
            sp_ring_fwd = (sp - 1) * (lat_pp + panel / bw_pp)
            sp_ring_bwd = 2.0 * sp_ring_fwd
            lat_sar, bw_sar = self._level_coeffs(strat, SP, "all_reduce")
            gbytes = cfg.bytes_per_param * params_dev
            sp_ar = lat_sar + 2.0 * self._ring_factor(sp) * gbytes / bw_sar

        # EP: all-to-all token dispatch + combine across the expert group
        # (fwd, and again for the gradients on the backward), plus a
        # DP-like gradient all-reduce of the replicated (non-expert)
        # params with the last micro-batch.
        ep_a2a = ep_ar = 0.0
        if ep > 1:
            lat_ep, bw_ep = self._level_coeffs(strat, EP)
            msg_ep = (spec.bnd_bytes_per_sample * b_dev / sp / ep
                      * spec.top_k * spec.capacity_factor * ep_imb)
            ep_a2a = 2.0 * (lat_ep + self._ring_factor(ep) * msg_ep / bw_ep)
            lat_ear, bw_ear = self._level_coeffs(strat, EP, "all_reduce")
            g_rep = cfg.bytes_per_param * (params_dev - p_exp_dev / ep)
            ep_ar = lat_ear + 2.0 * self._ring_factor(ep) * g_rep / bw_ear

        # ---- assemble (overlap model, §V) -------------------------------
        # forward: TP all-reduce and the EP all-to-all block; SDP gather
        # and the SP ring hand-off overlap with compute (the permute is
        # issued before the round's kernel — see kernels/ring_attention.py)
        fwd = (self._overlap(comp_fwd, sdp_ag_fwd + sp_ring_fwd)
               + tp_time_fwd + ep_a2a)
        # recompute forward (CKPT) repeats TP collectives + the SP ring too
        re_fwd = (self._overlap(recompute, sp_ring_fwd)
                  + tp_time_fwd + ep_a2a) if strat.ckpt else 0.0
        # backward: DP/SDP gradient comm overlaps with compute
        bwd_nosync = (self._overlap(comp_bwd, sdp_ag_bwd + sp_ring_bwd)
                      + tp_time_bwd + ep_a2a)
        bwd_sync = (self._overlap(
            comp_bwd,
            sdp_ag_bwd + sp_ring_bwd + sdp_rs + dp_ar + sp_ar + ep_ar)
            + tp_time_bwd + ep_a2a)

        if not _sp_applicable(spec, sp) or not _ep_applicable(spec, ep) or (
                cfg.min_samples_per_device > 0.0
                and b_dev < cfg.min_samples_per_device):
            # memory stays finite (the DP's bin weights must stay sane);
            # the poison time keeps any such pair out of optimal plans
            return LayerCosts(time=_SP_INVALID_TIME,
                              time_nosync=_SP_INVALID_TIME,
                              mem_f=mem_f, mem_b=mem_b, mem_ms=ms,
                              time_fwd=_SP_INVALID_TIME)

        return LayerCosts(
            time=fwd + re_fwd + bwd_sync,
            time_nosync=fwd + re_fwd + bwd_nosync,
            mem_f=mem_f,
            mem_b=mem_b,
            mem_ms=ms,
            time_fwd=fwd,
        )

    # ------------------------------------------------------------------
    # batched entry — whole (L, S) cost tables in one NumPy pass
    # ------------------------------------------------------------------
    def layer_cost_tables(self, specs: Sequence[LayerSpec],
                          strategies: Sequence[Strategy],
                          micro_batch_size: float, *,
                          inflight: float = 1) -> CostTables:
        """Vectorized equivalent of ``layer_costs`` + ``reshard_cost`` over
        every (layer, strategy) pair.

        Broadcasts (L,)-shaped layer workload vectors against (S,)-shaped
        strategy degree/bandwidth vectors; every arithmetic step mirrors the
        scalar path operation-for-operation so results agree to the last ulp
        (the memo-cache tests assert byte-identical search output).
        """
        cfg = self.cfg
        dev = self.cluster.device
        L, S = len(specs), len(strategies)
        if L == 0 or S == 0:
            z = np.zeros((L, S))
            return CostTables(*(z.copy() for _ in range(7)))

        # ---- per-strategy vectors (S,) --------------------------------
        dp = np.array([s.dp for s in strategies], float)
        sdp = np.array([s.sdp for s in strategies], float)
        tp = np.array([s.tp for s in strategies], float)
        spd = np.array([s.sp for s in strategies], float)
        epd = np.array([s.ep for s in strategies], float)
        total = np.array([s.total for s in strategies], float)
        ckpt = np.array([s.ckpt for s in strategies], bool)
        co = lambda pairs, i: np.array([p[i] for p in pairs])
        c_tp = [self._level_coeffs(s, TP) for s in strategies]
        c_ag = [self._level_coeffs(s, SDP, "all_gather") for s in strategies]
        c_rs = [self._level_coeffs(s, SDP, "reduce_scatter") for s in strategies]
        c_dp = [self._level_coeffs(s, DP) for s in strategies]
        c_sp = [self._level_coeffs(s, SP) for s in strategies]
        c_sar = [self._level_coeffs(s, SP, "all_reduce") for s in strategies]
        c_ep = [self._level_coeffs(s, EP) for s in strategies]
        c_ear = [self._level_coeffs(s, EP, "all_reduce") for s in strategies]
        c_tot = [self._group_coeffs("all_gather", int(s.total))
                 for s in strategies]
        bw_tp, bw_ag, bw_rs = co(c_tp, 1), co(c_ag, 1), co(c_rs, 1)
        bw_dp, bw_tot = co(c_dp, 1), co(c_tot, 1)
        bw_sp, bw_sar = co(c_sp, 1), co(c_sar, 1)
        bw_ep, bw_ear = co(c_ep, 1), co(c_ear, 1)
        # latency enters only where the paradigm is actually active — the
        # scalar path guards each comm term behind ``if deg > 1``
        lat_tp = np.where(tp > 1, co(c_tp, 0), 0.0)
        lat_ag = np.where(sdp > 1, co(c_ag, 0), 0.0)
        lat_rs = np.where(sdp > 1, co(c_rs, 0), 0.0)
        lat_dp = np.where(dp > 1, co(c_dp, 0), 0.0)
        lat_sp = np.where(spd > 1, co(c_sp, 0), 0.0)
        lat_sar = np.where(spd > 1, co(c_sar, 0), 0.0)
        lat_ep = np.where(epd > 1, co(c_ep, 0), 0.0)
        lat_ear = np.where(epd > 1, co(c_ear, 0), 0.0)
        lat_tot = np.where(total > 1, co(c_tot, 0), 0.0)
        ring_tp = np.where(tp > 1, (tp - 1) / tp, 0.0)
        ring_sdp = np.where(sdp > 1, (sdp - 1) / sdp, 0.0)
        ring_dp = np.where(dp > 1, (dp - 1) / dp, 0.0)
        ring_spd = np.where(spd > 1, (spd - 1) / spd, 0.0)
        ring_epd = np.where(epd > 1, (epd - 1) / epd, 0.0)
        ring_tot = np.where(total > 1, (total - 1) / total, 0.0)
        # hot-rank routing skew, exactly the scalar path's ``ep_imb``
        ep_imb = 1.0 + cfg.ep_imbalance * ring_epd

        # ---- per-layer vectors (L, 1) ---------------------------------
        col = lambda v: np.asarray(v, float).reshape(L, 1)
        param_count = col([sp.param_count for sp in specs])
        tp_frac = col([sp.tp_frac for sp in specs])
        bnd = col([sp.bnd_bytes_per_sample for sp in specs])
        intb = col([sp.int_bytes_per_sample for sp in specs])
        flops = col([sp.flops_per_sample for sp in specs])
        top_k = col([sp.top_k for sp in specs])
        moe = np.array([sp.n_experts > 1 for sp in specs]).reshape(L, 1)
        n_exp = col([sp.n_experts for sp in specs])
        epf = col([sp.expert_param_frac for sp in specs])
        eaf = col([sp.expert_act_frac for sp in specs])
        eff = col([sp.expert_flops_frac for sp in specs])
        cfac = col([sp.capacity_factor for sp in specs])
        kvb = col([sp.kv_bytes_per_sample for sp in specs])
        seq_l = col([sp.seq_len for sp in specs])
        sp_kind_ok = np.array([sp.kind != "ssm"
                               for sp in specs]).reshape(L, 1)
        profiled = col([self.profiled_times.get(sp.name, np.nan)
                        for sp in specs])

        # ---- memory: model states -------------------------------------
        b_dev = micro_batch_size / (dp * sdp)             # (S,)
        params_dev = param_count * tp_frac / tp + param_count * (1.0 - tp_frac)
        p_exp_dev = param_count * epf / tp
        params_dev = np.where(epd > 1,
                              params_dev - p_exp_dev + p_exp_dev / epd,
                              params_dev)
        ms = cfg.bytes_per_param_states * params_dev / sdp

        # ---- memory: activations --------------------------------------
        bnd_dev = bnd * b_dev / spd
        int_dev = intb * b_dev / spd / tp
        int_exp = intb * eaf * b_dev / spd / tp
        bnd_dev = np.where(epd > 1, bnd_dev / epd, bnd_dev)
        int_dev = np.where(epd > 1,
                           (int_dev - int_exp) / epd + int_exp * cfac / epd,
                           int_dev)
        int_dev = np.where(tp > 1,
                           int_dev + cfg.tp_act_replicated_bnd * bnd_dev,
                           int_dev)
        mem_f = np.where(ckpt, bnd_dev * inflight, (bnd_dev + int_dev) * inflight)
        mem_b = np.where(ckpt, int_dev, 0.0)

        # ---- compute time ---------------------------------------------
        comp_fwd = np.where(np.isnan(profiled),
                            (flops * b_dev / spd / tp) / (dev.peak_flops * cfg.mfu),
                            np.nan_to_num(profiled) * b_dev / spd / tp)
        ep_scale = (1.0 - eff) + eff * cfac * ep_imb
        comp_fwd = np.where(epd > 1, comp_fwd * ep_scale / epd, comp_fwd)
        comp_bwd = 2.0 * comp_fwd
        recompute = np.where(ckpt, comp_fwd, 0.0)

        # ---- communication --------------------------------------------
        # latency + bytes/bandwidth per collective, mirroring the scalar
        # path; lat_* is exactly 0.0 wherever a paradigm is inactive or no
        # profile is attached, so 0.0 + x keeps unprofiled results ulp-equal
        ar = lat_tp + 2.0 * ring_tp * bnd_dev / bw_tp
        tp_time = 2.0 * ar                                # fwd == bwd
        if cfg.moe_expert_parallel_tp:
            a2a = lat_tp + 2.0 * ring_tp / tp * bnd_dev * top_k / bw_tp
            tp_time = np.where(moe, tp_time + 2.0 * a2a, tp_time)

        pbytes = cfg.bytes_per_param * params_dev
        sdp_ag = lat_ag + ring_sdp * pbytes / bw_ag       # ag_fwd == ag_bwd
        sdp_rs = lat_rs + ring_sdp * pbytes / bw_rs
        dp_ar = lat_dp + 2.0 * ring_dp * pbytes / bw_dp

        # SP: sp−1 ppermute rounds of the local K/V panel (fwd), 2x on the
        # backward ring, plus the sp-group gradient all-reduce — mirrors
        # the scalar path's ``if sp > 1`` block
        panel = kvb * b_dev / spd
        sp_ring_fwd = np.where(spd > 1,
                               (spd - 1) * (lat_sp + panel / bw_sp), 0.0)
        sp_ring_bwd = 2.0 * sp_ring_fwd
        sp_ar = np.where(spd > 1,
                         lat_sar + 2.0 * ring_spd * pbytes / bw_sar, 0.0)

        # EP: all-to-all dispatch + combine, plus the replicated-param
        # gradient all-reduce — mirrors the scalar path's ``if ep > 1``
        msg_ep = bnd * b_dev / spd / epd * top_k * cfac * ep_imb
        ep_a2a = np.where(epd > 1,
                          2.0 * (lat_ep + ring_epd * msg_ep / bw_ep), 0.0)
        g_rep = cfg.bytes_per_param * (params_dev - p_exp_dev / epd)
        ep_ar = np.where(epd > 1,
                         lat_ear + 2.0 * ring_epd * g_rep / bw_ear, 0.0)

        # ---- assemble (overlap model, §V) ------------------------------
        sd = dev.overlap_slowdown

        def overlap(comp, comm):
            return np.where(comp <= 0.0, comm,
                            np.where(comm <= 0.0, comp,
                                     np.maximum(comp * sd, comm * sd)))

        fwd = overlap(comp_fwd, sdp_ag + sp_ring_fwd) + tp_time + ep_a2a
        re_fwd = np.where(ckpt,
                          overlap(recompute, sp_ring_fwd) + tp_time + ep_a2a,
                          0.0)
        bwd_nosync = overlap(comp_bwd, sdp_ag + sp_ring_bwd) + tp_time + ep_a2a
        bwd_sync = overlap(
            comp_bwd,
            sdp_ag + sp_ring_bwd + sdp_rs + dp_ar + sp_ar + ep_ar
        ) + tp_time + ep_a2a

        # pairs SP/EP cannot execute get the scalar path's poison time
        sp_bad = (spd > 1) & ~((seq_l > 0)
                               & (np.mod(seq_l, spd) == 0) & sp_kind_ok)
        ep_bad = (epd > 1) & ~((n_exp > 1) & (np.mod(n_exp, epd) == 0))
        sp_bad = sp_bad | ep_bad
        if cfg.min_samples_per_device > 0.0:
            # physical floor: DP/SDP cannot split one sample (see config)
            sp_bad = sp_bad | (b_dev < cfg.min_samples_per_device)

        # ---- reshard (layout-transformation) cost ----------------------
        reshard = lat_tot + 2.0 * ring_tot * (bnd * micro_batch_size / total) / bw_tot

        return CostTables(
            time_sync=np.where(sp_bad, _SP_INVALID_TIME,
                               fwd + re_fwd + bwd_sync),
            time_nosync=np.where(sp_bad, _SP_INVALID_TIME,
                                 fwd + re_fwd + bwd_nosync),
            time_fwd=np.where(sp_bad, _SP_INVALID_TIME, fwd),
            mem_f=mem_f,
            mem_b=mem_b,
            mem_ms=ms,
            reshard=reshard,
        )

    # ------------------------------------------------------------------
    def plan_peak_stage_mem(self, specs: Sequence[LayerSpec],
                            plan) -> List[float]:
        """Recompute each pipeline stage's exact peak memory (Eq. 2) for a
        finished :class:`~repro.core.plan.ParallelPlan`, via the scalar
        ``layer_costs`` path — independent of the DP search machinery, so
        it serves as the feasibility oracle for frontier plans (every
        swept plan must fit under its own budget)."""
        from .pipeline_balance import inflight_microbatches
        B_m = plan.global_batch / plan.n_micro
        out: List[float] = []
        start = 0
        for i, n in enumerate(plan.partition):
            infl = inflight_microbatches(i, plan.pp_degree, plan.n_micro,
                                         plan.schedule, plan.vpp_degree)
            cum_f = peak = ms = 0.0
            for l in range(start, start + n):
                c = self.layer_costs(specs[l], plan.strategies[l], B_m,
                                     inflight=infl)
                cum_f += c.mem_f
                peak = max(peak, cum_f + c.mem_b)
                ms += c.mem_ms
            out.append(peak + ms)
            start += n
        return out

    # ------------------------------------------------------------------
    def reshard_cost(self, spec: LayerSpec, strat_to: Strategy,
                     micro_batch_size: float) -> float:
        """R(l, S_i, S_j): slice-gather transformation cost when the previous
        layer used a different strategy.  Modeled as moving this layer's
        boundary activations once across the stage's device group."""
        n = strat_to.total
        if n <= 1:
            return 0.0
        lat, bw = self._group_coeffs("all_gather", n)
        bytes_moved = spec.bnd_bytes_per_sample * micro_batch_size / n
        return lat + 2.0 * self._ring_factor(n) * bytes_moved / bw

    # ------------------------------------------------------------------
    def p2p_cost(self, spec: LayerSpec, micro_batch_size: float,
                 data_deg: int) -> float:
        """Pipeline stage-boundary activation transfer (per micro-batch).

        Priced from the profiled ``ppermute`` pair when the cluster is a
        single island and carries one, else the analytic inter-island
        bandwidth (PP hand-offs cross the slow domain on hierarchical
        clusters — Takeaway #1)."""
        lat, bw = self._group_coeffs("ppermute", -1)
        bytes_moved = spec.bnd_bytes_per_sample * micro_batch_size / max(1, data_deg)
        return lat + bytes_moved / bw
