"""Throughput-vs-memory plan frontier (DESIGN.md §6).

The paper's headline evaluation presents throughput as a *function of the
per-device memory budget*.  ``GalvatronOptimizer.sweep_budgets`` produces a
:class:`PlanFrontier`: one (budget, plan, predicted throughput) point per
swept budget, all searched in ~one pass by running the stage DP with a
budget axis.  The frontier serializes to JSON (consumed by
``launch/search.py`` and ``benchmarks/bench_frontier.py``) and exposes the
knee points — the budgets where predicted throughput actually improves,
i.e. where buying more memory buys speed.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

from .plan import ParallelPlan

GB = 1024 ** 3


@dataclasses.dataclass
class FrontierPoint:
    """One swept budget: the best plan found under it (None if everything
    OOMs) and its predicted throughput (samples/s; 0.0 when infeasible)."""

    budget_bytes: float
    plan: Optional[ParallelPlan]
    predicted_throughput: float = 0.0

    @property
    def feasible(self) -> bool:
        return self.plan is not None

    def to_json(self) -> Dict:
        return {
            "budget_bytes": self.budget_bytes,
            "budget_gb": self.budget_bytes / GB,
            "predicted_throughput": self.predicted_throughput,
            "plan": self.plan.to_json() if self.plan is not None else None,
        }

    @staticmethod
    def from_json(d: Dict) -> "FrontierPoint":
        plan = (ParallelPlan.from_json(d["plan"])
                if d.get("plan") is not None else None)
        return FrontierPoint(
            budget_bytes=d["budget_bytes"],
            plan=plan,
            predicted_throughput=d.get("predicted_throughput", 0.0),
        )


@dataclasses.dataclass
class PlanFrontier:
    """The whole budget sweep, sorted by budget ascending.

    ``quant_bytes`` records the DP quantization grid the sweep ran on —
    a serial ``optimize()`` reproduces a point byte-for-byte only on the
    same grid.  ``search_stats`` is the aggregated engine telemetry
    (cache hits/misses summed across parallel workers); like
    ``ParallelPlan.search_stats`` it is excluded from equality.
    """

    points: List[FrontierPoint]
    quant_bytes: float = 0.0
    search_stats: Optional[Dict[str, float]] = dataclasses.field(
        default=None, compare=False)

    def __post_init__(self):
        self.points = sorted(self.points, key=lambda p: p.budget_bytes)

    # ---- queries --------------------------------------------------------
    def budgets(self) -> List[float]:
        return [p.budget_bytes for p in self.points]

    def throughputs(self) -> List[float]:
        return [p.predicted_throughput for p in self.points]

    def feasible_points(self) -> List[FrontierPoint]:
        return [p for p in self.points if p.feasible]

    def plan_at(self, budget_bytes: float) -> Optional[ParallelPlan]:
        """Best known plan fitting under ``budget_bytes``: the highest-
        throughput feasible point whose swept budget is <= the query (plans
        found under a smaller budget remain valid under a larger one).
        This is the incremental answer for budgets between swept points —
        no re-search needed."""
        best: Optional[FrontierPoint] = None
        for p in self.points:
            if p.budget_bytes <= budget_bytes and p.feasible:
                if (best is None
                        or p.predicted_throughput > best.predicted_throughput):
                    best = p
        return best.plan if best is not None else None

    def knee_points(self) -> List[FrontierPoint]:
        """Pareto knees: feasible points whose predicted throughput strictly
        exceeds every smaller budget's — the budgets where extra memory
        actually converts into speed."""
        out: List[FrontierPoint] = []
        seen_best = 0.0
        for p in self.points:
            if p.feasible and p.predicted_throughput > seen_best:
                out.append(p)
                seen_best = p.predicted_throughput
        return out

    def summary(self) -> str:
        rows = []
        for p in self.points:
            if p.feasible:
                rows.append(f"{p.budget_bytes / GB:7.1f} GB  "
                            f"{p.predicted_throughput:10.2f} samples/s  "
                            f"{p.plan.summary()}")
            else:
                rows.append(f"{p.budget_bytes / GB:7.1f} GB        OOM")
        return "\n".join(rows)

    # ---- (de)serialization ----------------------------------------------
    def to_json(self) -> Dict:
        knees = {id(p) for p in self.knee_points()}
        return {
            "quant_bytes": self.quant_bytes,
            "points": [dict(p.to_json(), knee=(id(p) in knees))
                       for p in self.points],
            "search_stats": self.search_stats,
        }

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=2)

    @staticmethod
    def from_json(d: Dict) -> "PlanFrontier":
        return PlanFrontier(
            points=[FrontierPoint.from_json(p) for p in d["points"]],
            quant_bytes=d.get("quant_bytes", 0.0),
            search_stats=d.get("search_stats"),
        )

    @staticmethod
    def loads(s: str) -> "PlanFrontier":
        return PlanFrontier.from_json(json.loads(s))


# --------------------------------------------------------------------------
# batch-axis dominance frontier (search-time pruning)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CandidateBound:
    """Certified optimistic bounds for one unexplored (B, P) candidate.

    ``tpt_upper`` over-estimates the best throughput any plan of the
    candidate can reach (an ideal-balance cost lower bound turned into a
    samples/s upper bound); ``mem_lower`` under-estimates the peak stage
    memory of its *cheapest* strategy assignment.  Both must be sound —
    the pruner's byte-identity guarantee leans on them — so they are built
    from per-layer minima of the exact cost tables (see
    ``GalvatronOptimizer._candidate_bound`` for the derivation)."""

    tpt_upper: float              # samples/s, >= any achievable throughput
    mem_lower: float              # bytes, <= any achievable peak stage memory


class DominanceFrontier:
    """Running per-budget dominance frontier over the batch axis.

    Mirrors the budget-axis machinery one level up: as the B × P sweep
    explores candidates in grid order it records the best throughput
    achieved so far *under each budget* (:meth:`observe`); an unexplored
    candidate whose optimistic :class:`CandidateBound` cannot beat that
    incumbent (:meth:`dominated`) — or cannot even fit
    (:meth:`infeasible`) — is skipped without running its inner DP.

    Soundness of skipping, per budget ``k``:

    * *infeasible*: ``mem_lower > budgets[k]`` means every strategy chain
      of the candidate exceeds the budget, so the serial search would have
      returned no plan for ``k`` — skipping changes nothing.
    * *dominated*: the serial sweep replaces its incumbent only on a
      *strictly* better throughput, and incumbents only improve over time,
      so a candidate with ``tpt_upper <= best[k]`` at skip time can never
      displace the final answer.

    The interaction with the two-consecutive-OOM batch stop is handled by
    the optimizer (a dominated-but-feasible candidate may still need a
    *forced* run to decide OOM bookkeeping — see ``_sweep_axis``).
    """

    def __init__(self, budgets):
        self.budgets = tuple(float(b) for b in budgets)
        self.best = [0.0] * len(self.budgets)

    def observe(self, k: int, throughput: float) -> None:
        """Record a plan actually found under budget ``k``."""
        if throughput > self.best[k]:
            self.best[k] = throughput

    def infeasible(self, k: int, bound: CandidateBound) -> bool:
        return bound.mem_lower > self.budgets[k]

    def dominated(self, k: int, bound: CandidateBound) -> bool:
        return self.best[k] > 0.0 and bound.tpt_upper <= self.best[k]

    def classify(self, k: int, bound: CandidateBound) -> str:
        """``"infeasible"`` / ``"dominated"`` / ``"live"`` for budget k."""
        if self.infeasible(k, bound):
            return "infeasible"
        if self.dominated(k, bound):
            return "dominated"
        return "live"
