"""Pipeline workload balance (paper §IV-B).

1F1B-Flush keeps ``P - i`` micro-batches in flight on (0-indexed) stage
``i``, so shallower stages hold more activation memory — the memory workload
is imbalanced even when the time workload is perfect.  This module provides:

  * balance degrees α_t / α_m (Eq. 6),
  * extreme partitions p_t (time-balanced) and p_m (memory-balanced),
  * the greedy boundary-layer adjustment + the 3-criterion validation of
    §IV-B2 (every accepted partition satisfies Eq. 7/8).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

INF = float("inf")


# fraction of a full per-micro-batch activation set a deferred W tick
# retains: the weight gradient needs each layer's *input* activation (and
# the incoming cotangent), but the activation-gradient chain through the
# intermediates is already consumed by the B tick — charge half a set
ZB_W_ACT_FRAC = 0.5


def zb_w_pending_max(stage: int, n_stages: int, n_micro: int) -> int:
    """Deepest completed-B-but-pending-W pile the compiled ZB-H1 program
    accumulates on (0-indexed) ``stage``: ``max(1, m - P + 1 + i)``.

    W ticks are deferred until a stage has nothing on the critical path
    (no ready B, no F under the in-flight cap), so deep stages — which run
    out of F work last — bank the most weight-gradient state.  This is
    the memory side of the zero-bubble trade: the greedy compiler
    (``runtime/schedules.py::_compile_zb_h1``) realizes exactly this
    depth (asserted by ``tests/test_pipeline_schedules.py``), and
    :func:`inflight_microbatches` charges :data:`ZB_W_ACT_FRAC` of an
    activation set per pending W using the same formula — one definition,
    priced and executed."""
    return max(1, n_micro - n_stages + 1 + stage)


def inflight_microbatches(stage: int, n_stages: int, n_micro: int,
                          schedule: str = "1f1b", vpp: int = 1) -> float:
    """In-flight micro-batch activation sets on one stage, in units of the
    stage's *full* forward activation footprint (the cost model multiplies
    a stage's per-micro-batch activation bytes by this).

    * ``gpipe``: every micro-batch is stashed — ``m``.
    * ``1f1b`` (flush): stage ``i`` (0-indexed) warms up ``P - i``
      micro-batches before its first backward.
    * ``1f1b-interleaved`` with ``V = vpp`` chunks: the depth-first
      Megatron schedule warms up ``2·(P-1-i) + (V-1)·P`` forward *chunks*
      on device ``i``, plus one in steady state, capped at the ``m·V``
      chunks that exist.  Each chunk's activations are ``1/V`` of the
      stage's, so the per-chunk count divides by ``V`` — fractional
      full-stage units (the per-chunk accounting of DESIGN.md §5).
    * ``zb-h1``: the forward stash keeps the 1F1B profile
      (``min(P - i, m)`` — the compiler enforces the same in-flight cap),
      but every deferred weight-gradient tick banks
      :data:`ZB_W_ACT_FRAC` of a set until it runs; the compiled
      deferral depth is :func:`zb_w_pending_max`.  This is the memory
      price of the near-zero bubble — strictly above 1F1B on every
      stage, approaching it as ``m`` shrinks toward ``P``.
    """
    if schedule == "gpipe":
        return n_micro
    if schedule == "zb-h1":
        return (min(n_stages - stage, n_micro)
                + ZB_W_ACT_FRAC * zb_w_pending_max(stage, n_stages, n_micro))
    if schedule == "1f1b-interleaved" and vpp > 1:
        chunks = min(2 * (n_stages - stage - 1) + (vpp - 1) * n_stages + 1,
                     n_micro * vpp)
        return chunks / vpp
    # 1F1B-flush: stage i (0-indexed) warms up P - i micro-batches
    return min(n_stages - stage, n_micro)


def stage_bounds(partition: Sequence[int]) -> List[Tuple[int, int]]:
    """[(start, end)) layer index ranges of each stage."""
    out, s = [], 0
    for p in partition:
        out.append((s, s + p))
        s += p
    return out


def balance_degrees(stage_times: Sequence[float],
                    stage_mems: Sequence[float]) -> Tuple[float, float]:
    """α_t, α_m of Eq. 6."""
    t, m = np.asarray(stage_times, float), np.asarray(stage_mems, float)
    a_t = 1.0 - t.max() / t.sum() if t.sum() > 0 else 0.0
    a_m = 1.0 - m.max() / m.sum() if m.sum() > 0 else 0.0
    return float(a_t), float(a_m)


def _partition_minimize_max(loads: np.ndarray, P: int,
                            stage_weight=None) -> List[int]:
    """Contiguous partition of ``loads`` into P parts minimizing the maximum
    (optionally stage-weighted) part sum.  O(P * L^2) DP — exact.

    ``stage_weight(i)`` multiplies the load of stage i (used for 1F1B
    in-flight activation weighting when balancing memory).
    """
    L = len(loads)
    prefix = np.concatenate([[0.0], np.cumsum(loads)])
    weight = [stage_weight(i) if stage_weight else 1.0 for i in range(P)]

    # dp[i][l] = min over partitions of first l layers into i+1 stages of max load
    dp = np.full((P, L + 1), INF)
    cut = np.zeros((P, L + 1), dtype=np.int64)
    dp[0, 1:] = (prefix[1:] - prefix[0]) * weight[0]
    for i in range(1, P):
        # vectorized over the cut point k: stage i spans (k, l]
        for l in range(i + 1, L + 1):
            ks = np.arange(i, l)
            v = np.maximum(dp[i - 1, ks], (prefix[l] - prefix[ks]) * weight[i])
            bk = int(v.argmin())
            dp[i, l] = v[bk]
            cut[i, l] = i + bk
    # backtrack
    parts = []
    l = L
    for i in range(P - 1, 0, -1):
        k = int(cut[i, l])
        parts.append(l - k)
        l = k
    parts.append(l)
    parts.reverse()
    return parts


def time_balanced_partition(layer_times: Sequence[float], P: int) -> List[int]:
    return _partition_minimize_max(np.asarray(layer_times, float), P)


def memory_balanced_partition(layer_mems: Sequence[float], P: int,
                              n_micro: int, schedule: str = "1f1b",
                              vpp: int = 1) -> List[int]:
    """Balance act-memory × 1F1B in-flight weight across stages."""
    return _partition_minimize_max(
        np.asarray(layer_mems, float), P,
        stage_weight=lambda i: inflight_microbatches(i, P, n_micro, schedule,
                                                     vpp))


def adjust_partition(partition: Sequence[int],
                     stage_times: Sequence[float]) -> List[List[int]]:
    """Greedy adjustment (§IV-B2): shed a boundary layer from the slowest
    stage to its adjacent stage(s).  Returns candidate new partitions."""
    p = list(partition)
    P = len(p)
    slow = int(np.argmax(stage_times))
    candidates = []
    if p[slow] > 1:
        if slow > 0:
            q = list(p)
            q[slow] -= 1
            q[slow - 1] += 1
            candidates.append(q)
        if slow < P - 1:
            q = list(p)
            q[slow] -= 1
            q[slow + 1] += 1
            candidates.append(q)
    return candidates


@dataclasses.dataclass
class PartitionEval:
    partition: List[int]
    stage_times: List[float]        # per-stage C(M_i, B_m) (sync variant)
    stage_times_nosync: List[float]
    stage_mems: List[float]
    feasible: bool


def validate_adjustment(new: PartitionEval, prev_max_time: float,
                        budget: float, pt_max_mem: float) -> bool:
    """The three §IV-B2 criteria: (1) no stage slower than the previous
    maximum, (2) all stages within budget, (3) no stage above the
    time-balanced partition's maximum memory."""
    if not new.feasible:
        return False
    if max(new.stage_times) > prev_max_time + 1e-12:
        return False
    if max(new.stage_mems) > budget:
        return False
    if max(new.stage_mems) > pt_max_mem + 1e-6:
        return False
    return True
