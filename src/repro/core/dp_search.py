"""Dynamic-programming strategy search (paper §IV-A2, Appendix A).

Optimizes the per-layer strategy assignment of one pipeline stage under a
device memory budget.  Follows the paper's decomposition:

  1. sweep a *forward* memory budget ``E_fwd <= E`` — the DP table is
     computed over all quantized budgets at once (knapsack style),
  2. for each candidate ``E_fwd`` (descending) backtrack the strategy chain
     and verify the exact peak memory ``E_all <= E`` (Eq. 2),
  3. the largest valid ``E_fwd`` wins; ``E_fwd <= E - b_up`` is always valid
     (b_up = max backward peak), which bounds the scan.

The transformation cost R(l, S_i, S_j) is instantiated as
``0 if levels(S_i) == levels(S_j) else r(l, S_j)`` (resharding into layout
S_j); this keeps the paper's claimed O(L·E·|S|) complexity (a general
R(i,j) matrix would cost O(L·E·|S|^2)).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .cost_model import CostModel
from .layerspec import LayerSpec
from .strategy import Strategy

INF = float("inf")


@dataclasses.dataclass
class StageSearchResult:
    feasible: bool
    time: float                     # stage time, last micro-batch (grad sync)
    time_nosync: float              # stage time, earlier micro-batches
    strategies: List[Strategy]
    e_all: float                    # exact peak memory (Eq. 2), bytes
    e_fwd: float                    # forward memory used (Eq. 3), bytes
    mem_states: float               # total model-state bytes per device


def _exact_e_all(mem_f: np.ndarray, mem_b: np.ndarray, mem_ms: np.ndarray,
                 choice: Sequence[int]) -> float:
    """Eq. 2 with a concrete strategy chain."""
    idx = np.arange(len(choice))
    f = mem_f[idx, choice]
    b = mem_b[idx, choice]
    ms_total = mem_ms[idx, choice].sum()
    cum_f = np.cumsum(f)
    return float((cum_f + b).max() + ms_total) if len(choice) else 0.0


def dp_search_stage(
    specs: Sequence[LayerSpec],
    strategies: Sequence[Strategy],
    cost_model: CostModel,
    micro_batch_size: float,
    budget_bytes: float,
    *,
    inflight: int = 1,
    n_bins: int = 256,
    n_micro: int = 1,
) -> StageSearchResult:
    """Search the optimal per-layer strategies for one pipeline stage.

    The DP objective is the m-amortized per-micro-batch time
    ``t_nosync + (t_sync - t_nosync)/m`` — Eq. 9 charges the grad-sync cost
    only on the last of ``n_micro`` micro-batches, so optimizing raw sync
    time would mis-rank strategies with expensive gradient synchronization
    but cheap steady-state micro-batches.
    """
    L, S = len(specs), len(strategies)
    if L == 0:
        return StageSearchResult(True, 0.0, 0.0, [], 0.0, 0.0, 0.0)

    # ---- per (layer, strategy) cost tables -----------------------------
    time = np.full((L, S), INF)       # DP objective (m-amortized)
    time_sync = np.full((L, S), INF)  # raw last-micro-batch time
    time_ns = np.full((L, S), INF)
    mem_f = np.zeros((L, S))
    mem_b = np.zeros((L, S))
    mem_ms = np.zeros((L, S))
    reshard = np.zeros((L, S))
    for l, spec in enumerate(specs):
        for j, s in enumerate(strategies):
            c = cost_model.layer_costs(spec, s, micro_batch_size, inflight=inflight)
            time[l, j] = c.time_nosync + (c.time - c.time_nosync) / max(1, n_micro)
            time_sync[l, j] = c.time
            time_ns[l, j] = c.time_nosync
            mem_f[l, j] = c.mem_f
            mem_b[l, j] = c.mem_b
            mem_ms[l, j] = c.mem_ms
            reshard[l, j] = cost_model.reshard_cost(spec, s, micro_batch_size)

    # quantized forward-memory weight of each (layer, strategy)
    bin_bytes = max(budget_bytes / n_bins, 1.0)
    w = np.ceil((mem_f + mem_ms) / bin_bytes).astype(np.int64)   # bins
    E = n_bins

    # strategies grouped by identical levels (R == 0 within a group)
    level_key = {}
    group_of = np.zeros(S, dtype=np.int64)
    for j, s in enumerate(strategies):
        group_of[j] = level_key.setdefault(s.levels, len(level_key))
    G = len(level_key)
    group_members = [np.where(group_of == g)[0] for g in range(G)]

    # ---- DP over (budget_bin, strategy) ---------------------------------
    # C[e, j]: min time of layers processed so far using total fwd-mem <= e
    # bins, with the last layer using strategy j.
    C = np.full((E + 1, S), INF)
    parents = np.zeros((L, E + 1, S), dtype=np.int16)

    for l in range(L):
        Cn = np.full((E + 1, S), INF)
        if l == 0:
            for j in range(S):
                if w[0, j] <= E:
                    Cn[w[0, j]:, j] = time[0, j]
                    parents[0, :, j] = -1
        else:
            best_all = C.min(axis=1)                        # (E+1,)
            arg_all = C.argmin(axis=1)                      # (E+1,)
            best_grp = np.full((E + 1, G), INF)
            arg_grp = np.zeros((E + 1, G), dtype=np.int64)
            for g, members in enumerate(group_members):
                sub = C[:, members]
                k = sub.argmin(axis=1)
                best_grp[:, g] = sub[np.arange(E + 1), k]
                arg_grp[:, g] = members[k]
            for j in range(S):
                wj = w[l, j]
                if wj > E:
                    continue
                n_src = E + 1 - wj
                src = np.arange(0, n_src)
                same = best_grp[src, group_of[j]]
                cross = best_all[src] + reshard[l, j]
                take_same = same <= cross
                val = np.where(take_same, same, cross) + time[l, j]
                par = np.where(take_same, arg_grp[src, group_of[j]], arg_all[src])
                Cn[wj:, j] = val
                parents[l, wj:, j] = par
        C = Cn

    # ---- E_fwd sweep with exact E_all validation (Alg. 3) ---------------
    b_up = float(np.max(mem_b)) if L else 0.0    # paper's b_up (max over l, S)

    final_best = C.min(axis=1)                   # per budget bin
    final_arg = C.argmin(axis=1)

    def backtrack(e_bin: int) -> Optional[List[int]]:
        j = int(final_arg[e_bin])
        if not np.isfinite(final_best[e_bin]):
            return None
        chain = [0] * L
        e = e_bin
        for l in range(L - 1, -1, -1):
            chain[l] = j
            pj = int(parents[l, e, j])
            e = e - int(w[l, j])
            j = pj
        return chain

    for e_bin in range(E, -1, -1):
        if not np.isfinite(final_best[e_bin]):
            continue
        chain = backtrack(e_bin)
        if chain is None:
            continue
        e_all = _exact_e_all(mem_f, mem_b, mem_ms, chain)
        e_fwd_exact = float(sum(mem_f[l, chain[l]] + mem_ms[l, chain[l]]
                                for l in range(L)))
        if e_all <= budget_bytes or e_bin * bin_bytes <= budget_bytes - b_up:
            idx = np.arange(L)
            t_sync = float(time_sync[idx, chain].sum())
            t_nosync = float(time_ns[idx, chain].sum())
            # add reshard costs along the chain
            extra = 0.0
            for l in range(1, L):
                if strategies[chain[l]].levels != strategies[chain[l - 1]].levels:
                    extra += reshard[l, chain[l]]
            ms_total = float(mem_ms[idx, chain].sum())
            return StageSearchResult(
                feasible=True,
                time=t_sync + extra,
                time_nosync=t_nosync + extra,
                strategies=[strategies[j] for j in chain],
                e_all=e_all,
                e_fwd=e_fwd_exact,
                mem_states=ms_total,
            )

    return StageSearchResult(False, INF, INF, [], INF, INF, 0.0)
