"""Dynamic-programming strategy search (paper §IV-A2, Appendix A).

Optimizes the per-layer strategy assignment of one pipeline stage under a
device memory budget.  Follows the paper's decomposition:

  1. sweep a *forward* memory budget ``E_fwd <= E`` — the DP table is
     computed over all quantized budgets at once (knapsack style),
  2. for each candidate ``E_fwd`` (descending) backtrack the strategy chain
     and verify the exact peak memory ``E_all <= E`` (Eq. 2),
  3. the largest valid ``E_fwd`` wins; ``E_fwd <= E - b_up`` is always valid
     (b_up = max backward peak), which bounds the scan.

The transformation cost R(l, S_i, S_j) is instantiated as
``0 if levels(S_i) == levels(S_j) else r(l, S_j)`` (resharding into layout
S_j); this keeps the paper's claimed O(L·E·|S|) complexity (a general
R(i,j) matrix would cost O(L·E·|S|^2)).

**Budget axis** (DESIGN.md §6): the forward DP table is independent of the
memory budget once the quantization grid is fixed — the budget only selects
where the descending E_fwd scan starts and when a backtracked chain is
accepted.  ``dp_search_stage_budgets`` exploits this: one forward pass,
then a per-budget argmax scan, so a whole budget sweep costs ~one search.
``quant_bytes`` pins the grid (``bin_bytes = quant_bytes / n_bins``);
results for budget ``b`` are bit-identical to a single-budget search at
``b`` run on the same grid.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost_model import CostModel, CostTables
from .layerspec import LayerSpec
from .strategy import Strategy, strategy_set_id

INF = float("inf")

# cached per strategy set: levels-group structure of the transformation cost
_GROUP_INFO_CACHE = {}


def _group_info(strategies: Sequence[Strategy]):
    """Group strategies by identical levels (R == 0 within a group).

    enumerate_strategies lists each levels-group contiguously (ckpt pairs);
    when that holds, per-group minima collapse to one reduceat call — and
    when every group additionally has the same size (the common all-ckpt /
    no-ckpt spaces) to an even cheaper reshape + min over the last axis.
    The structure only depends on the strategy list, so it is computed once
    per set.
    """
    sid = strategy_set_id(strategies)
    info = _GROUP_INFO_CACHE.get(sid)
    if info is None:
        S = len(strategies)
        level_key = {}
        group_of = np.zeros(S, dtype=np.int64)
        for j, s in enumerate(strategies):
            group_of[j] = level_key.setdefault(s.levels, len(level_key))
        G = len(level_key)
        group_members = [np.where(group_of == g)[0] for g in range(G)]
        contiguous = bool(np.all(np.diff(group_of) >= 0))
        group_starts = (np.searchsorted(group_of, np.arange(G))
                        if contiguous else None)
        uniform = contiguous and S % G == 0 and bool(
            np.all(np.diff(group_starts) == S // G)) if G else False
        info = (group_of, G, group_members, contiguous, group_starts, uniform)
        _GROUP_INFO_CACHE[sid] = info
    return info


@dataclasses.dataclass
class StageSearchResult:
    feasible: bool
    time: float                     # stage time, last micro-batch (grad sync)
    time_nosync: float              # stage time, earlier micro-batches
    strategies: List[Strategy]
    e_all: float                    # exact peak memory (Eq. 2), bytes
    e_fwd: float                    # forward memory used (Eq. 3), bytes
    mem_states: float               # total model-state bytes per device


def _exact_e_all(mem_f: np.ndarray, mem_b: np.ndarray, mem_ms: np.ndarray,
                 choice: Sequence[int]) -> float:
    """Eq. 2 with a concrete strategy chain."""
    idx = np.arange(len(choice))
    f = mem_f[idx, choice]
    b = mem_b[idx, choice]
    ms_total = mem_ms[idx, choice].sum()
    cum_f = np.cumsum(f)
    return float((cum_f + b).max() + ms_total) if len(choice) else 0.0


def _bin_cap(budget_bytes: float, quant_bytes: float, bin_bytes: float,
             n_bins: int) -> int:
    """Number of quantized bins usable under ``budget_bytes`` on the grid
    anchored at ``quant_bytes`` (budget == quant recovers exactly
    ``n_bins``, including the degenerate ``bin_bytes == 1.0`` clamp)."""
    if budget_bytes >= quant_bytes:
        return max(n_bins, int(budget_bytes / bin_bytes + 1e-9))
    return int(budget_bytes / bin_bytes + 1e-9)


def dp_search_stage(
    specs: Sequence[LayerSpec],
    strategies: Sequence[Strategy],
    cost_model: CostModel,
    micro_batch_size: float,
    budget_bytes: float,
    *,
    quant_bytes: Optional[float] = None,
    inflight: float = 1,
    n_bins: int = 256,
    n_micro: int = 1,
    tables: Optional[CostTables] = None,
    use_tables: bool = True,
) -> StageSearchResult:
    """Search the optimal per-layer strategies for one pipeline stage.

    The DP objective is the m-amortized per-micro-batch time
    ``t_nosync + (t_sync - t_nosync)/m`` — Eq. 9 charges the grad-sync cost
    only on the last of ``n_micro`` micro-batches, so optimizing raw sync
    time would mis-rank strategies with expensive gradient synchronization
    but cheap steady-state micro-batches.

    ``quant_bytes`` anchors the memory quantization grid (default: the
    budget itself, the pre-frontier behaviour); pinning it across calls
    with different budgets makes their results comparable bin-for-bin.

    ``tables`` takes precomputed (L, S) cost arrays (e.g. a row-slice of the
    full-model tables the optimizer caches per (B_m, inflight));
    ``use_tables=False`` dispatches to the seed reference implementation
    (per-pair scalar cost calls + per-strategy Python DP loops), kept as the
    benchmark baseline and differential-test oracle.
    """
    return dp_search_stage_budgets(
        specs, strategies, cost_model, micro_batch_size, [budget_bytes],
        quant_bytes=quant_bytes, inflight=inflight, n_bins=n_bins,
        n_micro=n_micro, tables=tables, use_tables=use_tables)[0]


def dp_search_stage_budgets(
    specs: Sequence[LayerSpec],
    strategies: Sequence[Strategy],
    cost_model: CostModel,
    micro_batch_size: float,
    budgets: Sequence[float],
    *,
    quant_bytes: Optional[float] = None,
    inflight: float = 1,
    n_bins: int = 256,
    n_micro: int = 1,
    tables: Optional[CostTables] = None,
    use_tables: bool = True,
) -> List[StageSearchResult]:
    """Budget-axis stage search: one forward DP, one result per budget.

    The DP table C depends on the budgets only through the shared
    quantization grid (``bin_bytes = quant_bytes / n_bins``), so a whole
    budget sweep runs the O(L·E·|S|) forward pass once; each budget then
    pays only its descending E_fwd scan (backtracked chains are memoized
    per bin and shared across budgets).  Every returned result is
    bit-identical to ``dp_search_stage(..., budget, quant_bytes=quant)``.
    """
    budgets = [float(b) for b in budgets]
    if not budgets:
        return []
    quant = float(quant_bytes) if quant_bytes is not None else max(budgets)

    if tables is None and not use_tables:
        return [dp_search_stage_reference(
                    specs, strategies, cost_model, micro_batch_size, b,
                    quant_bytes=quant, inflight=inflight, n_bins=n_bins,
                    n_micro=n_micro)
                for b in budgets]

    L, S = len(specs), len(strategies)
    if L == 0:
        return [StageSearchResult(True, 0.0, 0.0, [], 0.0, 0.0, 0.0)
                for _ in budgets]

    # ---- per (layer, strategy) cost tables -----------------------------
    if tables is None:
        tables = cost_model.layer_cost_tables(
            specs, strategies, micro_batch_size, inflight=inflight)
    time_sync, time_ns = tables.time_sync, tables.time_nosync
    mem_f, mem_b, mem_ms = tables.mem_f, tables.mem_b, tables.mem_ms
    reshard = tables.reshard
    # DP objective (m-amortized)
    time = time_ns + (time_sync - time_ns) / max(1, n_micro)

    # quantized forward-memory weight of each (layer, strategy)
    bin_bytes = max(quant / n_bins, 1.0)
    caps = [_bin_cap(b, quant, bin_bytes, n_bins) for b in budgets]
    nb_max = max(caps)
    w = np.ceil((mem_f + mem_ms) / bin_bytes).astype(np.int64)   # bins
    # No chain can weigh more than the sum of per-layer maxima (counting
    # only strategies that fit at all), so budget bins above that cap hold
    # exactly the same DP column as the cap bin — shrink the budget axis to
    # it.  The descending E_fwd scan then starts at the cap, which returns
    # the same chain the full-height scan would (identical C columns above).
    w_valid = np.where(w <= nb_max, w, -1)
    per_layer_max = w_valid.max(axis=1)
    if (per_layer_max < 0).any():       # some layer fits under no strategy
        return [StageSearchResult(False, INF, INF, [], INF, INF, 0.0)
                for _ in budgets]
    E = int(min(nb_max, per_layer_max.sum()))

    (group_of, G, group_members, contiguous, group_starts,
     uniform) = _group_info(strategies)

    # ---- DP over (budget_bin, strategy) ---------------------------------
    # C[e, j]: min time of layers processed so far using total fwd-mem <= e
    # bins, with the last layer using strategy j.  The per-layer transition
    # is fully vectorized over (budget_bin, strategy): candidate values are
    # computed at every unshifted budget e', then each strategy column is
    # shifted down by its own weight w[l, j] with one fancy-index gather.
    # No parent pointers are materialized — backtracking re-derives each
    # predecessor from the kept per-layer C tables (cheaper than building
    # (L, E+1, S) argmin tables that are read at most once per chain link).
    ebins = np.arange(E + 1)
    cols = np.arange(S)
    # layers with identical strategy weights (homogeneous stacks) share the
    # same shifted-gather indices — build them once per distinct w row
    shift_cache = {}

    def shift_for(l: int):
        key = w[l].tobytes()
        cached = shift_cache.get(key)
        if cached is None:
            idx = ebins[:, None] - w[l][None, :]    # source bin per (e, j)
            invalid = (idx < 0).ravel()             # also when w[l,j] > E
            np.clip(idx, 0, E, out=idx)
            flat = (idx * S + cols[None, :]).ravel()
            cached = shift_cache[key] = (flat, invalid)
        return cached

    states = []                                  # C after each layer
    C = None
    for l in range(L):
        flat, invalid = shift_for(l)
        if l == 0:
            Cn = np.broadcast_to(time[0][None, :], (E + 1, S)).copy()
        else:
            if uniform and S == 2 * G:          # ckpt pairs: one binary ufunc
                red = np.minimum(C[:, ::2], C[:, 1::2])
            elif uniform:
                red = C.reshape(E + 1, G, S // G).min(axis=2)
            elif contiguous:
                red = np.minimum.reduceat(C, group_starts, axis=1)
            else:
                red = np.empty((E + 1, G))
                for g, members in enumerate(group_members):
                    red[:, g] = C[:, members].min(axis=1)
            best_all = red.min(axis=1)                       # == C.min(axis=1)
            best_grp = red[:, group_of]                      # (E+1, S)
            cross = best_all[:, None] + reshard[l][None, :]  # (E+1, S)
            val = np.minimum(best_grp, cross) + time[l][None, :]
            Cn = val.ravel().take(flat).reshape(E + 1, S)
        Cn.ravel()[invalid] = INF
        states.append(Cn)
        C = Cn

    # ---- per-budget E_fwd sweep with exact E_all validation (Alg. 3) ----
    return _finish_budget_scan(
        states, w, strategies, group_of, group_members,
        time_sync, time_ns, mem_f, mem_b, mem_ms, reshard,
        budgets, caps, bin_bytes, E)


def _finish_budget_scan(
    states: Sequence[np.ndarray],
    w: np.ndarray,
    strategies: Sequence[Strategy],
    group_of: np.ndarray,
    group_members: Sequence[np.ndarray],
    time_sync: np.ndarray,
    time_ns: np.ndarray,
    mem_f: np.ndarray,
    mem_b: np.ndarray,
    mem_ms: np.ndarray,
    reshard: np.ndarray,
    budgets: Sequence[float],
    caps: Sequence[int],
    bin_bytes: float,
    E: int,
) -> List[StageSearchResult]:
    """Backtracking + per-budget descending E_fwd scan over finished DP
    tables (the tail of ``dp_search_stage_budgets``, shared verbatim with
    the batched entry so both produce identical results by construction).

    ``states`` holds the per-layer C tables for the *real* layers only;
    their budget-bin height may exceed ``E + 1`` (the batched path stacks
    jobs to a shared height) — rows above ``E`` are simply never read, and
    rows ``<= E`` are independent of table height because every transition
    reads only equal-or-lower bins (weights are non-negative).
    """
    L = len(states)
    C = states[-1]

    b_up = float(np.max(mem_b)) if L else 0.0    # paper's b_up (max over l, S)

    final_best = C.min(axis=1)                   # per budget bin
    final_arg = C.argmin(axis=1)
    feasible_bins = np.isfinite(final_best)

    def backtrack(e_bin: int) -> np.ndarray:
        """Re-derive the optimal chain ending at budget bin ``e_bin``.

        The predecessor of (l, e, j) is recomputed from C_{l-1}[e - w[l,j]]
        with the same same-group-vs-reshard comparison (and the same argmin
        tie-breaking) the forward pass used, so the recovered chain is
        identical to one backtracked through stored parent pointers.
        """
        chain = np.empty(L, dtype=np.int64)
        j = int(final_arg[e_bin])
        e = e_bin
        chain[L - 1] = j
        for l in range(L - 1, 0, -1):
            e -= int(w[l, j])
            v = states[l - 1][e]
            members = group_members[group_of[j]]
            sub = v[members]
            kg = int(sub.argmin())
            ka = int(v.argmin())
            if sub[kg] <= v[ka] + reshard[l, j]:
                j = int(members[kg])
            else:
                j = ka
            chain[l - 1] = j
        return chain

    # chains (and the expensive per-chain stats) depend on the bin, not the
    # budget — memoize per bin so overlapping budget scans share the work
    chain_cache: Dict[int, Tuple[np.ndarray, float]] = {}
    result_cache: Dict[int, StageSearchResult] = {}

    def chain_at(e_bin: int) -> Tuple[np.ndarray, float]:
        got = chain_cache.get(e_bin)
        if got is None:
            chain = backtrack(e_bin)
            got = (chain, _exact_e_all(mem_f, mem_b, mem_ms, chain))
            chain_cache[e_bin] = got
        return got

    def result_at(e_bin: int) -> StageSearchResult:
        res = result_cache.get(e_bin)
        if res is None:
            chain, e_all = chain_at(e_bin)
            idx = np.arange(L)
            e_fwd_exact = float(sum(mem_f[l, chain[l]] + mem_ms[l, chain[l]]
                                    for l in range(L)))
            t_sync = float(time_sync[idx, chain].sum())
            t_nosync = float(time_ns[idx, chain].sum())
            # add reshard costs along the chain (levels change ⇔ group changes)
            extra = 0.0
            for l in range(1, L):
                if group_of[chain[l]] != group_of[chain[l - 1]]:
                    extra += reshard[l, chain[l]]
            ms_total = float(mem_ms[idx, chain].sum())
            res = StageSearchResult(
                feasible=True,
                time=t_sync + extra,
                time_nosync=t_nosync + extra,
                strategies=[strategies[j] for j in chain],
                e_all=e_all,
                e_fwd=e_fwd_exact,
                mem_states=ms_total,
            )
            result_cache[e_bin] = res
        return res

    out: List[StageSearchResult] = []
    infeasible = StageSearchResult(False, INF, INF, [], INF, INF, 0.0)
    for b, cap in zip(budgets, caps):
        found = infeasible
        for e_bin in range(min(E, cap), -1, -1):
            if not feasible_bins[e_bin]:
                continue
            chain, e_all = chain_at(e_bin)
            if e_all <= b or e_bin * bin_bytes <= b - b_up:
                found = result_at(e_bin)
                break
        out.append(found)
    return out


# --------------------------------------------------------------------------
# batched entry — many stage searches, one stacked forward pass
# --------------------------------------------------------------------------

def dp_search_stage_budgets_batch(
    jobs: Sequence[Tuple[CostTables, int]],
    strategies: Sequence[Strategy],
    budgets: Sequence[float],
    *,
    quant_bytes: float,
    n_bins: int = 256,
) -> List[List[StageSearchResult]]:
    """Run many independent stage searches as ONE stacked NumPy DP.

    ``jobs`` is a sequence of ``(tables, n_micro)`` pairs — each ``tables``
    holds the (L_j, S) cost arrays of one stage (already sliced at the
    right ``B_m`` / inflight), all over the *same* strategy set and the
    same budget axis.  The per-layer DP transition is evaluated for every
    job at once on ``(N, E+1, S)`` arrays instead of N separate Python
    loops — the ``backend="vectorized"`` hot path of the optimizer.

    Byte-identity with N separate ``dp_search_stage_budgets`` calls:

    * jobs are stacked by *front*-padding shorter stages with zero layers
      (zero time/weight/reshard).  A zero prefix leaves the DP table
      identically zero, and the transition into the first real layer
      reproduces the unpadded initialization exactly (the cross term is
      ``0 + reshard >= 0 = same-group``, so ``min`` keeps 0, and the shift
      by ``w`` marks ``e < w`` infeasible — the serial ``l == 0`` case);
    * the stacked tables use the tallest job's bin count, but each job's
      scan/backtrack runs at its own ``E_j``; rows ``<= E_j`` never read
      higher rows (non-negative weights), so extra height is inert;
    * the finisher is literally the serial one (``_finish_budget_scan``)
      on per-job views of the stacked states.
    """
    budgets = [float(b) for b in budgets]
    if not jobs or not budgets:
        return [[] for _ in jobs]
    quant = float(quant_bytes)
    S = len(strategies)
    bin_bytes = max(quant / n_bins, 1.0)
    caps = [_bin_cap(b, quant, bin_bytes, n_bins) for b in budgets]
    nb_max = max(caps)

    (group_of, G, group_members, contiguous, group_starts,
     uniform) = _group_info(strategies)

    empty = [StageSearchResult(True, 0.0, 0.0, [], 0.0, 0.0, 0.0)
             for _ in budgets]
    infeasible = [StageSearchResult(False, INF, INF, [], INF, INF, 0.0)
                  for _ in budgets]

    # ---- per-job prep: amortized time, weights, own scan height ---------
    prepped = []          # (job_index, tables, time, w, E_j)
    out: List[Optional[List[StageSearchResult]]] = [None] * len(jobs)
    for i, (tb, n_micro) in enumerate(jobs):
        L = tb.time_sync.shape[0]
        if L == 0:
            out[i] = list(empty)
            continue
        time = tb.time_nosync + (tb.time_sync - tb.time_nosync) / max(1, n_micro)
        w = np.ceil((tb.mem_f + tb.mem_ms) / bin_bytes).astype(np.int64)
        w_valid = np.where(w <= nb_max, w, -1)
        per_layer_max = w_valid.max(axis=1)
        if (per_layer_max < 0).any():   # some layer fits under no strategy
            out[i] = list(infeasible)
            continue
        E_j = int(min(nb_max, per_layer_max.sum()))
        prepped.append((i, tb, time, w, E_j))
    if not prepped:
        return out  # type: ignore[return-value]

    # ---- stack with zero front-padding to a shared (Lmax, N*S) ----------
    # jobs live side by side as column blocks so every transition below is
    # literally the serial one on a wider table — including its cached
    # shifted-gather flat indices (homogeneous stacks repeat weight rows
    # across both layers and jobs, so the cache hits constantly)
    N = len(prepped)
    Lmax = max(tb.time_sync.shape[0] for _, tb, _, _, _ in prepped)
    E = max(E_j for *_, E_j in prepped)
    W = N * S
    t_stk = np.zeros((Lmax, W))
    w_stk = np.zeros((Lmax, W), dtype=np.int64)
    r_stk = np.zeros((Lmax, W))
    pads = []
    for k, (_, tb, time, w, _) in enumerate(prepped):
        pad = Lmax - time.shape[0]
        pads.append(pad)
        t_stk[pad:, k * S:(k + 1) * S] = time
        w_stk[pad:, k * S:(k + 1) * S] = w
        r_stk[pad:, k * S:(k + 1) * S] = tb.reshard

    # ---- stacked forward DP (the serial transition on N*S columns) ------
    ebins = np.arange(E + 1)
    cols = np.arange(W)
    shift_cache: Dict[bytes, Tuple[np.ndarray, np.ndarray]] = {}

    def shift_for(l: int):
        key = w_stk[l].tobytes()
        cached = shift_cache.get(key)
        if cached is None:
            idx = ebins[:, None] - w_stk[l][None, :]    # source bin per (e, c)
            invalid = (idx < 0).ravel()                 # also when w > E
            np.clip(idx, 0, E, out=idx)
            flat = (idx * W + cols[None, :]).ravel()
            cached = shift_cache[key] = (flat, invalid)
        return cached

    states: List[np.ndarray] = []
    C = None
    for l in range(Lmax):
        flat, invalid = shift_for(l)
        if l == 0:
            Cn = np.broadcast_to(t_stk[0][None, :], (E + 1, W)).copy()
        else:
            C3 = C.reshape(E + 1, N, S)
            if uniform and S == 2 * G:          # ckpt pairs: one binary ufunc
                red = np.minimum(C3[:, :, ::2], C3[:, :, 1::2])
            elif uniform:
                red = C3.reshape(E + 1, N, G, S // G).min(axis=3)
            elif contiguous:
                red = np.minimum.reduceat(C3, group_starts, axis=2)
            else:
                red = np.empty((E + 1, N, G))
                for g, members in enumerate(group_members):
                    red[:, :, g] = C3[:, :, members].min(axis=2)
            best_all = red.min(axis=2)                          # (E+1, N)
            best_grp = red[:, :, group_of]                      # (E+1, N, S)
            cross = (best_all[:, :, None]
                     + r_stk[l].reshape(N, S)[None, :, :])
            val = (np.minimum(best_grp, cross).reshape(E + 1, W)
                   + t_stk[l][None, :])
            Cn = val.ravel().take(flat).reshape(E + 1, W)
        Cn.ravel()[invalid] = INF
        states.append(Cn)
        C = Cn

    # ---- per-job serial finisher on views of the stacked states ---------
    for k, (i, tb, _, w, E_j) in enumerate(prepped):
        pad = pads[k]
        out[i] = _finish_budget_scan(
            [states[l][:, k * S:(k + 1) * S] for l in range(pad, Lmax)],
            w, strategies, group_of, group_members,
            tb.time_sync, tb.time_nosync, tb.mem_f, tb.mem_b, tb.mem_ms,
            tb.reshard, budgets, caps, bin_bytes, E_j)
    return out  # type: ignore[return-value]


# --------------------------------------------------------------------------
# Seed reference implementation (pre-vectorization), verbatim.
#
# Kept for two reasons: it is the baseline `benchmarks/bench_search.py`
# measures the tentpole speedup against, and the differential-test oracle
# the vectorized path must match bit-for-bit (tests/test_search_cache.py).
# --------------------------------------------------------------------------
def dp_search_stage_reference(
    specs: Sequence[LayerSpec],
    strategies: Sequence[Strategy],
    cost_model: CostModel,
    micro_batch_size: float,
    budget_bytes: float,
    *,
    quant_bytes: Optional[float] = None,
    inflight: float = 1,
    n_bins: int = 256,
    n_micro: int = 1,
) -> StageSearchResult:
    """Search the optimal per-layer strategies for one pipeline stage.

    The DP objective is the m-amortized per-micro-batch time
    ``t_nosync + (t_sync - t_nosync)/m`` — Eq. 9 charges the grad-sync cost
    only on the last of ``n_micro`` micro-batches, so optimizing raw sync
    time would mis-rank strategies with expensive gradient synchronization
    but cheap steady-state micro-batches.

    ``quant_bytes`` anchors the bin grid exactly as in ``dp_search_stage``
    (default: the budget itself — the seed behaviour).
    """
    L, S = len(specs), len(strategies)
    if L == 0:
        return StageSearchResult(True, 0.0, 0.0, [], 0.0, 0.0, 0.0)
    quant = float(quant_bytes) if quant_bytes is not None else budget_bytes

    # ---- per (layer, strategy) cost tables -----------------------------
    time = np.full((L, S), INF)       # DP objective (m-amortized)
    time_sync = np.full((L, S), INF)  # raw last-micro-batch time
    time_ns = np.full((L, S), INF)
    mem_f = np.zeros((L, S))
    mem_b = np.zeros((L, S))
    mem_ms = np.zeros((L, S))
    reshard = np.zeros((L, S))
    for l, spec in enumerate(specs):
        for j, s in enumerate(strategies):
            c = cost_model.layer_costs(spec, s, micro_batch_size, inflight=inflight)
            time[l, j] = c.time_nosync + (c.time - c.time_nosync) / max(1, n_micro)
            time_sync[l, j] = c.time
            time_ns[l, j] = c.time_nosync
            mem_f[l, j] = c.mem_f
            mem_b[l, j] = c.mem_b
            mem_ms[l, j] = c.mem_ms
            reshard[l, j] = cost_model.reshard_cost(spec, s, micro_batch_size)

    # quantized forward-memory weight of each (layer, strategy)
    bin_bytes = max(quant / n_bins, 1.0)
    w = np.ceil((mem_f + mem_ms) / bin_bytes).astype(np.int64)   # bins
    E = _bin_cap(budget_bytes, quant, bin_bytes, n_bins)

    # strategies grouped by identical levels (R == 0 within a group)
    level_key = {}
    group_of = np.zeros(S, dtype=np.int64)
    for j, s in enumerate(strategies):
        group_of[j] = level_key.setdefault(s.levels, len(level_key))
    G = len(level_key)
    group_members = [np.where(group_of == g)[0] for g in range(G)]

    # ---- DP over (budget_bin, strategy) ---------------------------------
    # C[e, j]: min time of layers processed so far using total fwd-mem <= e
    # bins, with the last layer using strategy j.
    C = np.full((E + 1, S), INF)
    parents = np.zeros((L, E + 1, S), dtype=np.int16)

    for l in range(L):
        Cn = np.full((E + 1, S), INF)
        if l == 0:
            for j in range(S):
                if w[0, j] <= E:
                    Cn[w[0, j]:, j] = time[0, j]
                    parents[0, :, j] = -1
        else:
            best_all = C.min(axis=1)                        # (E+1,)
            arg_all = C.argmin(axis=1)                      # (E+1,)
            best_grp = np.full((E + 1, G), INF)
            arg_grp = np.zeros((E + 1, G), dtype=np.int64)
            for g, members in enumerate(group_members):
                sub = C[:, members]
                k = sub.argmin(axis=1)
                best_grp[:, g] = sub[np.arange(E + 1), k]
                arg_grp[:, g] = members[k]
            for j in range(S):
                wj = w[l, j]
                if wj > E:
                    continue
                n_src = E + 1 - wj
                src = np.arange(0, n_src)
                same = best_grp[src, group_of[j]]
                cross = best_all[src] + reshard[l, j]
                take_same = same <= cross
                val = np.where(take_same, same, cross) + time[l, j]
                par = np.where(take_same, arg_grp[src, group_of[j]], arg_all[src])
                Cn[wj:, j] = val
                parents[l, wj:, j] = par
        C = Cn

    # ---- E_fwd sweep with exact E_all validation (Alg. 3) ---------------
    b_up = float(np.max(mem_b)) if L else 0.0    # paper's b_up (max over l, S)

    final_best = C.min(axis=1)                   # per budget bin
    final_arg = C.argmin(axis=1)

    def backtrack(e_bin: int) -> Optional[List[int]]:
        j = int(final_arg[e_bin])
        if not np.isfinite(final_best[e_bin]):
            return None
        chain = [0] * L
        e = e_bin
        for l in range(L - 1, -1, -1):
            chain[l] = j
            pj = int(parents[l, e, j])
            e = e - int(w[l, j])
            j = pj
        return chain

    for e_bin in range(E, -1, -1):
        if not np.isfinite(final_best[e_bin]):
            continue
        chain = backtrack(e_bin)
        if chain is None:
            continue
        e_all = _exact_e_all(mem_f, mem_b, mem_ms, chain)
        e_fwd_exact = float(sum(mem_f[l, chain[l]] + mem_ms[l, chain[l]]
                                for l in range(L)))
        if e_all <= budget_bytes or e_bin * bin_bytes <= budget_bytes - b_up:
            idx = np.arange(L)
            t_sync = float(time_sync[idx, chain].sum())
            t_nosync = float(time_ns[idx, chain].sum())
            # add reshard costs along the chain
            extra = 0.0
            for l in range(1, L):
                if strategies[chain[l]].levels != strategies[chain[l - 1]].levels:
                    extra += reshard[l, chain[l]]
            ms_total = float(mem_ms[idx, chain].sum())
            return StageSearchResult(
                feasible=True,
                time=t_sync + extra,
                time_nosync=t_nosync + extra,
                strategies=[strategies[j] for j in chain],
                e_all=e_all,
                e_fwd=e_fwd_exact,
                mem_states=ms_total,
            )

    return StageSearchResult(False, INF, INF, [], INF, INF, 0.0)
