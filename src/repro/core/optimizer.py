"""Parallelism optimization framework (paper §IV, Algorithms 1 & 2).

``GalvatronOptimizer`` implements:
  * Galvatron-Base (Alg. 1): batch-size sweep x PP-degree sweep x
    micro-batch choice x per-stage DP search, with an ideally (memory-)
    balanced pipeline partition;
  * Galvatron-BMW (Alg. 2): the bi-objective workload-balance refinement —
    queue of partitions seeded with the memory-balanced plan p_m, greedy
    boundary-layer adjustment, 3-criterion validation (Eq. 7/8 invariants).

Baseline modes (pure DP/SDP/TP/PP, DP+TP, DP+PP, DeepSpeed-3D-style fixed
strategies, no-CKPT variants) are expressed through the constructor knobs so
every row of the paper's tables is produced by this one class.
"""
from __future__ import annotations

import copy
import dataclasses
import os
import time as _time
from concurrent.futures import (ProcessPoolExecutor, ThreadPoolExecutor,
                                as_completed)
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost_model import (CostModel, CostModelConfig, CostTables,
                         _SP_INVALID_TIME, _drain_divisor,
                         pipeline_iter_time)
from .decision_tree import SearchSpace, construct_search_space
from .dp_search import (StageSearchResult, dp_search_stage_budgets,
                        dp_search_stage_budgets_batch)
from .frontier import (CandidateBound, DominanceFrontier, FrontierPoint,
                       PlanFrontier)
from .hardware import ClusterSpec
from .layerspec import LayerSpec
from .pipeline_balance import (PartitionEval, adjust_partition,
                               balance_degrees, inflight_microbatches,
                               memory_balanced_partition, stage_bounds,
                               time_balanced_partition,
                               validate_adjustment)
from .plan import ParallelPlan
from .strategy import EP, PARADIGMS, SP, Strategy, strategy_set_id

INF = float("inf")

#: legal values of ``OptimizerConfig.search_backend`` / ``--backend``
SEARCH_BACKENDS = ("serial", "threads", "processes", "vectorized")


def normalize_batch_grid(grid: Optional[Sequence[int]]
                         ) -> Optional[List[int]]:
    """Canonicalize a user-supplied batch grid: dedupe, sort ascending,
    validate entries.

    The Alg. 1 sweep's two-consecutive-OOM early stop assumes batch sizes
    arrive in ascending order — an unsorted grid would silently stop the
    sweep after two OOMs that are *not* adjacent on the size axis (or never
    stop at all), so the grid is canonicalized everywhere it enters the
    engine, not just in ``OptimizerConfig.__post_init__`` (callers mutate
    ``cfg.batch_grid`` after construction).

    Raises:
      ValueError: an entry is not a positive integer, or the grid is empty.
    """
    if grid is None:
        return None
    out = set()
    for b in grid:
        if (isinstance(b, (bool, str)) or not float(b).is_integer()):
            raise ValueError(
                f"batch_grid entries must be positive integers, got {b!r}")
        b = int(b)
        if b < 1:
            raise ValueError(
                f"batch_grid entries must be positive integers, got {b}")
        out.add(b)
    if not out:
        raise ValueError("batch_grid must not be empty (pass None for the "
                         "default geometric+linear grid)")
    return sorted(out)


@dataclasses.dataclass
class OptimizerConfig:
    paradigms: Sequence[str] = PARADIGMS      # which of DP/SDP/TP to search
    allow_ckpt: bool = True
    use_pp: bool = True                        # False => PP degree fixed to 1
    # sequence parallelism (ring attention) as a fourth searched paradigm;
    # opt-in: appends "sp" to ``paradigms`` so the decision tree grows the
    # SP branch (the paper-count leaf sets stay untouched by default)
    use_sp: bool = False
    max_sp: Optional[int] = None
    # expert parallelism (sharded MoE experts + all-to-all dispatch) as a
    # fifth searched paradigm; opt-in exactly like ``use_sp`` — appends
    # "ep" to ``paradigms``, so default searches stay bit-identical
    use_ep: bool = False
    max_ep: Optional[int] = None
    bi_objective: bool = True                  # BMW partition refinement
    schedule: str = "1f1b"          # or "gpipe" / "1f1b-interleaved" / "zb-h1"
    # pipeline-schedule search axis: candidate schedule names swept per
    # (B, P); None => just (schedule,), the pre-schedule-subsystem behaviour
    schedules: Optional[Sequence[str]] = None
    # virtual-chunk degrees V tried for "1f1b-interleaved" candidates
    vpp_candidates: Sequence[int] = (2, 4)
    max_pp: Optional[int] = None
    max_tp: Optional[int] = None
    # batch-size exploration grid (Alg. 1 line 2 increments B; we use a
    # geometric+linear grid and stop after everything OOMs)
    batch_grid: Optional[Sequence[int]] = None
    max_batch: int = 4096
    micro_candidates: int = 8                  # how many micro-batch counts to try
    n_bins: int = 256                          # DP memory quantization
    fixed_strategy: Optional[Strategy] = None  # pure-baseline mode
    fixed_pp: Optional[int] = None
    max_adjust_iters: int = 32                 # BMW queue budget per (B, P)
    # search-engine speed knobs (both default on; turning them off recovers
    # the original per-candidate / per-pair behaviour for benchmarking)
    enable_stage_cache: bool = True            # memoize dp_search_stage results
    vectorized_cost: bool = True               # batched (L,S) cost tables
    # memory-budget constraint in bytes; None => cluster.budget().  Distinct
    # from the DP quantization grid: two searches are comparable
    # bin-for-bin only when their ``quant_bytes`` coincide (DESIGN.md §6)
    budget_bytes: Optional[float] = None
    # quantization-grid anchor; None => max of the active budget axis
    # (single-budget searches then quantize on their own budget — the
    # pre-frontier behaviour)
    quant_bytes: Optional[float] = None
    # -- cluster-scale engine knobs ------------------------------------
    # how the outer (B, P) candidates execute: "serial" (the oracle),
    # "threads" / "processes" (pooled fan-out), or "vectorized" (all of a
    # partition's stage DPs batched into one stacked NumPy evaluation).
    # Every backend returns plans byte-identical to "serial".
    search_backend: str = "serial"
    # pool size for threads/processes (None => one worker per core)
    jobs: Optional[int] = None
    # frontier-guided batch-axis pruning: skip (B, P) candidates whose
    # certified optimistic bound is dominated or provably over-budget
    # (needs vectorized_cost for the bound tables; plans stay identical)
    prune_batch_axis: bool = False

    def __post_init__(self):
        if self.search_backend not in SEARCH_BACKENDS:
            raise ValueError(
                f"search_backend must be one of {SEARCH_BACKENDS}, "
                f"got {self.search_backend!r}")
        if self.search_backend == "vectorized" and not self.vectorized_cost:
            raise ValueError(
                "search_backend='vectorized' batches the stage DP over the "
                "(L, S) cost tables and therefore needs vectorized_cost=True")
        if self.jobs is not None and int(self.jobs) < 1:
            raise ValueError(f"jobs must be a positive integer, got {self.jobs}")
        self.batch_grid = normalize_batch_grid(self.batch_grid)


def default_batch_grid(max_batch: int) -> List[int]:
    grid, b = [], 8
    while b <= max_batch:
        grid.append(b)
        b = b + max(8, b // 2)
    return grid


_MISS = object()


class _ShardCache(dict):
    """Worker-local memo shard (parallel sweep, DESIGN.md §6).

    Reads fall through to the shared base cache (filled before the pool
    fanned out, never mutated while workers run); writes stay local and are
    merged back into the base once the worker's (B, P) candidate is done.
    Iteration / ``update()`` expose only the local writes, which is exactly
    what the merge wants.
    """

    def __init__(self, base: dict):
        super().__init__()
        self._base = base

    def get(self, key, default=None):
        v = super().get(key, _MISS)
        if v is not _MISS:
            return v
        return self._base.get(key, default)


class GalvatronOptimizer:
    def __init__(self, specs: Sequence[LayerSpec], cluster: ClusterSpec,
                 config: Optional[OptimizerConfig] = None,
                 cost_config: Optional[CostModelConfig] = None,
                 profiled_times: Optional[Dict[str, float]] = None):
        self.specs = list(specs)
        self.cluster = cluster
        self.cfg = config or OptimizerConfig()
        self._cost_config = cost_config      # kept for process-pool workers
        self.cost = CostModel(cluster, cost_config,
                              profiled_times=profiled_times)
        paradigms = tuple(self.cfg.paradigms)
        if self.cfg.use_sp and SP not in paradigms:
            paradigms = paradigms + (SP,)
        if self.cfg.use_ep and EP not in paradigms:
            paradigms = paradigms + (EP,)
        self.search_space = construct_search_space(
            cluster.n_devices,
            paradigms=paradigms,
            allow_ckpt=self.cfg.allow_ckpt,
            max_pp=(1 if not self.cfg.use_pp else self.cfg.max_pp),
            max_tp=self.cfg.max_tp,
            max_sp=self.cfg.max_sp,
            max_ep=self.cfg.max_ep,
        )
        self.stats: Dict[str, float] = {
            "stage_searches": 0,        # dp_search_stage requests
            "stage_cache_hits": 0,
            "stage_cache_misses": 0,
            "table_builds": 0,          # full-model (L,S) cost-table builds
            "table_hits": 0,
            "bound_evals": 0,           # (B, P) optimistic-bound builds
            "bp_candidates": 0,         # (B, P) outer candidates considered
            "bp_pruned_infeasible": 0,  # skipped: cannot fit any live budget
            "bp_pruned_dominated": 0,   # deferred: cannot beat incumbent
            "bp_forced": 0,             # deferred candidates run anyway (OOM
                                        # bookkeeping; see _sweep_axis)
            "search_seconds": 0.0,
        }
        # memo caches: stage-search results keyed on (layer-range, B_m,
        # inflight, n_micro, strategy-set id) and full-model cost tables
        # keyed on (strategy-set id, B_m, inflight).  budget / n_bins are
        # fixed per optimizer instance, so they are deliberately not part
        # of the keys; the schedule/vpp axis enters stage costs only via
        # ``inflight``, which IS in the key — so the schedule sweep shares
        # entries wherever in-flight counts coincide (e.g. m <= P - i).
        # The caches deliberately persist across optimize() calls on one
        # instance (re-searches after a batch-grid or schedule-axis tweak
        # are mostly hits); ``clear_cache()`` is the escape hatch.
        self._stage_cache: Dict[Tuple, Tuple[StageSearchResult, ...]] = {}
        self._table_cache: Dict[Tuple, CostTables] = {}
        self._ref_cache: Dict[Tuple, Tuple[np.ndarray, np.ndarray]] = {}
        self._part_cache: Dict[Tuple, Tuple[List[int], List[int]]] = {}
        # (B, P) -> CandidateBound for the pruning frontier; budget-
        # independent (bounds compare against the axis at classify time)
        self._bound_cache: Dict[Tuple[int, int], CandidateBound] = {}
        # True only while _sweep_axis runs the "vectorized" backend:
        # _eval_partition then routes stage searches through the stacked DP
        self._batch_eval = False
        # active budget axis: every stage search returns one result per
        # budget (optimize() runs a 1-point axis; sweep_budgets() the full
        # frontier).  The quantization grid is pinned per axis so results
        # are comparable bin-for-bin across its budgets.
        self._budget_axis: Tuple[float, ...] = (self._single_budget(),)
        self._quant: float = (float(self.cfg.quant_bytes)
                              if self.cfg.quant_bytes is not None
                              else max(self._budget_axis))
        # both speed knobs off = seed-faithful baseline (used by
        # benchmarks/bench_search.py): no memoization anywhere
        self._seed_mode = (not self.cfg.enable_stage_cache
                           and not self.cfg.vectorized_cost)
        # layer-content signatures: stage-search results depend on the layer
        # *workloads* in a range, not their positions, so ranges covering
        # identical layer runs (ubiquitous in homogeneous transformer
        # stacks) share one cache entry.  The name enters costs only via the
        # profiled-time lookup, so it is replaced by that lookup's value.
        sig_of: Dict[Tuple, int] = {}
        self._layer_sig = tuple(
            sig_of.setdefault(
                (dataclasses.replace(sp, name=""),
                 self.cost.profiled_times.get(sp.name)),
                len(sig_of))
            for sp in self.specs)

    # ------------------------------------------------------------------
    # budget axis
    # ------------------------------------------------------------------
    def _single_budget(self) -> float:
        return (float(self.cfg.budget_bytes)
                if self.cfg.budget_bytes is not None
                else float(self.cluster.budget()))

    def _set_budget_axis(self, axis: Tuple[float, ...]) -> None:
        """Point the engine at a (sorted) budget axis.

        Stage-search memo entries are axis-shaped (one result per budget),
        so changing the axis drops only ``_stage_cache``; the budget-
        independent caches (cost tables, reference costs, seed partitions)
        survive — that is the incremental-re-search path when only the
        budget changes.
        """
        quant = (float(self.cfg.quant_bytes)
                 if self.cfg.quant_bytes is not None else max(axis))
        if (axis, quant) != (self._budget_axis, self._quant):
            self._stage_cache.clear()
            self._budget_axis, self._quant = axis, quant

    # ------------------------------------------------------------------
    # layer-level reference costs (used for initial partitions)
    # ------------------------------------------------------------------
    def _reference_layer_costs(self, micro_batch: float,
                               group: int) -> Tuple[np.ndarray, np.ndarray]:
        """Per-layer (time, act-memory) under a cheap reference strategy —
        pure data parallel over the stage group (paper's load-balancing
        guideline: #layers/params/exec-time)."""
        key = (micro_batch, group)
        cached = None if self._seed_mode else self._ref_cache.get(key)
        if cached is not None:
            return cached
        ref = Strategy((("dp", group),)) if group > 1 else Strategy(())
        if self.cfg.vectorized_cost:
            tb = self.cost.layer_cost_tables(self.specs, [ref], micro_batch)
            t = tb.time_nosync[:, 0].copy()
            m = (tb.mem_f + tb.mem_ms)[:, 0]
        else:
            t = np.zeros(len(self.specs))
            m = np.zeros(len(self.specs))
            for i, s in enumerate(self.specs):
                c = self.cost.layer_costs(s, ref, micro_batch)
                t[i] = c.time_nosync
                m[i] = c.mem_f + c.mem_ms
        self._ref_cache[key] = (t, m)
        return t, m

    # ------------------------------------------------------------------
    # memoized single-stage search
    # ------------------------------------------------------------------
    def _full_tables(self, strategies: List[Strategy], sid: int,
                     B_m: float, inflight: int) -> Optional[CostTables]:
        """Whole-model (L, S) cost tables, cached per (B_m, inflight) — every
        stage search over any layer range row-slices the same arrays."""
        if not self.cfg.vectorized_cost:
            return None
        key = (sid, B_m, inflight)
        tb = self._table_cache.get(key)
        if tb is None:
            # inflight multiplies exactly one table entry — the forward
            # activation stash mem_f is linear in it (the cost model keeps
            # everything else inflight-independent) — so only the inflight=1
            # base is ever built and others are derived by scaling
            base = self._table_cache.get((sid, B_m, 1))
            if base is None:
                base = self.cost.layer_cost_tables(self.specs, strategies,
                                                   B_m, inflight=1)
                self._table_cache[(sid, B_m, 1)] = base
                self.stats["table_builds"] += 1
            else:
                self.stats["table_hits"] += 1
            tb = (base if inflight == 1 else
                  dataclasses.replace(base, mem_f=base.mem_f * inflight))
            self._table_cache[key] = tb
        else:
            self.stats["table_hits"] += 1
        return tb

    def _stage_search(self, a: int, b: int, strategies: List[Strategy],
                      sid: int, B_m: float, inflight: int,
                      n_micro: int) -> Tuple[StageSearchResult, ...]:
        """Budget-axis stage search over specs[a:b], memoized — one result
        per budget on the active axis from a single forward DP.

        The BMW adjustment queue mostly re-evaluates identical layer ranges
        (a one-layer boundary shift changes only the two adjacent stages),
        the p_t / p_m seed partitions overlap heavily, and every budget on
        the axis shares one memo entry — so the cache turns most of the
        O(P·K) work per candidate into dict lookups.
        """
        self.stats["stage_searches"] += 1
        key = (self._layer_sig[a:b], B_m, inflight, n_micro, sid)
        if self.cfg.enable_stage_cache:
            res = self._stage_cache.get(key)
            if res is not None:
                self.stats["stage_cache_hits"] += 1
                return res
            self.stats["stage_cache_misses"] += 1
        tables = self._full_tables(strategies, sid, B_m, inflight)
        res = tuple(dp_search_stage_budgets(
            self.specs[a:b], strategies, self.cost, B_m,
            self._budget_axis, quant_bytes=self._quant, inflight=inflight,
            n_bins=self.cfg.n_bins, n_micro=n_micro,
            tables=tables.rows(a, b) if tables is not None else None,
            use_tables=self.cfg.vectorized_cost))
        if self.cfg.enable_stage_cache:
            self._stage_cache[key] = res
        return res

    def _stage_search_batch(self, reqs: Sequence[Tuple[int, int, int]],
                            strategies: List[Strategy], sid: int, B_m: float,
                            n_micro: int
                            ) -> List[Tuple[StageSearchResult, ...]]:
        """All of a partition's stage searches as ONE stacked DP.

        ``reqs`` is ``[(a, b, inflight)]`` — one entry per pipeline stage.
        Cache lookups, hit/miss telemetry and writes mirror the serial
        per-stage loop exactly: the first in-batch occurrence of a key is
        the miss, later duplicates are the hits the serial loop would have
        scored against the first occurrence's fresh memo write.  Results
        are byte-identical to per-request :meth:`_stage_search` calls
        (``dp_search_stage_budgets_batch``'s front-padding proof).
        """
        out: List[Optional[Tuple[StageSearchResult, ...]]] = [None] * len(reqs)
        pending: Dict[Tuple, List[int]] = {}   # key -> out indices wanting it
        job_keys: List[Tuple] = []
        job_reqs: List[Tuple[int, int, int]] = []
        for i, (a, b, infl) in enumerate(reqs):
            self.stats["stage_searches"] += 1
            key = (self._layer_sig[a:b], B_m, infl, n_micro, sid)
            if self.cfg.enable_stage_cache:
                res = self._stage_cache.get(key)
                if res is not None:
                    self.stats["stage_cache_hits"] += 1
                    out[i] = res
                    continue
                if key in pending:
                    self.stats["stage_cache_hits"] += 1
                    pending[key].append(i)
                    continue
                self.stats["stage_cache_misses"] += 1
            want = pending.get(key)
            if want is not None:     # cache-disabled duplicate: share the job
                want.append(i)
                continue
            pending[key] = [i]
            job_keys.append(key)
            job_reqs.append((a, b, infl))
        if job_keys:
            jobs = []
            for a, b, infl in job_reqs:
                tb = self._full_tables(strategies, sid, B_m, infl)
                jobs.append((tb.rows(a, b), n_micro))
            batch = dp_search_stage_budgets_batch(
                jobs, strategies, self._budget_axis,
                quant_bytes=self._quant, n_bins=self.cfg.n_bins)
            for key, res_list in zip(job_keys, batch):
                res = tuple(res_list)
                if self.cfg.enable_stage_cache:
                    self._stage_cache[key] = res
                for i in pending[key]:
                    out[i] = res
        return out

    def _strategies_for(self, P: int) -> Tuple[List[Strategy], int]:
        strategies = self.search_space.strategies(P)
        if self.cfg.fixed_strategy is not None:
            strategies = [self.cfg.fixed_strategy]
        return strategies, strategy_set_id(strategies)

    def clear_cache(self) -> None:
        """Drop every memo cache (stage searches, cost tables, reference
        costs, seed partitions, pruning bounds, and the cost model's
        collective-coefficient memo) and zero the telemetry counters.  The caches
        persist across ``optimize()`` calls by design; call this when the
        instance's cost inputs change under it (e.g. mutated
        ``profiled_times``).  A cleared optimizer behaves exactly like a
        freshly constructed one: same plan, same cold-start stats."""
        self._stage_cache.clear()
        self._table_cache.clear()
        self._ref_cache.clear()
        self._part_cache.clear()
        self._bound_cache.clear()
        self.cost.clear_cache()
        for k in self.stats:
            self.stats[k] = 0.0 if k == "search_seconds" else 0

    # ------------------------------------------------------------------
    # pipeline-schedule search axis
    # ------------------------------------------------------------------
    def _schedule_candidates(self, P: int, m: int) -> List[Tuple[str, int]]:
        """(schedule, vpp_degree) candidates swept per (B, P, m).

        ``1f1b-interleaved`` expands over ``cfg.vpp_candidates`` and is
        dropped where it degenerates (P == 1), cannot be laid out
        (P·V > L), or has a ragged last micro-batch group (m % P != 0 —
        the compiled program's bubble then exceeds the analytic
        ``(P-1)/(m·V)`` term, so the model would oversell it);
        ``zb-h1`` is dropped at P == 1 (no bubble to fill — it would
        only add the deferred-W memory term over plain 1f1b) and when
        m < P (the compiled program's bubble exceeds the analytic
        ``(P-1)/(3m)``); single-chunk schedules carry V = 1.
        """
        names = (tuple(self.cfg.schedules) if self.cfg.schedules
                 else (self.cfg.schedule,))
        out: List[Tuple[str, int]] = []
        for name in names:
            if name == "1f1b-interleaved":
                if P <= 1 or m % P:
                    continue
                for v in self.cfg.vpp_candidates:
                    v = int(v)
                    if v > 1 and P * v <= len(self.specs):
                        out.append((name, v))
            elif name == "zb-h1":
                if P > 1 and m >= P:
                    out.append((name, 1))
            else:
                out.append((name, 1))
        if not out:     # zb/interleaved-only request on a degenerate (B, P, m)
            out.append(("1f1b", 1))
        return out

    # ------------------------------------------------------------------
    # per-(B, P, m, partition) evaluation == Galvatron_Search (Alg. 1 l.17)
    # ------------------------------------------------------------------
    def _eval_partition(self, partition: Sequence[int], B: int, m: int,
                        P: int, strategies: Optional[List[Strategy]] = None,
                        sid: Optional[int] = None, schedule: Optional[str] = None,
                        vpp: int = 1,
                        ) -> List[Tuple[float, PartitionEval, List[Strategy]]]:
        """Evaluate one partition on every budget of the active axis.

        Returns one ``(iter_time, PartitionEval, strategies)`` triple per
        budget; the per-stage DP runs once (budget axis inside
        ``_stage_search``), the per-budget assembly here is pure Python.
        """
        B_m = B / m
        schedule = schedule or self.cfg.schedule
        if strategies is None or sid is None:
            strategies, sid = self._strategies_for(P)
        K = len(self._budget_axis)
        if vpp > 1 and min(partition) < vpp:
            # a stage needs >= V layers to be cut into V virtual chunks
            ev = PartitionEval(list(partition), [INF] * P, [INF] * P,
                               [INF] * P, False)
            bad = (INF, ev, [Strategy(())] * sum(partition))
            return [bad] * K
        bounds = stage_bounds(partition)
        infls = [inflight_microbatches(i, P, m, schedule, vpp)
                 for i in range(P)]
        if self._batch_eval:
            per_stage = self._stage_search_batch(
                [(a, b, infl) for (a, b), infl in zip(bounds, infls)],
                strategies, sid, B_m, m)
        else:
            per_stage = [self._stage_search(a, b, strategies, sid, B_m,
                                            infl, m)
                         for (a, b), infl in zip(bounds, infls)]
        out: List[Tuple[float, PartitionEval, List[Strategy]]] = []
        for k in range(K):
            stage_times, stage_ns, stage_mems, all_strats = [], [], [], []
            feasible = True
            for i, (a, b) in enumerate(bounds):
                res = per_stage[i][k]
                if not res.feasible:
                    feasible = False
                    stage_times.append(INF)
                    stage_ns.append(INF)
                    stage_mems.append(INF)
                    all_strats.extend([Strategy(())] * (b - a))
                    continue
                p2p = 0.0
                if P > 1 and b < len(self.specs):
                    dd = res.strategies[-1].data_degree if res.strategies else 1
                    # interleaved: each micro-batch crosses every device
                    # boundary V times (once per virtual chunk)
                    p2p = vpp * self.cost.p2p_cost(self.specs[b - 1], B_m, dd)
                stage_times.append(res.time + p2p)
                stage_ns.append(res.time_nosync + p2p)
                stage_mems.append(res.e_all)
                all_strats.extend(res.strategies)
            ev = PartitionEval(list(partition), stage_times, stage_ns,
                               stage_mems, feasible)
            if not feasible:
                out.append((INF, ev, all_strats))
                continue
            # Eq. 9 (generalized over V and the ZB backward split): steady
            # state paced by the slowest no-sync stage; the drain's bubble
            # term shrinks by 1/V (interleaved) or 1/3 (zb-h1 W refill)
            out.append((pipeline_iter_time(stage_times, stage_ns, m, vpp,
                                           schedule=schedule),
                        ev, all_strats))
        return out

    # ------------------------------------------------------------------
    def _micro_candidates(self, B: int, P: int) -> List[int]:
        cands = []
        m = max(1, P)  # at least P micro-batches to fill a pipeline
        while m <= B and len(cands) < self.cfg.micro_candidates:
            if B % m == 0:
                cands.append(m)
            m *= 2
        if not cands:
            cands = [B]
        return cands

    # ------------------------------------------------------------------
    # frontier-guided batch-axis pruning (optimistic candidate bounds)
    # ------------------------------------------------------------------
    def _max_drain_divisor(self) -> float:
        """Largest bubble-shrink factor any configured schedule can reach —
        the sound (most optimistic) divisor for the pruning bound's drain
        term (``_drain_divisor``: 3 for zb-h1, V for interleaved)."""
        names = (tuple(self.cfg.schedules) if self.cfg.schedules
                 else (self.cfg.schedule,))
        div = 1.0
        for name in names:
            if name == "1f1b-interleaved":
                vs = [int(v) for v in self.cfg.vpp_candidates if int(v) > 1]
                if vs:
                    div = max(div, _drain_divisor(max(vs), name))
            else:
                div = max(div, _drain_divisor(1, name))
        return div

    def _candidate_bound(self, B: int, P: int) -> CandidateBound:
        """Certified optimistic bounds for the (B, P) outer candidate,
        cached per pair (budget-independent).

        Throughput upper bound: for any partition, schedule (divisor
        ``div <= div_max``) and micro-batch count ``m``, the iteration time
        satisfies ``T >= (m-1)·max(Cns) + max(Cns) + (ΣCns - max(Cns))/div
        >= m·ΣCns/P + (P-1)·t_min/div_max`` — sync/p2p/reshard terms only
        add, ``max >= mean``, and each of the other ``P-1`` stages holds at
        least one layer costing at least ``t_min`` (the cheapest layer's
        cheapest strategy's no-sync time).  With ``ΣCns >= Tns_min`` (sum
        of per-layer minima) and maximizing ``B / lb`` over the candidate
        micro-batch counts, no plan of this candidate can beat the result.

        Memory lower bound: every stage's exact DP memory ``e_all`` is the
        sum of its layers' ``mem_f·inflight + mem_ms`` for the chosen
        strategies, and ``inflight >= 1``, so the peak stage memory is at
        least ``max(Σ_l min_s mem_ls / P, max_l min_s mem_ls)``; minimized
        over ``m`` (``mem`` depends on ``B/m``).  The DP's acceptance
        conditions each imply an exact fit (``e_all <= budget``), so
        ``mem_lower > budget`` proves the serial search returns no plan.
        """
        bd = self._bound_cache.get((B, P))
        if bd is not None:
            return bd
        self.stats["bound_evals"] += 1
        strategies, sid = self._strategies_for(P)
        div_max = self._max_drain_divisor()
        tpt_ub, mem_lb = 0.0, INF
        for m in self._micro_candidates(B, P):
            tb = self._full_tables(strategies, sid, B / m, 1)
            tmin = tb.time_nosync.min(axis=1)              # (L,)
            iter_lb = m * float(tmin.sum()) / P
            if P > 1:
                iter_lb += (P - 1) * float(tmin.min()) / div_max
            tpt_ub = max(tpt_ub, B / iter_lb if iter_lb > 0.0 else INF)
            mem_vec = (tb.mem_f + tb.mem_ms).min(axis=1)   # (L,)
            mem_lb = min(mem_lb,
                         max(float(mem_vec.sum()) / P, float(mem_vec.max())))
        bd = CandidateBound(tpt_upper=tpt_ub, mem_lower=mem_lb)
        self._bound_cache[(B, P)] = bd
        return bd

    # ------------------------------------------------------------------
    def _search_pp(self, B: int, P: int) -> Optional[List[Optional[ParallelPlan]]]:
        """Best plan per budget for one (batch, PP degree): Alg. 1 inner
        body crossed with the schedule × vpp axis, plus the Alg. 2
        partition-adjustment queue when bi_objective is on.

        The Alg. 2 queue trajectory depends on the budget (criterion (2) of
        the validation, and which strategies the DP picked), so each budget
        runs its *own* cheap control-flow queue — but all of them draw from
        the same memoized budget-axis stage searches, so the expensive work
        is shared.  A 1-point axis reproduces the pre-frontier serial
        search move for move.
        """
        L = len(self.specs)
        if P > L:
            return None
        K = len(self._budget_axis)
        best: List[Optional[ParallelPlan]] = [None] * K
        strategies, sid = self._strategies_for(P)
        for m in self._micro_candidates(B, P):
          for sched, vpp in self._schedule_candidates(P, m):
            B_m = B / m
            group = self.cluster.n_devices // P
            # per-(m, sched, vpp) eval memo: the per-budget queues revisit
            # mostly the same partitions; the underlying stage searches are
            # already cached, this just skips the per-budget re-assembly
            evals: Dict[Tuple[int, ...],
                        List[Tuple[float, PartitionEval, List[Strategy]]]] = {}

            def ev_of(part):
                pk = tuple(part)
                r = evals.get(pk)
                if r is None:
                    r = self._eval_partition(part, B, m, P, strategies,
                                             sid, sched, vpp)
                    evals[pk] = r
                return r

            if P == 1:
                partitions = [[L]]
                pt_max_mems = [INF] * K
            else:
                pkey = (B_m, group, P, m, sched, vpp)
                seeds = None if self._seed_mode else self._part_cache.get(pkey)
                if seeds is None:
                    t_ref, m_ref = self._reference_layer_costs(B_m, group)
                    seeds = (
                        memory_balanced_partition(m_ref, P, m, sched, vpp),
                        time_balanced_partition(t_ref, P),
                    )
                    self._part_cache[pkey] = seeds
                p_m, p_t = seeds
                # pt_max_mem: criterion (3) reference — max stage memory
                # under the time-balanced partition (per budget)
                ev_ts = ev_of(p_t)
                pt_max_mems = [max(ev_t.stage_mems) if ev_t.feasible else INF
                               for _, ev_t, _ in ev_ts]
                # Alg. 2 seeds the queue with p_m and adjusts toward p_t;
                # p_t itself is also evaluated (the optimum lies between the
                # two extremes, Eq. 7).
                partitions = [p_m, p_t]
            for k, budget in enumerate(self._budget_axis):
                queue = [list(p) for p in partitions]
                seen = {tuple(p) for p in queue}
                iters = 0
                while queue and iters <= self.cfg.max_adjust_iters:
                    part = queue.pop(0)
                    iters += 1
                    t, ev, strats = ev_of(part)[k]
                    # a plan priced at the invalid-strategy poison time is
                    # one the runtime cannot execute (SP-inapplicable layer
                    # or sub-physical per-device batch) — not feasible
                    if ev.feasible and t < _SP_INVALID_TIME:
                        if best[k] is None or B / t > best[k].est_throughput:
                            a_t, a_m = balance_degrees(ev.stage_times,
                                                       ev.stage_mems)
                            best[k] = ParallelPlan(
                                n_devices=self.cluster.n_devices,
                                pp_degree=P, partition=list(part),
                                strategies=strats, global_batch=B, n_micro=m,
                                schedule=sched, vpp_degree=vpp,
                                sp_degree=max((s.sp for s in strats),
                                              default=1),
                                seq_len=max((sp.seq_len
                                             for sp in self.specs),
                                            default=0),
                                ep_degree=max((s.ep for s in strats),
                                              default=1),
                                est_iter_time=t, est_throughput=B / t,
                                est_stage_mem=ev.stage_mems,
                                alpha_t=a_t, alpha_m=a_m)
                        if self.cfg.bi_objective and P > 1:
                            for cand in adjust_partition(part, ev.stage_times):
                                key = tuple(cand)
                                if key in seen:
                                    continue
                                t2, ev2, _ = ev_of(cand)[k]
                                if validate_adjustment(
                                        ev2, max(ev.stage_times),
                                        budget, pt_max_mems[k]):
                                    seen.add(key)
                                    queue.append(cand)
        return best

    # ------------------------------------------------------------------
    def optimize(self, verbose: bool = False) -> Optional[ParallelPlan]:
        """Alg. 1 / Alg. 2 top level: sweep batch sizes, keep best Tpt.

        Repeated calls on one instance reuse the memo caches (hit/miss
        telemetry keeps accumulating in ``self.stats`` and is snapshotted
        into the returned plan's ``search_stats``); ``clear_cache()``
        resets them.

        Args:
          verbose: print every improving (B, P) candidate as it is found.

        Returns:
          The highest-predicted-throughput :class:`ParallelPlan` under
          the configured memory budget (``OptimizerConfig.budget_bytes``,
          default the cluster's), or ``None`` when every candidate OOMs.
        """
        return self._sweep_axis((self._single_budget(),),
                                verbose=verbose)[0]

    def sweep_budgets(self, budgets: Sequence[float], *,
                      parallel: bool = False,
                      max_workers: Optional[int] = None,
                      backend: Optional[str] = None,
                      verbose: bool = False) -> PlanFrontier:
        """Compute the throughput-vs-memory frontier over ``budgets`` in
        ~one search (DESIGN.md §6).

        The stage DP runs once per memo key with a budget *axis* and the
        budget-independent caches (cost tables, reference costs, seed
        partitions) are shared, so a K-point sweep costs close to a single
        ``optimize()`` instead of K of them.  Each budget's plan is
        byte-identical to a serial ``optimize()`` at that budget on the
        same quantization grid (``quant_bytes = max(budgets)`` unless
        pinned in the config).

        Grid-resolution tradeoff: the DP resolves memory in
        ``quant_bytes / n_bins`` steps, so on a wide sweep the small
        budgets are quantized more coarsely than a dedicated search at
        that budget would be (slightly worse plans, possibly a spurious
        OOM right at the feasibility edge).  Pin
        ``cfg.quant_bytes = min(budgets)`` to give every point
        dedicated-search resolution — the larger budgets' scans then span
        proportionally more bins, costing more DP time.

        ``backend`` selects how the independent (B, P) outer candidates
        execute — ``"threads"`` / ``"processes"`` fan them over a pool
        (workers write to private cache shards merged back with their
        hit/miss telemetry), ``"vectorized"`` batches each partition's
        stage DPs into one stacked NumPy evaluation.  Every backend's
        plans are byte-identical to the ``"serial"`` oracle, in any
        interleaving.  ``parallel=True`` is the PR-4-era spelling of
        ``backend="threads"``.

        Args:
          budgets: memory budgets in bytes (deduplicated and sorted).
          parallel: fan (B, P) candidates over a thread pool.
          max_workers: pool size for pooled backends (default:
            ``cfg.jobs``, else one per core).
          backend: execution backend override (default:
            ``cfg.search_backend``, or ``"threads"`` when ``parallel``).
          verbose: print every improving (B, P, budget) candidate.

        Returns:
          A :class:`~repro.core.frontier.PlanFrontier` with one
          (budget, plan, predicted throughput) point per budget —
          ``plan`` is ``None`` where everything OOMs — plus the
          quantization grid and aggregated search telemetry.

        Raises:
          ValueError: ``budgets`` is empty.
        """
        axis = tuple(sorted({float(b) for b in budgets}))
        if not axis:
            raise ValueError("sweep_budgets needs at least one budget")
        plans = self._sweep_axis(axis, verbose=verbose, parallel=parallel,
                                 max_workers=max_workers, backend=backend)
        points = [FrontierPoint(budget_bytes=b, plan=p,
                                predicted_throughput=(p.est_throughput
                                                      if p else 0.0))
                  for b, p in zip(axis, plans)]
        return PlanFrontier(points=points, quant_bytes=self._quant,
                            search_stats=dict(self.stats))

    def _sweep_axis(self, axis: Tuple[float, ...], *, verbose: bool = False,
                    parallel: bool = False,
                    max_workers: Optional[int] = None,
                    backend: Optional[str] = None,
                    ) -> List[Optional[ParallelPlan]]:
        """Shared Alg. 1 outer loop over a budget axis: per-budget best
        plans, with the per-budget OOM early-stop of the serial search (a
        budget that OOMed at two consecutive batch sizes stops growing B —
        exactly when its serial counterpart would have).

        The candidate execution backend (serial / threads / processes /
        vectorized) and the dominance-frontier pruning are both plan-
        preserving: every path below returns plans byte-identical to the
        serial oracle.  Pruning soundness rests on three pillars:

        * *infeasible* skips are final — ``CandidateBound.mem_lower``
          exceeding a budget proves the serial search would have found no
          plan there, so skipping contributes exactly nothing;
        * *dominated* candidates cannot displace the incumbent (the bound
          certifies their best throughput cannot beat a best that only
          grows, and the sweep improves on strict ``>`` only), but their
          *feasibility* still feeds the two-consecutive-OOM stop — so they
          are deferred, and **forced** to run whenever a budget they were
          deferred for found nothing else this round;
        * forced candidates merge after the live ones, which is safe
          because their plans provably never update ``best`` (order only
          matters for ``best``; ``found`` is an order-free OR).
        """
        t0 = _time.time()
        backend = backend or ("threads" if parallel
                              else self.cfg.search_backend)
        if backend not in SEARCH_BACKENDS:
            raise ValueError(f"unknown search backend {backend!r}; "
                             f"expected one of {SEARCH_BACKENDS}")
        self._set_budget_axis(axis)
        K = len(axis)
        grid = list(normalize_batch_grid(self.cfg.batch_grid)
                    or default_batch_grid(self.cfg.max_batch))
        pp_degrees = [P for P in ([self.cfg.fixed_pp] if self.cfg.fixed_pp
                                  else sorted(self.search_space.per_pp))
                      if P is not None and self.cluster.n_devices % P == 0]
        prune = bool(self.cfg.prune_batch_axis and self.cfg.vectorized_cost)
        frontier = DominanceFrontier(axis) if prune else None
        pool = (_CandidatePool(self, backend, max_workers or self.cfg.jobs)
                if backend in ("threads", "processes") else None)
        self._batch_eval = (backend == "vectorized")
        best: List[Optional[ParallelPlan]] = [None] * K
        active = [True] * K
        try:
            eager: Dict[Tuple[int, int],
                        Optional[List[Optional[ParallelPlan]]]] = {}
            if pool is not None and not prune:
                # eager full fan-out: every (B, P) computed up front (even
                # past a budget's OOM stopping point — the merge below
                # re-applies the serial stopping rule, so nothing changes)
                eager = pool.run_many([(B, P) for B in grid
                                       for P in pp_degrees])
            consecutive_oom = [0] * K
            L = len(self.specs)
            for B in grid:
                if not any(active):
                    break
                found = [False] * K

                def merge(P, plans, B=B, found=found):
                    if plans is None:
                        return
                    for k in range(K):
                        if not active[k] or plans[k] is None:
                            continue
                        found[k] = True
                        if frontier is not None:
                            frontier.observe(k, plans[k].est_throughput)
                        if (best[k] is None or plans[k].est_throughput
                                > best[k].est_throughput):
                            best[k] = plans[k]
                            if verbose:
                                print(
                                    f"[B={B} P={P} "
                                    f"budget={axis[k]/2**30:.1f}G] "
                                    f"tpt={plans[k].est_throughput:.2f} "
                                    f"{plans[k].summary()}")

                if not prune:
                    for P in pp_degrees:
                        self.stats["bp_candidates"] += 1
                        merge(P, eager[(B, P)] if pool is not None
                              else self._search_pp(B, P))
                else:
                    # classify this B's candidates against the frontier
                    # built from all previous batch sizes
                    run_list: List[int] = []
                    deferred: List[Tuple[int, List[int]]] = []
                    for P in pp_degrees:
                        self.stats["bp_candidates"] += 1
                        if P > L:        # _search_pp would return None
                            continue
                        bound = self._candidate_bound(B, P)
                        classes = {k: frontier.classify(k, bound)
                                   for k in range(K) if active[k]}
                        if any(c == "live" for c in classes.values()):
                            run_list.append(P)
                        elif all(c == "infeasible"
                                 for c in classes.values()):
                            self.stats["bp_pruned_infeasible"] += 1
                        else:
                            self.stats["bp_pruned_dominated"] += 1
                            deferred.append(
                                (P, [k for k, c in classes.items()
                                     if c == "dominated"]))
                    if pool is not None:
                        wave = pool.run_many([(B, P) for P in run_list])
                        for P in run_list:
                            merge(P, wave[(B, P)])
                    else:
                        for P in run_list:
                            merge(P, self._search_pp(B, P))
                    # forced pass: a deferred candidate's feasibility may
                    # be all that keeps a budget's OOM counter at zero
                    for P, ks in deferred:
                        if any(not found[k] for k in ks):
                            self.stats["bp_forced"] += 1
                            merge(P, self._search_pp(B, P))
                for k in range(K):
                    if not active[k]:
                        continue
                    consecutive_oom[k] = (0 if found[k]
                                          else consecutive_oom[k] + 1)
                    if consecutive_oom[k] >= 2:  # everything OOMs: stop
                        active[k] = False        # growing B
        finally:
            self._batch_eval = False
            if pool is not None:
                pool.close()
        self.stats["search_seconds"] = _time.time() - t0
        for plan in best:
            if plan is not None:
                plan.search_stats = dict(self.stats)
        return best

    # ------------------------------------------------------------------
    # parallel (B, P) fan-out (DESIGN.md §6)
    # ------------------------------------------------------------------
    def _make_shard(self) -> "GalvatronOptimizer":
        """A worker-view of this optimizer: shares the immutable inputs
        (specs, cost model, search space, budget axis) but writes stage-
        search memo entries and telemetry into private shards, leaving the
        parent's stage cache untouched until merge.

        The table / reference / partition caches are shared *directly*:
        their entries are deterministic, they are never iterated, and
        CPython's GIL makes individual dict get/set atomic — so publishing
        a freshly built cost table immediately spares every other worker
        the same (expensive, budget-independent) build.  A lost race
        merely rebuilds an identical value.
        """
        shard = copy.copy(self)
        shard.stats = {k: (0.0 if k == "search_seconds" else 0)
                       for k in self.stats}
        shard._stage_cache = _ShardCache(self._stage_cache)
        return shard

    def _merge_shard(self, shard: "GalvatronOptimizer") -> None:
        """Fold a worker shard back into the shared memo + telemetry.
        ``update()`` on a shard only sees its local writes; counters are
        summed so hits + misses == lookups holds across the merged stats."""
        for k, v in shard.stats.items():
            if k != "search_seconds":
                self.stats[k] += v
        self._stage_cache.update(shard._stage_cache)

    def _merge_process_result(self, P: int, writes: Dict, stats: Dict) -> None:
        """Fold one process-worker task back into the parent.

        ``writes`` are the worker's stage-cache entries with the worker-
        local strategy-set id *stripped* — ``strategy_set_id`` is an
        insertion-order intern counter, so the worker's ids need not match
        the parent's numbering; the parent re-keys every entry under its
        own id for ``P`` (all writes of one (B, P) task share that one
        strategy set).  Counters are summed, so hits + misses == lookups
        holds across the merged stats."""
        for k, v in stats.items():
            if k in self.stats and k != "search_seconds":
                self.stats[k] += v
        if writes and self.cfg.enable_stage_cache:
            _, sid = self._strategies_for(P)
            for k, v in writes.items():
                self._stage_cache[k + (sid,)] = v


class _CandidatePool:
    """Fan independent (B, P) outer candidates over an executor.

    ``"threads"``: workers are shard views of the parent (shared memo
    caches, private stage-cache shard + telemetry, merged as each task
    completes — DESIGN.md §6).  ``"processes"``: each worker process
    builds its own :class:`GalvatronOptimizer` from the parent's picklable
    constructor arguments (with the serial backend pinned); tasks return
    plans, stage-cache writes and a telemetry delta, which the parent
    merges via ``_merge_process_result``.  Stage-search results are
    deterministic functions of their inputs, so any completion
    interleaving yields the same plans as the serial sweep.
    """

    def __init__(self, opt: GalvatronOptimizer, backend: str,
                 max_workers: Optional[int]):
        self._opt = opt
        self._procs = backend == "processes"
        # one worker per core: the DP is a stream of small NumPy calls, so
        # oversubscription (the executor's cpu+4 default) turns GIL
        # hand-offs into a convoy and *slows the thread sweep several-fold*
        n = max_workers or os.cpu_count() or 2
        if self._procs:
            worker_cfg = dataclasses.replace(
                opt.cfg, search_backend="serial", prune_batch_axis=False,
                jobs=None)
            self._pool = ProcessPoolExecutor(
                max_workers=n, initializer=_process_worker_init,
                initargs=(opt.specs, opt.cluster, worker_cfg,
                          opt._cost_config, dict(opt.cost.profiled_times),
                          opt._budget_axis))
        else:
            self._pool = ThreadPoolExecutor(max_workers=n)

    def run_many(self, bps: Sequence[Tuple[int, int]]
                 ) -> Dict[Tuple[int, int],
                           Optional[List[Optional[ParallelPlan]]]]:
        """Run candidates, merging caches/telemetry into the parent as
        each completes (later tasks then reuse earlier finishers' work)."""
        out: Dict[Tuple[int, int],
                  Optional[List[Optional[ParallelPlan]]]] = {}
        if not bps:
            return out
        opt = self._opt
        if self._procs:
            futures = [self._pool.submit(_process_worker_run, bp)
                       for bp in bps]
            for fut in as_completed(futures):
                bp, plans, writes, stats = fut.result()
                out[bp] = plans
                opt._merge_process_result(bp[1], writes, stats)
            return out

        def run(bp: Tuple[int, int]):
            shard = opt._make_shard()
            return bp, shard._search_pp(*bp), shard

        futures = [self._pool.submit(run, bp) for bp in bps]
        for fut in as_completed(futures):
            bp, plans, shard = fut.result()
            out[bp] = plans
            opt._merge_shard(shard)
        return out

    def close(self) -> None:
        self._pool.shutdown()


# ---- process-pool worker side (module-level for picklability) ------------

_WORKER: Optional[GalvatronOptimizer] = None


def _process_worker_init(specs, cluster, config, cost_config,
                         profiled_times, axis) -> None:
    """Build the worker-resident optimizer once per process; tasks then
    share its memo caches for the worker's lifetime."""
    global _WORKER
    _WORKER = GalvatronOptimizer(specs, cluster, config, cost_config,
                                 profiled_times or None)
    _WORKER._set_budget_axis(tuple(axis))


def _process_worker_run(bp: Tuple[int, int]):
    """One (B, P) candidate in a worker process.

    Runs on a shard (exactly like a thread worker) so the task's fresh
    stage-cache writes and telemetry delta are cleanly separated, then
    folds the shard into the worker-resident optimizer for intra-worker
    reuse.  Returned cache keys have the worker-local strategy-set id
    stripped (see ``_merge_process_result``); every write of the task
    carries the same id — ``_search_pp`` resolves the strategy set once.
    """
    shard = _WORKER._make_shard()
    plans = shard._search_pp(*bp)
    writes = {k[:-1]: v for k, v in shard._stage_cache.items()}
    stats = {k: v for k, v in shard.stats.items() if k != "search_seconds"}
    _WORKER._merge_shard(shard)
    return bp, plans, writes, stats


# --------------------------------------------------------------------------
# convenience constructors for the paper's baselines
# --------------------------------------------------------------------------

def pure_baseline(kind: str, n_devices: int) -> OptimizerConfig:
    """PyTorch-DDP / Megatron-TP / GPipe-PP / FSDP-SDP single-paradigm rows."""
    if kind == "dp":
        return OptimizerConfig(fixed_strategy=Strategy((("dp", n_devices),)),
                               fixed_pp=1, allow_ckpt=False, use_pp=False,
                               bi_objective=False)
    if kind == "sdp":
        return OptimizerConfig(fixed_strategy=Strategy((("sdp", n_devices),)),
                               fixed_pp=1, allow_ckpt=False, use_pp=False,
                               bi_objective=False)
    if kind == "tp":
        return OptimizerConfig(fixed_strategy=Strategy((("tp", n_devices),)),
                               fixed_pp=1, allow_ckpt=False, use_pp=False,
                               bi_objective=False)
    if kind == "pp":
        return OptimizerConfig(fixed_strategy=Strategy(()),
                               fixed_pp=n_devices, allow_ckpt=False,
                               bi_objective=False, schedule="gpipe")
    raise ValueError(kind)


def deepspeed_3d(n_devices: int) -> OptimizerConfig:
    """Expert-designed fixed 3D strategy: 2-way DP x 2-way TP x 2-way PP
    scaled to the device count (officially suggested global combination)."""
    pp = 2
    rest = n_devices // pp
    tp = 2
    dp = rest // tp
    levels = []
    if dp > 1:
        levels.append(("dp", dp))
    if tp > 1:
        levels.append(("tp", tp))
    return OptimizerConfig(fixed_strategy=Strategy(tuple(levels)),
                           fixed_pp=pp, allow_ckpt=False, bi_objective=False)


def galvatron_variant(kind: str) -> OptimizerConfig:
    """'dp+tp' / 'dp+pp' / 'galvatron' (4-dim, no CKPT) / 'base' (5-dim) /
    '1f1b-biobj' (4-dim + balance) / 'bmw' (everything)."""
    if kind == "dp+tp":
        return OptimizerConfig(paradigms=("dp", "tp"), allow_ckpt=False,
                               use_pp=False, bi_objective=False)
    if kind == "dp+pp":
        return OptimizerConfig(paradigms=("dp",), allow_ckpt=False,
                               use_pp=True, bi_objective=False)
    if kind == "galvatron":
        return OptimizerConfig(allow_ckpt=False, bi_objective=False)
    if kind == "base":
        return OptimizerConfig(allow_ckpt=True, bi_objective=False)
    if kind == "1f1b-biobj":
        return OptimizerConfig(allow_ckpt=False, bi_objective=True)
    if kind == "bmw":
        return OptimizerConfig(allow_ckpt=True, bi_objective=True)
    raise ValueError(kind)


def alpa_like() -> "OptimizerConfig":
    """Alpa-style baseline (paper Table VI): automatic inter-op (PP) +
    intra-op parallelism, but SDP is a global either/or choice (no per-layer
    DP/SDP mixing) and activation checkpointing is not searched."""
    return OptimizerConfig(paradigms=("dp", "tp"), allow_ckpt=False,
                           bi_objective=False)


def alpa_like_sdp() -> "OptimizerConfig":
    return OptimizerConfig(paradigms=("sdp", "tp"), allow_ckpt=False,
                           bi_objective=False)
