"""Profiled per-layer execution times (paper §V: "computation time ...
measured by profiling the real layer execution time on a single device").

The analytic cost model divides FLOPs by peak x MFU; profiling replaces
that guess with a measured per-sample time for each distinct layer kind.
``profile_layerspecs`` times a jitted matmul-equivalent workload of each
LayerSpec on the current backend and returns {layer_name: sec/sample},
which ``CostModel(..., profiled_times=...)`` consumes directly.
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from .hardware import COLLECTIVE_KINDS, ClusterSpec, CollectiveProfile
from .layerspec import LayerSpec


def _time_fn(fn, *args, iters: int = 3) -> float:
    jax.block_until_ready(fn(*args))   # warm up once (compile + first run)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def measure_matmul_throughput(d: int = 1024, iters: int = 5) -> float:
    """Achieved dense FLOP/s of this backend (the profiling yardstick)."""
    a = jnp.ones((d, d), jnp.float32)
    b = jnp.ones((d, d), jnp.float32)
    f = jax.jit(lambda x, y: x @ y)
    t = _time_fn(f, a, b, iters=iters)
    return 2.0 * d ** 3 / max(t, 1e-9)


def profile_layerspecs(specs: Sequence[LayerSpec], *,
                       device_peak_flops: Optional[float] = None,
                       iters: int = 3) -> Dict[str, float]:
    """Per-sample forward time for each distinct layer.

    We time a matmul workload with the same FLOP count as the layer (the
    Transformer layers are >95% dense algebra — §II-A), then, if the
    *target* device differs from the profiling host, rescale by the ratio
    of achieved throughputs.  Duplicate layer names share measurements.
    """
    achieved = measure_matmul_throughput()
    scale = 1.0
    if device_peak_flops is not None:
        # translate host-measured seconds to the target device
        scale = achieved / (0.45 * device_peak_flops)
    out: Dict[str, float] = {}
    by_flops: Dict[float, float] = {}
    for s in specs:
        if s.name in out:
            continue
        key = round(s.flops_per_sample, 3)
        if key not in by_flops:
            # time a matmul chain with ~the same FLOPs (capped for speed)
            f = min(s.flops_per_sample, 2e10)
            d = max(64, int((f / 2) ** (1.0 / 3.0)))
            d = min(d, 1024)
            reps = max(1, int(f / (2.0 * d ** 3)))
            a = jnp.ones((d, d), jnp.float32)

            def chain(x, reps=reps):
                for _ in range(min(reps, 16)):
                    x = x @ x * 0.5
                return x

            jitted = jax.jit(chain)
            t = _time_fn(jitted, a, iters=iters)
            t *= max(1, reps) / max(1, min(reps, 16))
            t *= s.flops_per_sample / max(f, 1.0)
            by_flops[key] = t * scale
        out[s.name] = by_flops[key]
    return out


# --------------------------------------------------------------------------
# collective microbenchmarks → latency/bandwidth pairs for the cost model
# --------------------------------------------------------------------------

def device_fingerprint() -> str:
    """Stable id of the local accelerator configuration — the JSON-cache key.

    ``backend:device_kind:count``, e.g. ``gpu:NVIDIA-A100-SXM4-40GB:8`` or
    ``cpu:cpu:1``.  Profiles measured on one fingerprint never leak onto
    another machine shape."""
    devs = jax.local_devices()
    kind = devs[0].device_kind.replace(" ", "-") if devs else "none"
    return f"{jax.default_backend()}:{kind}:{len(devs)}"


def _lstsq_latency_bandwidth(byte_sizes: Sequence[float],
                             times: Sequence[float]) -> CollectiveProfile:
    """Least-squares fit of ``t = latency + bytes / bandwidth``.

    Latency is clamped to >= 0 (a negative intercept just means the small
    messages already saturated the link) and bandwidth to > 0."""
    import numpy as np
    x = np.asarray(byte_sizes, float)
    y = np.asarray(times, float)
    a = np.stack([np.ones_like(x), x], axis=1)
    (lat, inv_bw), *_ = np.linalg.lstsq(a, y, rcond=None)
    if inv_bw <= 0.0:
        # degenerate fit (timer noise dominates): charge everything to
        # bandwidth at the mean observed rate
        inv_bw = float(np.mean(y / np.maximum(x, 1.0)))
        lat = 0.0
    return CollectiveProfile(latency_s=max(0.0, float(lat)),
                             bus_bandwidth=1.0 / float(inv_bw),
                             n_samples=len(x))


def profile_collectives(sizes_mb: Sequence[float] = (1.0, 4.0, 16.0), *,
                        iters: int = 3) -> Dict[str, CollectiveProfile]:
    """Measure all-reduce / all-gather / reduce-scatter / ppermute on the
    local devices and fit a latency-bandwidth pair per kind.

    Returns ``{}`` when fewer than two local devices exist (single-chip
    hosts and CPU CI have no collective to measure — callers fall back to
    the analytic constants), so importing and calling this is always safe.
    """
    n = jax.local_device_count()
    if n < 2:
        return {}
    axis = "i"
    perm = [(i, (i + 1) % n) for i in range(n)]
    ops = {
        "all_reduce": lambda x: jax.lax.psum(x, axis),
        "all_gather": lambda x: jax.lax.all_gather(x, axis),
        "reduce_scatter": lambda x: jax.lax.psum_scatter(
            x, axis, tiled=True),
        "ppermute": lambda x: jax.lax.ppermute(x, axis, perm),
    }
    out: Dict[str, CollectiveProfile] = {}
    for kind, op in ops.items():
        fn = jax.pmap(op, axis_name=axis)
        byte_sizes: List[float] = []
        times: List[float] = []
        for mb in sizes_mb:
            elems = max(n, int(mb * 2 ** 20 / 4))
            elems -= elems % n                 # psum_scatter needs n | len
            x = jnp.ones((n, elems), jnp.float32)
            times.append(_time_fn(fn, x, iters=iters))
            byte_sizes.append(elems * 4.0)     # message bytes per device
        out[kind] = _lstsq_latency_bandwidth(byte_sizes, times)
    return out


def load_collective_profiles(path: Union[str, pathlib.Path]
                             ) -> Dict[str, Dict[str, CollectiveProfile]]:
    """Parse a profile cache file: {fingerprint: {kind: profile}}."""
    raw = json.loads(pathlib.Path(path).read_text())
    return {fp: {k: CollectiveProfile.from_json(v)
                 for k, v in kinds.items() if k in COLLECTIVE_KINDS}
            for fp, kinds in raw.items()}


def save_collective_profiles(path: Union[str, pathlib.Path],
                             by_fingerprint: Dict[str, Dict[str, CollectiveProfile]]
                             ) -> None:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(
        {fp: {k: prof.to_json() for k, prof in sorted(kinds.items())}
         for fp, kinds in sorted(by_fingerprint.items())},
        indent=2, sort_keys=True) + "\n")


def default_profile_cache_path() -> pathlib.Path:
    env = os.environ.get("REPRO_COLLECTIVES_CACHE")
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "collectives.json"


def cached_collective_profiles(
        path: Union[str, pathlib.Path, None] = None, *,
        fingerprint: Optional[str] = None,
        refresh: bool = False,
        profile_fn: Optional[Callable[[], Dict[str, CollectiveProfile]]] = None,
) -> Dict[str, CollectiveProfile]:
    """Profiled collective constants for this host, via a JSON cache.

    Looks up :func:`device_fingerprint` in the cache at ``path`` (default:
    ``$REPRO_COLLECTIVES_CACHE`` or ``~/.cache/repro/collectives.json``);
    on a miss (or ``refresh=True``) runs :func:`profile_collectives` and
    writes the result through, merging with other fingerprints already in
    the file.  Returns ``{}`` when nothing could be measured — and caches
    that too, so single-device hosts don't re-probe every run.
    """
    path = pathlib.Path(path) if path is not None else default_profile_cache_path()
    fp = fingerprint or device_fingerprint()
    cache: Dict[str, Dict[str, CollectiveProfile]] = {}
    if path.exists():
        try:
            cache = load_collective_profiles(path)
        except (json.JSONDecodeError, KeyError, TypeError, ValueError):
            cache = {}                         # corrupt cache: re-measure
    if not refresh and fp in cache:
        return dict(cache[fp])
    measured = (profile_fn or profile_collectives)()
    cache[fp] = dict(measured)
    save_collective_profiles(path, cache)
    return dict(measured)


def profiled_cluster(cluster: ClusterSpec,
                     path: Union[str, pathlib.Path, None] = None, *,
                     refresh: bool = False) -> ClusterSpec:
    """``cluster`` with this host's measured collective constants attached
    (unchanged when nothing could be measured)."""
    profiles = cached_collective_profiles(path, refresh=refresh)
    return cluster.with_profiles(profiles) if profiles else cluster
