"""Profiled per-layer execution times (paper §V: "computation time ...
measured by profiling the real layer execution time on a single device").

The analytic cost model divides FLOPs by peak x MFU; profiling replaces
that guess with a measured per-sample time for each distinct layer kind.
``profile_layerspecs`` times a jitted matmul-equivalent workload of each
LayerSpec on the current backend and returns {layer_name: sec/sample},
which ``CostModel(..., profiled_times=...)`` consumes directly.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from .layerspec import LayerSpec


def _time_fn(fn, *args, iters: int = 3) -> float:
    jax.block_until_ready(fn(*args))   # warm up once (compile + first run)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def measure_matmul_throughput(d: int = 1024, iters: int = 5) -> float:
    """Achieved dense FLOP/s of this backend (the profiling yardstick)."""
    a = jnp.ones((d, d), jnp.float32)
    b = jnp.ones((d, d), jnp.float32)
    f = jax.jit(lambda x, y: x @ y)
    t = _time_fn(f, a, b, iters=iters)
    return 2.0 * d ** 3 / max(t, 1e-9)


def profile_layerspecs(specs: Sequence[LayerSpec], *,
                       device_peak_flops: Optional[float] = None,
                       iters: int = 3) -> Dict[str, float]:
    """Per-sample forward time for each distinct layer.

    We time a matmul workload with the same FLOP count as the layer (the
    Transformer layers are >95% dense algebra — §II-A), then, if the
    *target* device differs from the profiling host, rescale by the ratio
    of achieved throughputs.  Duplicate layer names share measurements.
    """
    achieved = measure_matmul_throughput()
    scale = 1.0
    if device_peak_flops is not None:
        # translate host-measured seconds to the target device
        scale = achieved / (0.45 * device_peak_flops)
    out: Dict[str, float] = {}
    by_flops: Dict[float, float] = {}
    for s in specs:
        if s.name in out:
            continue
        key = round(s.flops_per_sample, 3)
        if key not in by_flops:
            # time a matmul chain with ~the same FLOPs (capped for speed)
            f = min(s.flops_per_sample, 2e10)
            d = max(64, int((f / 2) ** (1.0 / 3.0)))
            d = min(d, 1024)
            reps = max(1, int(f / (2.0 * d ** 3)))
            a = jnp.ones((d, d), jnp.float32)

            def chain(x, reps=reps):
                for _ in range(min(reps, 16)):
                    x = x @ x * 0.5
                return x

            jitted = jax.jit(chain)
            t = _time_fn(jitted, a, iters=iters)
            t *= max(1, reps) / max(1, min(reps, 16))
            t *= s.flops_per_sample / max(f, 1.0)
            by_flops[key] = t * scale
        out[s.name] = by_flops[key]
    return out
