"""Hardware descriptions used by the Galvatron-BMW cost estimator.

The paper profiles GPUs (RTX TITAN / A100 clusters); our *target* is TPU
v5e pods.  Every constant the estimator needs is collected here so the same
search engine reproduces the paper's GPU tables and plans for TPU pods.

Bandwidths are *algorithmic* bandwidths (bytes/s available to a collective
on one device), compute is peak dense throughput per chip.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

GB = 1024**3
MB = 1024**2
TFLOPS = 1e12

#: collective kinds the profiler measures and the cost model consumes
COLLECTIVE_KINDS = ("all_reduce", "all_gather", "reduce_scatter", "ppermute")


@dataclasses.dataclass(frozen=True)
class CollectiveProfile:
    """Measured latency/bandwidth pair of one collective kind.

    Produced by ``core/profiler.py::profile_collectives`` from on-device
    microbenchmarks (a linear fit ``t = latency_s + bytes / bus_bandwidth``
    over several message sizes) and consumed by the cost model through
    :meth:`ClusterSpec.collective_coeffs`.  ``bus_bandwidth`` is the
    *algorithmic* bytes/s seen by one device (same convention as the
    analytic ``intra_island_bandwidth``), so a profiled and an analytic
    constant drop into the same cost-model formulas.
    """

    latency_s: float                 # fixed per-invocation cost, seconds
    bus_bandwidth: float             # algorithmic bytes/s per device
    n_samples: int = 0               # message sizes the fit saw

    def to_json(self) -> Dict:
        return {"latency_s": self.latency_s,
                "bus_bandwidth": self.bus_bandwidth,
                "n_samples": self.n_samples}

    @staticmethod
    def from_json(d: Mapping) -> "CollectiveProfile":
        return CollectiveProfile(
            latency_s=float(d["latency_s"]),
            bus_bandwidth=float(d["bus_bandwidth"]),
            n_samples=int(d.get("n_samples", 0)))


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """A single accelerator."""

    name: str
    peak_flops: float            # dense (bf16/fp16) FLOP/s
    hbm_bytes: float             # device memory capacity
    hbm_bandwidth: float         # bytes/s
    # Slowdown multiplier applied to BOTH compute and communication when the
    # two overlap (paper §V measures ~1.3x on GPUs from SM contention; TPUs
    # run collectives on dedicated ICI/DMA hardware so the factor is ~1.1).
    overlap_slowdown: float = 1.3


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A (possibly hierarchical) collection of identical devices.

    ``intra_island_bandwidth`` is the fast interconnect (NVLink / ICI);
    ``inter_island_bandwidth`` is the slow one (IB / PCIe / DCI).  Takeaway #1
    puts PP across islands.  ``island_size`` devices share the fast domain.
    """

    name: str
    device: DeviceSpec
    n_devices: int
    island_size: int
    intra_island_bandwidth: float   # bytes/s per device, fast domain
    inter_island_bandwidth: float   # bytes/s per device, slow domain
    memory_budget: Optional[float] = None  # training budget; default = hbm
    # Measured collective constants, stored as a sorted tuple of
    # (kind, CollectiveProfile) pairs so the frozen dataclass stays
    # hashable.  Build with :meth:`with_profiles`; ``None`` means "analytic
    # constants only" and reproduces the pre-profiling cost model exactly.
    collective_profiles: Optional[Tuple[Tuple[str, "CollectiveProfile"], ...]] = None

    def budget(self) -> float:
        return self.memory_budget if self.memory_budget is not None else self.device.hbm_bytes

    def bandwidth_for_group(self, group_size: int) -> float:
        """Bandwidth seen by a collective over ``group_size`` devices.

        Groups that fit inside an island use the fast domain; larger groups
        are bottlenecked by the slow domain.
        """
        if group_size <= self.island_size:
            return self.intra_island_bandwidth
        return self.inter_island_bandwidth

    def profiles(self) -> Dict[str, "CollectiveProfile"]:
        """Profiled collective constants as a plain dict (possibly empty)."""
        return dict(self.collective_profiles or ())

    def _profile_for(self, kind: str) -> Optional["CollectiveProfile"]:
        for k, p in (self.collective_profiles or ()):
            if k == kind:
                return p
        return None

    def collective_coeffs(self, kind: str, group_size: int) -> Tuple[float, float]:
        """``(latency_s, bandwidth)`` the cost model should charge for one
        ``kind`` collective spanning ``group_size`` devices.

        Profiled constants were measured inside one fast domain, so they
        apply only to groups that fit in an island; degenerate groups
        (``group_size <= 1``) and cross-island groups fall back to zero
        latency and the analytic :meth:`bandwidth_for_group` — with no
        profiles attached every result is the analytic pair, keeping the
        cost model byte-identical to the pre-profiling one.
        """
        if group_size > 1 and group_size <= self.island_size:
            p = self._profile_for(kind)
            if p is not None:
                return (p.latency_s, p.bus_bandwidth)
        return (0.0, self.bandwidth_for_group(group_size))

    def p2p_coeffs(self) -> Tuple[float, float]:
        """``(latency_s, bandwidth)`` for the pipeline hand-off transfer.

        PP boundaries sit on the *slow* domain by construction (Takeaway
        #1), so a profiled ``ppermute`` — measured inside the fast domain —
        only applies when the whole cluster is one island.
        """
        if self.island_size >= self.n_devices:
            p = self._profile_for("ppermute")
            if p is not None:
                return (p.latency_s, p.bus_bandwidth)
        return (0.0, self.inter_island_bandwidth)

    def with_budget(self, budget_bytes: float) -> "ClusterSpec":
        return dataclasses.replace(self, memory_budget=budget_bytes)

    def with_devices(self, n: int) -> "ClusterSpec":
        return dataclasses.replace(self, n_devices=n)

    def with_profiles(self, profiles: Mapping[str, "CollectiveProfile"]) -> "ClusterSpec":
        """Attach measured collective constants (see ``core/profiler.py``).

        An empty mapping detaches all profiles (back to analytic)."""
        packed = tuple(sorted(profiles.items())) or None
        return dataclasses.replace(self, collective_profiles=packed)


# --------------------------------------------------------------------------
# Device presets
# --------------------------------------------------------------------------

RTX_TITAN = DeviceSpec(
    name="rtx-titan-24g",
    peak_flops=32.6 * TFLOPS,        # fp16 w/ fp32 accum tensor cores
    hbm_bytes=24 * GB,
    hbm_bandwidth=672e9,
    overlap_slowdown=1.3,
)

A100_40G = DeviceSpec(
    name="a100-40g",
    peak_flops=312 * TFLOPS,
    hbm_bytes=40 * GB,
    hbm_bandwidth=1555e9,
    overlap_slowdown=1.3,
)

A100_80G = DeviceSpec(
    name="a100-80g",
    peak_flops=312 * TFLOPS,
    hbm_bytes=80 * GB,
    hbm_bandwidth=2039e9,
    overlap_slowdown=1.3,
)

# The TARGET: TPU v5e.  Constants given by the task spec:
# 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_V5E = DeviceSpec(
    name="tpu-v5e",
    peak_flops=197 * TFLOPS,
    hbm_bytes=16 * GB,
    hbm_bandwidth=819e9,
    overlap_slowdown=1.1,
)

TPU_PEAK_FLOPS = TPU_V5E.peak_flops
TPU_HBM_BW = TPU_V5E.hbm_bandwidth
TPU_ICI_BW = 50e9  # bytes/s per link


# --------------------------------------------------------------------------
# Cluster presets (paper evaluation environments + TPU targets)
# --------------------------------------------------------------------------

def paper_8gpu() -> ClusterSpec:
    """Single node, 8x RTX TITAN on PCIe 3.0 (paper §VII-A)."""
    return ClusterSpec(
        name="8x-rtx-titan-pcie",
        device=RTX_TITAN,
        n_devices=8,
        island_size=8,
        intra_island_bandwidth=12e9,     # PCIe 3.0 x16 effective
        inter_island_bandwidth=12e9,
    )


def paper_16gpu_low() -> ClusterSpec:
    """2 nodes x 8 RTX TITAN, 100Gb IB across (low-perf cluster)."""
    return ClusterSpec(
        name="16x-rtx-titan-ib100",
        device=RTX_TITAN,
        n_devices=16,
        island_size=8,
        intra_island_bandwidth=12e9,
        inter_island_bandwidth=10e9,     # 100 Gb/s ≈ 10 GB/s after overhead
    )


def paper_16gpu_high() -> ClusterSpec:
    """2 nodes x 8 A100-NVLink, 100Gb IB across (high-perf cluster)."""
    return ClusterSpec(
        name="16x-a100-nvlink-ib100",
        device=A100_40G,
        n_devices=16,
        island_size=8,
        intra_island_bandwidth=300e9,    # NVLink3 per-GPU algorithmic
        inter_island_bandwidth=10e9,
    )


def paper_64gpu() -> ClusterSpec:
    """8 nodes x 8 A100-40G NVLink, 100Gb IB (Table IV)."""
    return ClusterSpec(
        name="64x-a100-nvlink-ib100",
        device=A100_40G,
        n_devices=64,
        island_size=8,
        intra_island_bandwidth=300e9,
        inter_island_bandwidth=10e9,
    )


def paper_32gpu_80g() -> ClusterSpec:
    """4 nodes x 8 A100-80G, 400Gb IB (Table VI, GPT-3 runs)."""
    return ClusterSpec(
        name="32x-a100-80g-ib400",
        device=A100_80G,
        n_devices=32,
        island_size=8,
        intra_island_bandwidth=300e9,
        inter_island_bandwidth=40e9,
    )


def tpu_v5e_pod(n_chips: int = 256) -> ClusterSpec:
    """One v5e pod: 2D torus, ICI everywhere."""
    # A v5e chip has 4 ICI links; algorithmic per-device collective bandwidth
    # on the torus ≈ 2 links usable per logical ring direction.
    return ClusterSpec(
        name=f"tpu-v5e-pod-{n_chips}",
        device=TPU_V5E,
        n_devices=n_chips,
        island_size=n_chips,
        intra_island_bandwidth=2 * TPU_ICI_BW,
        inter_island_bandwidth=2 * TPU_ICI_BW,
    )


def tpu_v5e_multipod(n_pods: int = 2, chips_per_pod: int = 256) -> ClusterSpec:
    """Multiple v5e pods over data-center interconnect."""
    return ClusterSpec(
        name=f"tpu-v5e-{n_pods}x{chips_per_pod}",
        device=TPU_V5E,
        n_devices=n_pods * chips_per_pod,
        island_size=chips_per_pod,
        intra_island_bandwidth=2 * TPU_ICI_BW,
        inter_island_bandwidth=6.25e9,   # ~50 Gb/s effective DCI per chip-pair
    )


CLUSTERS: Dict[str, "ClusterSpec"] = {}
for _f in (paper_8gpu, paper_16gpu_low, paper_16gpu_high, paper_64gpu,
           paper_32gpu_80g, tpu_v5e_pod, tpu_v5e_multipod):
    _c = _f()
    CLUSTERS[_c.name] = _c
