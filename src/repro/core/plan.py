"""Serializable parallelism plan — the output of the Galvatron-BMW search
and the input of the execution runtime.

JSON format versioning (full schema + compat table: docs/plan-format.md):

  * v0 (PR 1) — no ``vpp_degree`` key; ``schedule`` may be absent too.
  * v1 (PR 2) — ``schedule`` + ``vpp_degree`` always present.
  * v2 (PR 5) — ``format_version`` stamp; ``schedule`` may be ``"zb-h1"``.
  * v3 (PR 8) — optional ``serving`` section (:class:`ServingSection`):
    the SLO-aware serving search's prefill/decode disaggregation plan
    (TP/PP per phase, decode batch, paged-KV page size / pool size).
    ``serving`` may be ``null``/absent — a v3 plan without it is a pure
    training plan.
  * v4 (PR 9) — optional ``sp_degree`` (sequence-parallel / ring-attention
    degree, default 1 = no sequence sharding) and ``seq_len`` (the
    sequence length the plan was searched for, default 0 = unrecorded;
    lint rule PLN011 checks ``seq_len % sp_degree == 0`` when both are
    present).
  * v5 (PR 10) — optional ``ep_degree`` (expert-parallel degree: MoE
    experts sharded over an expert axis with all-to-all dispatch/combine,
    default 1 = experts replicated; lint rule PLN012 checks the device
    factorization and that per-layer ``ep`` degrees stay under the stamp).

``from_json`` reads every older version (missing keys default to the
value that version implied: ``schedule="1f1b"``, ``vpp_degree=1``,
``serving=None``, ``sp_degree=1``, ``seq_len=0``, ``ep_degree=1``);
``to_json`` always writes the current version.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

from .strategy import Strategy

#: version stamp written by :meth:`ParallelPlan.to_json` (see module doc)
PLAN_FORMAT_VERSION = 5


@dataclasses.dataclass
class ServingSection:
    """Optional inference block of a plan (format v3+).

    Emitted by the SLO-aware serving search (``repro.serving.slo_search``)
    and consumed by ``launch/serve.py --plan``.  Prefill and decode are
    disaggregated phases with independent TP/PP degrees; the paged KV
    cache is described by ``page_size`` / ``kv_pool_pages``.  All ``est_*``
    fields are cost-model predictions, not measurements."""

    slo_ms: float                 # per-decoded-token latency SLO
    page_size: int                # tokens per KV page
    max_context: int              # per-request context ceiling (tokens)
    decode_batch: int             # continuous-batching decode lanes
    prefill_chunk: int            # chunked-prefill tokens per jit call
    decode_tp: int = 1
    decode_pp: int = 1
    prefill_tp: int = 1
    prefill_pp: int = 1
    kv_pool_pages: int = 0        # shared page-pool capacity (pages/layer)
    ttft_slo_ms: float = 0.0      # 0 = no TTFT target
    est_tok_ms: float = 0.0       # predicted per-token decode latency
    est_ttft_ms: float = 0.0      # predicted time-to-first-token
    est_tok_per_s: float = 0.0    # predicted aggregate decode throughput

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict) -> "ServingSection":
        if not isinstance(d, dict):
            raise PlanFormatError(
                "serving",
                f"must be an object or null, got {type(d).__name__}")

        def req(key):
            try:
                return d[key]
            except KeyError:
                raise PlanFormatError(
                    f"serving.{key}",
                    "required serving field is missing") from None

        known = {f.name for f in dataclasses.fields(ServingSection)}
        extra = {k: v for k, v in d.items() if k in known
                 and k not in ("slo_ms", "page_size", "max_context",
                               "decode_batch", "prefill_chunk")}
        return ServingSection(
            slo_ms=req("slo_ms"),
            page_size=req("page_size"),
            max_context=req("max_context"),
            decode_batch=req("decode_batch"),
            prefill_chunk=req("prefill_chunk"),
            **extra,
        )


class PlanFormatError(ValueError):
    """Structured plan-JSON failure: names the offending field.

    Raised by :meth:`ParallelPlan.from_json` instead of leaking a bare
    ``KeyError``/``TypeError`` stack trace, so CLIs and the plan verifier
    (``repro.analysis.plan_lint``) can point at the exact field.  The full
    multi-diagnostic verification lives in the verifier; this is the
    minimal always-on guard for any loading path."""

    def __init__(self, field: str, message: str):
        self.field = field
        super().__init__(f"plan.{field}: {message}")


@dataclasses.dataclass
class ParallelPlan:
    """A complete distributed-execution plan for one model + cluster."""

    n_devices: int
    pp_degree: int
    partition: List[int]                 # layers per pipeline stage
    strategies: List[Strategy]           # one per layer (concatenated stages)
    global_batch: int
    n_micro: int
    schedule: str = "1f1b"
    vpp_degree: int = 1                  # virtual chunks per stage (V);
                                         # > 1 only with "1f1b-interleaved"
    sp_degree: int = 1                   # sequence-parallel (ring attention)
                                         # degree; 1 = no sequence sharding
    seq_len: int = 0                     # searched sequence length (tokens);
                                         # 0 = unrecorded (pre-v4 plans)
    ep_degree: int = 1                   # expert-parallel degree (sharded
                                         # MoE experts); 1 = replicated

    # estimator outputs (filled by the search)
    est_iter_time: float = 0.0
    est_throughput: float = 0.0          # samples / s
    est_stage_mem: Optional[List[float]] = None
    alpha_t: float = 0.0
    alpha_m: float = 0.0
    searched_by: str = "galvatron-bmw"
    # inference plan (v3+); None for pure training plans
    serving: Optional[ServingSection] = None
    # search-engine telemetry (stage-search / cache-hit counts, wall time);
    # excluded from equality so cached and uncached searches that find the
    # same plan compare equal
    search_stats: Optional[Dict[str, float]] = dataclasses.field(
        default=None, compare=False)

    def __post_init__(self):
        if self.n_micro < 1:
            raise ValueError(f"n_micro must be >= 1, got {self.n_micro}")
        if self.global_batch % self.n_micro:
            raise ValueError(
                f"global_batch={self.global_batch} is not divisible by "
                f"n_micro={self.n_micro}: micro-batches would be uneven "
                "(pick n_micro dividing the global batch)")
        if self.vpp_degree < 1:
            raise ValueError(
                f"vpp_degree must be >= 1, got {self.vpp_degree}")
        if self.sp_degree < 1:
            raise ValueError(
                f"sp_degree must be >= 1, got {self.sp_degree}")
        if self.ep_degree < 1:
            raise ValueError(
                f"ep_degree must be >= 1, got {self.ep_degree}")

    @property
    def micro_batch_size(self) -> int:
        # exact by the __post_init__ divisibility check
        return self.global_batch // self.n_micro

    def stage_strategies(self, stage: int) -> List[Strategy]:
        start = sum(self.partition[:stage])
        return self.strategies[start:start + self.partition[stage]]

    def summary(self) -> str:
        segs: List[str] = []
        run, prev = 0, None
        for s in self.strategies + [None]:
            name = s.name() if s is not None else None
            if name == prev:
                run += 1
                continue
            if prev is not None:
                segs.append(f"{prev} x{run}")
            prev, run = name, 1
        sched = (f"{self.schedule}" if self.vpp_degree == 1
                 else f"{self.schedule}(V={self.vpp_degree})")
        return (f"pp{self.pp_degree} p={self.partition} B={self.global_batch} "
                f"m={self.n_micro} {sched} | " + ", ".join(segs))

    # ---- (de)serialization ----------------------------------------------
    def to_json(self) -> Dict:
        return {
            "format_version": PLAN_FORMAT_VERSION,
            "n_devices": self.n_devices,
            "pp_degree": self.pp_degree,
            "partition": self.partition,
            "strategies": [s.to_json() for s in self.strategies],
            "global_batch": self.global_batch,
            "n_micro": self.n_micro,
            "schedule": self.schedule,
            "vpp_degree": self.vpp_degree,
            "sp_degree": self.sp_degree,
            "seq_len": self.seq_len,
            "ep_degree": self.ep_degree,
            "est_iter_time": self.est_iter_time,
            "est_throughput": self.est_throughput,
            "est_stage_mem": self.est_stage_mem,
            "alpha_t": self.alpha_t,
            "alpha_m": self.alpha_m,
            "searched_by": self.searched_by,
            "serving": (self.serving.to_json()
                        if self.serving is not None else None),
            "search_stats": self.search_stats,
        }

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=2)

    def canonical_json(self) -> Dict:
        """``to_json()`` minus the ``search_stats`` telemetry — everything
        that defines the plan's execution semantics and estimates, nothing
        that depends on how the search was run (caches, workers, wall
        time).  Two searches agree iff their canonical JSON agrees."""
        d = self.to_json()
        d.pop("search_stats", None)
        return d

    def canonical_dumps(self) -> str:
        """Deterministic byte representation of :meth:`canonical_json`
        (sorted keys, no whitespace variance) — the byte-identity oracle
        used by the frontier differential tests and benchmarks."""
        return json.dumps(self.canonical_json(), sort_keys=True)

    @staticmethod
    def from_json(d: Dict) -> "ParallelPlan":
        if not isinstance(d, dict):
            raise PlanFormatError(
                "", f"plan JSON must be an object, got {type(d).__name__}")
        ver = d.get("format_version", 0)
        if isinstance(ver, int) and ver > PLAN_FORMAT_VERSION:
            raise PlanFormatError(
                "format_version",
                f"declares v{ver}, but this build reads "
                f"<= v{PLAN_FORMAT_VERSION}; re-emit the plan with this "
                "build's search CLI")

        def req(key):
            try:
                return d[key]
            except KeyError:
                raise PlanFormatError(
                    key, "required field is missing (every plan version "
                         "carries it; the file is truncated or not a "
                         "plan)") from None

        strategies = []
        for j, s in enumerate(req("strategies")):
            try:
                strategies.append(Strategy.from_json(s))
            except (KeyError, TypeError, ValueError) as e:
                raise PlanFormatError(
                    f"strategies[{j}]",
                    f"strategy does not parse ({e!r}); see "
                    "docs/plan-format.md for the per-layer schema"
                ) from None
        return ParallelPlan(
            n_devices=req("n_devices"),
            pp_degree=req("pp_degree"),
            partition=list(req("partition")),
            strategies=strategies,
            global_batch=req("global_batch"),
            n_micro=req("n_micro"),
            schedule=d.get("schedule", "1f1b"),
            # PR-1-era plan JSON predates interleaved schedules
            vpp_degree=d.get("vpp_degree", 1),
            # pre-v4 plan JSON predates sequence parallelism
            sp_degree=d.get("sp_degree", 1),
            seq_len=d.get("seq_len", 0),
            # pre-v5 plan JSON predates expert parallelism
            ep_degree=d.get("ep_degree", 1),
            est_iter_time=d.get("est_iter_time", 0.0),
            est_throughput=d.get("est_throughput", 0.0),
            est_stage_mem=d.get("est_stage_mem"),
            alpha_t=d.get("alpha_t", 0.0),
            alpha_m=d.get("alpha_m", 0.0),
            searched_by=d.get("searched_by", "galvatron-bmw"),
            # pre-v3 plan JSON has no serving section
            serving=(ServingSection.from_json(d["serving"])
                     if d.get("serving") is not None else None),
            search_stats=d.get("search_stats"),
        )

    @staticmethod
    def loads(s: str) -> "ParallelPlan":
        return ParallelPlan.from_json(json.loads(s))
