"""Decision-tree based search-space construction (paper §III-B).

Takeaway #1: PP is applied first, across the slowest links; the remaining
paradigms (DP/SDP/TP) form decision trees over each stage's device group.
Takeaway #2: devices split into equal-size groups ⇒ group size = N / pp.
Takeaway #3: prune trees mixing DP and SDP.

For 8 devices this produces 68 leaves without T#3 and 44 with it (unit
tested against the paper's reported counts).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from .strategy import PARADIGMS, Strategy, enumerate_strategies


def pp_degree_candidates(n_devices: int, max_pp: int | None = None) -> List[int]:
    """Powers of two dividing the device count (paper assumes 2^k devices)."""
    out = []
    p = 1
    while p <= n_devices:
        if n_devices % p == 0:
            if max_pp is None or p <= max_pp:
                out.append(p)
        p *= 2
    return out


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """All candidate per-layer strategies, grouped by PP degree."""

    n_devices: int
    per_pp: Dict[int, List[Strategy]]

    def strategies(self, pp: int) -> List[Strategy]:
        return self.per_pp[pp]

    def total_leaves(self) -> int:
        return sum(len(v) for v in self.per_pp.values())


def construct_search_space(
    n_devices: int,
    *,
    paradigms: Sequence[str] = PARADIGMS,
    allow_ckpt: bool = True,
    prune_dp_sdp: bool = True,
    max_pp: int | None = None,
    max_tp: int | None = None,
    max_sp: int | None = None,
    max_ep: int | None = None,
) -> SearchSpace:
    per_pp: Dict[int, List[Strategy]] = {}
    for pp in pp_degree_candidates(n_devices, max_pp):
        group = n_devices // pp
        strategies = enumerate_strategies(
            group,
            paradigms=paradigms,
            allow_ckpt=allow_ckpt,
            prune_dp_sdp=prune_dp_sdp,
        )
        if max_tp is not None:
            strategies = [s for s in strategies if s.tp <= max_tp]
        if max_sp is not None:
            strategies = [s for s in strategies if s.sp <= max_sp]
        if max_ep is not None:
            strategies = [s for s in strategies if s.ep <= max_ep]
        per_pp[pp] = strategies
    return SearchSpace(n_devices=n_devices, per_pp=per_pp)
