"""Galvatron-BMW core: automatic hybrid-parallelism search (the paper's
primary contribution), in pure Python/NumPy — model- and runtime-agnostic."""
from .cost_model import (CostModel, CostModelConfig, CostTables, LayerCosts,
                         bubble_fraction, pipeline_iter_time)
from .decision_tree import SearchSpace, construct_search_space, pp_degree_candidates
from .dp_search import (StageSearchResult, dp_search_stage,
                        dp_search_stage_budgets, dp_search_stage_budgets_batch)
from .frontier import (CandidateBound, DominanceFrontier, FrontierPoint,
                       PlanFrontier)
from .hardware import (CLUSTERS, ClusterSpec, CollectiveProfile, DeviceSpec,
                       TPU_V5E, paper_8gpu, paper_16gpu_high, paper_16gpu_low,
                       paper_32gpu_80g, paper_64gpu, tpu_v5e_multipod,
                       tpu_v5e_pod)
from .layerspec import (LayerSpec, cross_attn_extra, dense_layer, embed_layer,
                        head_layer, merge, moe_layer, ssm_layer, total_params)
from .optimizer import (SEARCH_BACKENDS, GalvatronOptimizer, OptimizerConfig,
                        deepspeed_3d, galvatron_variant, normalize_batch_grid,
                        pure_baseline)
from .pipeline_balance import (ZB_W_ACT_FRAC, balance_degrees,
                               inflight_microbatches,
                               memory_balanced_partition,
                               time_balanced_partition, zb_w_pending_max)
from .plan import (PLAN_FORMAT_VERSION, ParallelPlan, PlanFormatError,
                   ServingSection)
from .strategy import (DP, SDP, TP, Strategy, enumerate_strategies,
                       strategy_set_id)

__all__ = [k for k in dir() if not k.startswith("_")]
