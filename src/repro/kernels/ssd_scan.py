"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

Grid: one program per (batch, head).  The program walks the sequence in
``chunk``-sized tiles, carrying the (head_dim x state) SSM state in a VMEM
scratch buffer.  Each chunk does the quadratic intra-chunk part on the MXU
(chunk x chunk matmul) and one state update — the same decomposition as the
paper's SSD algorithm, re-tiled for VMEM instead of CUDA shared memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *,
                chunk: int, seq: int):
    # x (S,P) dt (S,1) a (1,1) b (S,N) c (S,N) out (S,P); scratch (P,N)
    P = x_ref.shape[-1]
    N = b_ref.shape[-1]
    state_ref[...] = jnp.zeros((P, N), jnp.float32)
    a = a_ref[0].astype(jnp.float32)   # block (None, 1) -> shape (1,)
    n_chunks = seq // chunk

    def body(ci, _):
        sl = pl.dslice(ci * chunk, chunk)
        x = pl.load(x_ref, (sl, slice(None))).astype(jnp.float32)   # (Q,P)
        dt = pl.load(dt_ref, (sl, slice(None))).astype(jnp.float32)  # (Q,1)
        bm = pl.load(b_ref, (sl, slice(None))).astype(jnp.float32)  # (Q,N)
        cm = pl.load(c_ref, (sl, slice(None))).astype(jnp.float32)  # (Q,N)

        dA = dt[:, 0] * a                                  # (Q,) negative
        cum = jnp.cumsum(dA)                               # inclusive
        # intra-chunk quadratic part
        cb = cm @ bm.T                                     # (Q,Q)
        delta = cum[:, None] - cum[None, :]
        iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        ik = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        decay = jnp.exp(jnp.where(iq >= ik, delta, -1e30))
        m = cb * decay * dt[:, 0][None, :]
        y = m @ x                                          # (Q,P)
        # contribution of the carried state
        state = state_ref[...]
        y += jnp.exp(cum)[:, None] * (cm @ state.T)        # (Q,N)@(N,P)
        # state update
        decay_to_end = jnp.exp(cum[-1] - cum)              # (Q,)
        upd = (bm * (decay_to_end * dt[:, 0])[:, None]).T @ x   # (N,Q)@(Q,P)
        state_ref[...] = state * jnp.exp(cum[-1]) + upd.T  # (P,N)
        pl.store(o_ref, (sl, slice(None)), y.astype(o_ref.dtype))
        return 0

    jax.lax.fori_loop(0, n_chunks, body, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 64,
             interpret: bool = False) -> jax.Array:
    """x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,H,N) -> y (B,S,H,P)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0

    grid = (B, H)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, seq=S)
    dt4 = dt[..., None]                       # (B,S,H,1)
    a2 = A.reshape(H, 1)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, S, None, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((None, S, None, 1), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((None, 1), lambda b, h: (h, 0)),
            pl.BlockSpec((None, S, None, N), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((None, S, None, N), lambda b, h: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, S, None, P), lambda b, h: (b, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt4, a2, Bm, Cm)
