"""Ring attention: flash attention over a sequence-sharded mesh axis.

Each device holds a local q shard and a local K/V panel of the sequence.
The panels rotate around the ``seq`` mesh axis with ``lax.ppermute`` —
the same ring hand-off the pipeline runtime uses: the permute on the
current panel is issued *before* the round's compute, so the collective
has no data dependency on it and XLA overlaps the send/recv with the
flash kernel of the round in flight.

Every round runs a *partial* flash kernel over (local q, visiting K/V
panel) that returns the un-normalized online-softmax state (acc, m, l);
rounds merge states with the standard log-sum-exp combine, and after
P − 1 hand-offs (P = axis size) every device has attended its q shard to
the full global sequence.  The result is token-identical to running the
single-device ``flash_attention`` on the gathered sequence.

Masks are expressed through ``delta = q_start − k_start`` (the offset of
the local q shard against the visiting panel's global origin), the only
dynamic quantity the kernel needs: ``k_global <= q_global`` is exactly
``k_local <= q_local + delta``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import (NEG_INF, _pad_to,
                                           _validate_attn_shapes)


def _partial_kernel(delta_ref, q_ref, k_ref, v_ref,
                    acc_ref, m_ref, l_ref, *, scale: float, causal: bool,
                    window: Optional[int], block_q: int, block_k: int,
                    seq_k: int, kv_len: int):
    # delta_ref: (1, 1) int32 — q_start − k_start in global positions.
    # Outputs are the raw online-softmax state: acc (block_q, dh) fp32,
    # m / l (block_q, 1) fp32.  Rows the mask fully rejects keep
    # m == NEG_INF, l == 0, acc == 0, which the cross-round merge and the
    # final normalization treat as an exact zero contribution.
    iq = pl.program_id(2)
    delta = delta_ref[0, 0]
    q = q_ref[...].astype(jnp.float32) * scale
    q_pos = (iq * block_q + delta
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0))

    n_k = seq_k // block_k

    def body(ik, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (pl.dslice(ik * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(ik * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T                       # (bq, bk)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if kv_len < seq_k:
            mask &= k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(s > NEG_INF * 0.5, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v.astype(jnp.float32)
        return acc, m_new, l_new

    dh = q_ref.shape[-1]
    init = (jnp.zeros((block_q, dh), jnp.float32),
            jnp.full((block_q, 1), NEG_INF, jnp.float32),
            jnp.zeros((block_q, 1), jnp.float32))
    # delta is dynamic (it changes per ring round), so no static block
    # skipping here — masking alone decides admissibility.
    acc, m, l = jax.lax.fori_loop(0, n_k, body, init)
    acc_ref[...] = acc
    m_ref[...] = m
    l_ref[...] = l


def _flash_partial(q: jax.Array, k: jax.Array, v: jax.Array,
                   delta: jax.Array, *, causal: bool,
                   window: Optional[int], block_q: int, block_k: int,
                   interpret: bool) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One panel visit: (acc, m, l) of local q against one K/V panel."""
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, -(-S // 8) * 8)
    block_k = min(block_k, -(-T // 8) * 8)
    S_pad = -(-S // block_q) * block_q
    T_pad = -(-T // block_k) * block_k
    q = _pad_to(q, 1, S_pad)
    k = _pad_to(k, 1, T_pad)
    v = _pad_to(v, 1, T_pad)
    delta = jnp.reshape(delta, (1, 1)).astype(jnp.int32)

    grid = (B, H, S_pad // block_q)
    kernel = functools.partial(
        _partial_kernel, scale=1.0 / (dh ** 0.5), causal=causal,
        window=window, block_q=block_q, block_k=block_k, seq_k=T_pad,
        kv_len=T)

    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, i: (0, 0)),
            pl.BlockSpec((None, block_q, None, dh),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, T_pad, None, dh),
                         lambda b, h, i, G=G: (b, 0, h // G, 0)),
            pl.BlockSpec((None, T_pad, None, dh),
                         lambda b, h, i, G=G: (b, 0, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, None, dh),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, block_q, None, 1),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, block_q, None, 1),
                         lambda b, h, i: (b, i, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S_pad, H, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, S_pad, H, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, S_pad, H, 1), jnp.float32),
        ],
        interpret=interpret,
    )(delta, q, k, v)
    if S_pad != S:
        acc, m, l = acc[:, :S], m[:, :S], l[:, :S]
    return acc, m, l


def _merge(state, part):
    """Log-sum-exp combine of two online-softmax states.

    Fully-masked states carry m == NEG_INF with acc == 0, l == 0; the
    exp() of a NEG_INF gap underflows to an exact 0 coefficient, so they
    merge as identity elements without special-casing.
    """
    acc_a, m_a, l_a = state
    acc_b, m_b, l_b = part
    m_new = jnp.maximum(m_a, m_b)
    ca = jnp.exp(m_a - m_new)
    cb = jnp.exp(m_b - m_new)
    return (acc_a * ca + acc_b * cb, m_new, l_a * ca + l_b * cb)


def ring_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         axis_name: str = "seq", axis_size: int,
                         causal: bool = True, window: Optional[int] = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False) -> jax.Array:
    """Sequence-sharded flash attention; call inside ``shard_map``.

    q (B, S/P, H, dh); k/v (B, T/P, KV, dh) — local shards of a sequence
    split over the ``axis_name`` mesh axis of size ``axis_size`` (= P).
    Returns the local (B, S/P, H, dh) output shard, token-identical to
    ``flash_attention`` on the gathered sequence.

    P − 1 ``ppermute`` rounds rotate the K/V panels; each round's
    hand-off is issued before its compute so the collective overlaps the
    kernel (the pipeline runtime's hand-off idiom).  Causally dead
    visits (a panel entirely in this shard's future) still run but
    contribute an all-masked zero state — the merge ignores them.
    """
    P = int(axis_size)
    B, S_loc, H, dh = q.shape
    T_loc, KV = k.shape[1], k.shape[2]
    _validate_attn_shapes(S_loc * P, T_loc * P, H, KV, window)
    if P == 1:
        from repro.kernels.flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)

    idx = jax.lax.axis_index(axis_name)
    q_start = idx * S_loc
    perm = [(i, (i + 1) % P) for i in range(P)]

    state = (jnp.zeros((B, S_loc, H, dh), jnp.float32),
             jnp.full((B, S_loc, H, 1), NEG_INF, jnp.float32),
             jnp.zeros((B, S_loc, H, 1), jnp.float32))
    k_cur, v_cur = k, v
    for r in range(P):
        if r < P - 1:
            # hand-off overlap: rotate the panel we already consumed a
            # copy of BEFORE this round's kernel — no data dependency,
            # so the collective runs under the compute
            k_nxt = jax.lax.ppermute(k_cur, axis_name, perm)
            v_nxt = jax.lax.ppermute(v_cur, axis_name, perm)
        src = (idx - r) % P               # original owner of k_cur/v_cur
        delta = q_start - src * T_loc
        part = _flash_partial(q, k_cur, v_cur, delta, causal=causal,
                              window=window, block_q=block_q,
                              block_k=block_k, interpret=interpret)
        state = _merge(state, part)
        if r < P - 1:
            k_cur, v_cur = k_nxt, v_nxt

    acc, _, l = state
    o = jnp.where(l > 0.0, acc / jnp.where(l > 0.0, l, 1.0), 0.0)
    return o.astype(q.dtype)
