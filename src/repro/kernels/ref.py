"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q (B,S,H,dh); k/v (B,T,KV,dh) grouped-query attention."""
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, dh).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(dh))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no visible key are exact zeros (matching the kernel's
    # masked-row semantics), not a softmax average over the -1e30 sentinel
    any_visible = mask.any(axis=-1)                          # (S,)
    probs = jnp.where(any_visible[None, None, None, :, None], probs, 0.0)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, dh).astype(q.dtype)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, chunk: int = 64) -> jax.Array:
    """Chunked SSD oracle — delegates to the model-layer implementation
    (itself validated against the sequential one-step recurrence)."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, Bm, Cm, chunk)


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)
