"""Blocked flash attention for TPU (Pallas), GQA + causal + sliding window.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * blocking is VMEM-resident: the q block (block_q x dh) and this
    (batch, head)'s full K/V panels are staged in VMEM by BlockSpec; the
    online-softmax loop walks K/V in ``block_k`` slices with MXU-friendly
    (128-multiple) tile shapes,
  * running max/sum are rank-2 (block_q, 1) fp32 — TPU VREGs want >=2D,
  * no warp-level shuffles: the reduction happens in-register per block,
    which is the natural systolic-array formulation.

Context beyond ~8k per device should arrive already sequence-sharded
(GSPMD), each shard calling this kernel on its local panel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                  window: Optional[int], block_q: int, block_k: int,
                  seq_k: int):
    # q_ref: (block_q, dh); k_ref/v_ref: (seq_k, dh); o_ref: (block_q, dh)
    iq = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    n_k = seq_k // block_k

    def body(ik, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (pl.dslice(ik * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(ik * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T                       # (bq, bk)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v.astype(jnp.float32)
        return acc, m_new, l_new

    dh = q_ref.shape[-1]
    init = (jnp.zeros((block_q, dh), jnp.float32),
            jnp.full((block_q, 1), NEG_INF, jnp.float32),
            jnp.zeros((block_q, 1), jnp.float32))
    if causal:
        # only walk K blocks that can intersect this q block
        hi = jnp.minimum(n_k, (iq + 1) * block_q // block_k + 1)
    else:
        hi = n_k
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (iq * block_q - window) // block_k)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, init)
    o_ref[...] = (acc / jnp.maximum(l, 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q (B,S,H,dh); k/v (B,T,KV,dh) -> (B,S,H,dh)."""
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0

    grid = (B, H, S // block_q)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (dh ** 0.5), causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_k=T)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, None, dh),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, T, None, dh),
                         lambda b, h, i, G=G: (b, 0, h // G, 0)),
            pl.BlockSpec((None, T, None, dh),
                         lambda b, h, i, G=G: (b, 0, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, dh),
                               lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
