"""Blocked flash attention for TPU (Pallas), GQA + causal + sliding window.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * blocking is VMEM-resident: the q block (block_q x dh) and this
    (batch, head)'s full K/V panels are staged in VMEM by BlockSpec; the
    online-softmax loop walks K/V in ``block_k`` slices with MXU-friendly
    (128-multiple) tile shapes,
  * running max/sum are rank-2 (block_q, 1) fp32 — TPU VREGs want >=2D,
  * no warp-level shuffles: the reduction happens in-register per block,
    which is the natural systolic-array formulation.

Ragged lengths: S and T need not be block multiples — inputs are padded
up to the block grid and the kernel masks out-of-range k positions
(padded q rows are computed and sliced off).  Rows whose mask admits no
key at all (tiny window + causal corners) produce exact zeros.

Context beyond ~8k per device arrives sequence-sharded; each shard calls
the ring variant (``kernels/ring_attention.py``) which walks the K/V
panels around the ``seq`` mesh axis.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _validate_attn_shapes(S: int, T: int, H: int, KV: int,
                          window: Optional[int]) -> None:
    """Reject genuinely unsupported shapes with descriptive errors."""
    if KV <= 0 or H % KV != 0:
        raise ValueError(
            f"GQA requires n_heads divisible by n_kv_heads; got H={H}, "
            f"KV={KV} (H % KV = {H % KV}) — integer grouping would "
            f"silently mis-route queries to the wrong KV head")
    if window is not None:
        if window <= 0:
            raise ValueError(
                f"sliding window must be a positive span, got window="
                f"{window} (every position would be masked)")
        if window > T:
            raise ValueError(
                f"sliding window {window} exceeds the key length T={T}; "
                f"pass window=None for full attention over this context")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                  window: Optional[int], block_q: int, block_k: int,
                  seq_k: int, kv_len: int):
    # q_ref: (block_q, dh); k_ref/v_ref: (seq_k, dh); o_ref: (block_q, dh)
    # seq_k is the padded panel length; kv_len the number of real keys.
    iq = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32) * scale
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    n_k = seq_k // block_k

    def body(ik, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (pl.dslice(ik * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(ik * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T                       # (bq, bk)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if kv_len < seq_k:                  # padded K/V tail: never attended
            mask &= k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # all-masked rows keep m_new == NEG_INF; exp(NEG_INF - NEG_INF)
        # would be 1 with a finite sentinel, so zero those lanes explicitly
        p = jnp.where(s > NEG_INF * 0.5, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v.astype(jnp.float32)
        return acc, m_new, l_new

    dh = q_ref.shape[-1]
    init = (jnp.zeros((block_q, dh), jnp.float32),
            jnp.full((block_q, 1), NEG_INF, jnp.float32),
            jnp.zeros((block_q, 1), jnp.float32))
    if causal:
        # only walk K blocks that can intersect this q block
        hi = jnp.minimum(n_k, (iq + 1) * block_q // block_k + 1)
    else:
        hi = n_k
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (iq * block_q - window) // block_k)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, init)
    # rows with no admissible key (l == 0) are exact zeros, not acc/eps noise
    o = jnp.where(l > 0.0, acc / jnp.where(l > 0.0, l, 1.0), 0.0)
    o_ref[...] = o.astype(o_ref.dtype)


def _pad_to(x: jax.Array, axis: int, size: int) -> jax.Array:
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q (B,S,H,dh); k/v (B,T,KV,dh) -> (B,S,H,dh).

    Arbitrary (ragged) S/T are padded up to the block grid; out-of-range
    keys are masked in-kernel and padded q rows sliced off the output.
    """
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    _validate_attn_shapes(S, T, H, KV, window)
    G = H // KV
    block_q = min(block_q, -(-S // 8) * 8)
    block_k = min(block_k, -(-T // 8) * 8)
    S_pad = -(-S // block_q) * block_q
    T_pad = -(-T // block_k) * block_k
    q = _pad_to(q, 1, S_pad)
    k = _pad_to(k, 1, T_pad)
    v = _pad_to(v, 1, T_pad)

    grid = (B, H, S_pad // block_q)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (dh ** 0.5), causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_k=T_pad, kv_len=T)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, None, dh),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, T_pad, None, dh),
                         lambda b, h, i, G=G: (b, 0, h // G, 0)),
            pl.BlockSpec((None, T_pad, None, dh),
                         lambda b, h, i, G=G: (b, 0, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, dh),
                               lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S_pad, H, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :S] if S_pad != S else out
