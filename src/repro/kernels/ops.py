"""jit'd public wrappers for the Pallas kernels.

On non-TPU backends (this CPU container) the kernels execute with
``interpret=True`` — the kernel body runs step-by-step on CPU, validating
BlockSpec indexing and the numerical algorithm against ``ref.py``.
On TPU the same call sites compile to Mosaic.
"""
from __future__ import annotations

from typing import Optional

import jax

from . import flash_attention as _fa
from . import ring_attention as _ra
from . import rmsnorm as _rn
from . import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())


def ring_flash_attention(q, k, v, *, axis_name: str = "seq", axis_size: int,
                         causal: bool = True, window: Optional[int] = None,
                         block_q: int = 128, block_k: int = 128):
    """Sequence-sharded flash attention (call inside shard_map)."""
    return _ra.ring_flash_attention(
        q, k, v, axis_name=axis_name, axis_size=axis_size, causal=causal,
        window=window, block_q=block_q, block_k=block_k,
        interpret=_interpret())


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 64):
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                         interpret=_interpret())


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256):
    return _rn.rmsnorm(x, w, eps=eps, block_rows=block_rows,
                       interpret=_interpret())
