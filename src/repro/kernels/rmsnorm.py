"""Fused RMSNorm Pallas kernel (row-blocked, fp32 accumulation in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (block_rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x (..., d), w (d,)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows //= 2
    block_rows = max(1, block_rows)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
