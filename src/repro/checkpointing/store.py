"""Sharded-pytree checkpointing.

Parameters/optimizer state are flattened by tree path into a single ``.npz``
per step directory, with a JSON manifest carrying step metadata.  Arrays are
fetched shard-by-shard via ``jax.device_get`` (fully-addressable process);
restore re-shards through the executor's out_shardings.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            flat[key + "@bf16"] = arr.astype(np.float32)
        else:
            flat[key] = arr
    return flat


def save_pytree(tree, path: str | pathlib.Path) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_pytree(template, path: str | pathlib.Path):
    """Restore into the structure of ``template`` (same tree paths)."""
    data = np.load(pathlib.Path(path), allow_pickle=False)
    lookup = {}
    for k in data.files:
        if k.endswith("@bf16"):
            lookup[k[:-5]] = data[k].astype(jnp.bfloat16)
        else:
            lookup[k] = data[k]

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_keys, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path_keys)
        arr = lookup[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


def save_train_state(step: int, params, opt_state,
                     directory: str | pathlib.Path,
                     extra: Optional[Dict[str, Any]] = None) -> pathlib.Path:
    d = pathlib.Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    save_pytree(params, d / "params.npz")
    save_pytree(opt_state, d / "opt_state.npz")
    (d / "meta.json").write_text(json.dumps({"step": step, **(extra or {})}))
    return d


def restore_train_state(params_template, opt_template,
                        directory: str | pathlib.Path,
                        step: Optional[int] = None) -> Tuple[Any, Any, int]:
    d = pathlib.Path(directory)
    if step is None:
        cands = sorted(d.glob("step_*"))
        if not cands:
            raise FileNotFoundError(f"no checkpoints under {d}")
        d = cands[-1]
    else:
        d = d / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    params = load_pytree(params_template, d / "params.npz")
    opt_state = load_pytree(opt_template, d / "opt_state.npz")
    return params, opt_state, int(meta["step"])
