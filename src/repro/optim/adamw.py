"""AdamW in pure JAX with mixed-precision model states.

Matches the paper's memory accounting: bf16 live params + fp32 master copy,
fp32 first/second moments (16 bytes/param total with bf16 grads).  The
optimizer state is a pytree congruent with the params, so whatever sharding
the plan assigns to a parameter automatically applies to its states (ZeRO
partitioning falls out of the SDP sharding rules).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # optimizer-state precision: "fp32" (16 B/param total, paper default)
    # or "bf16" moments + fp32 master (10 B/param) — the lever that brings
    # kimi-k2-scale state under HBM (EXPERIMENTS.md capacity note)
    state_dtype: str = "fp32"


def adamw_init(params, cfg: "AdamWConfig" = None) -> Dict[str, Any]:
    mdt = jnp.bfloat16 if (cfg and cfg.state_dtype == "bf16") else jnp.float32
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
    }


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cfg.lr * lr_scale

    b1c = 1.0 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(master, g, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32) * clip
        m = cfg.beta1 * m.astype(jnp.float32) + (1.0 - cfg.beta1) * g
        v = cfg.beta2 * v.astype(jnp.float32) + (1.0 - cfg.beta2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                    + cfg.weight_decay * master)
        return new_master, m.astype(mdt), v.astype(mdt)

    flat_master, treedef = jax.tree_util.tree_flatten(state["master"])
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    new = [upd(a, b, c, d) for a, b, c, d in zip(flat_master, flat_g, flat_m, flat_v)]
    master = treedef.unflatten([t[0] for t in new])
    m = treedef.unflatten([t[1] for t in new])
    v = treedef.unflatten([t[2] for t in new])
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"step": step, "master": master, "m": m, "v": v}, metrics
