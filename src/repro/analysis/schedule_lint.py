"""Schedule verifier: happens-before certification of ``ScheduleProgram``
program tables (rule ids ``SCH001``–``SCH010``, catalog in
``docs/analysis.md``).

The pipeline runtime replays a compiled table blindly — one generic
``lax.scan`` over whatever the compiler emitted — so a wrong table is a
silent wrong answer (stale-activation read) or a real-hardware deadlock.
This pass certifies an arbitrary table *independently of the compiler and
the cost model that priced it*:

  1. **Happens-before graph.**  Every valid slot is an event
     ``(phase, virtual stage, micro-batch)``; its dependencies (upstream
     forward hand-off, downstream activation-gradient, same-slot F→B→W
     chain) must all be scheduled at strictly earlier ticks.  Because
     events carry tick assignments, any dependency *cycle* necessarily
     contains a non-forward edge, so cycle detection (deadlock) reduces to
     checking every edge (SCH001).  Missing producers are use-before-def
     (SCH002); duplicated events double-consume their input buffer
     (SCH003).
  2. **Liveness certification.**  Per stage, the peak number of live
     activation sets is derived by interval analysis — directly from the
     F/B/W tick intervals for three-phase tables, from an independent
     event simulation of the flush backward for ``1f1b``, from the
     stash-to-flush rule for ``gpipe``, and from the Megatron warm-up
     depth for interleaved programs.  The certified counts are pinned
     *exactly* against ``core/pipeline_balance.py``
     (``inflight_microbatches`` / ``zb_w_pending_max``): cost-model drift
     is an error (SCH007), as is exceeding the schedule's in-flight cap
     (SCH006).
  3. **Bubble re-derivation.**  The compiled bubble tick count is
     recomputed from the table and pinned against the priced
     ``bubble_fraction`` (SCH008) — a schedule the model oversells (e.g.
     a ragged interleaved group) is rejected before the search can emit
     it.

``verify_program`` returns structured diagnostics; ``certify_program``
wraps it in a report.  ``compile_schedule(..., validate=True)`` routes
here, making this module the single source of truth for the program-table
invariants that used to live only in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost_model import bubble_fraction
from repro.core.pipeline_balance import (ZB_W_ACT_FRAC, inflight_microbatches,
                                         zb_w_pending_max)
from repro.runtime.schedules import (PHASE_B, PHASE_F, PHASE_W,
                                     ScheduleProgram)

from .diagnostics import Diagnostic, DiagnosticReport, error, info

_PHASE_NAME = {PHASE_F: "F", PHASE_B: "B", PHASE_W: "W"}

# numeric tolerance for fractional (per-chunk / ZB_W_ACT_FRAC) set counts;
# the cross-checks are exact in exact arithmetic, this only absorbs float
# representation of x/V
_TOL = 1e-9


@dataclasses.dataclass(frozen=True)
class StageCertificate:
    """Certified liveness numbers for one pipeline stage."""

    stage: int
    fwd_stash: float        # peak forward activation sets held (full-stage
                            # units; interleaved chunks count 1/V each)
    w_pending: int          # peak completed-B-but-pending-W sets (zb only)
    live_sets: float        # cost-model units: fwd + ZB_W_ACT_FRAC*pending

    @property
    def modeled_units(self) -> float:
        return self.live_sets


def _loc(pr: ScheduleProgram, detail: str = "") -> str:
    base = f"{pr.name}[P={pr.n_stages},m={pr.n_micro},V={pr.n_chunks}]"
    return f"{base} {detail}" if detail else base


# ---------------------------------------------------------------------------
# event extraction + structural checks
# ---------------------------------------------------------------------------

def _collect_events(pr: ScheduleProgram, out: List[Diagnostic]
                    ) -> Dict[Tuple[int, int, int], Tuple[int, int]]:
    """Map ``(phase, virtual stage, micro-batch) -> (tick, device)`` for
    every valid slot, flagging malformed indices (SCH010) and duplicates
    (SCH003) along the way."""
    P, m, V = pr.n_stages, pr.n_micro, pr.n_chunks
    events: Dict[Tuple[int, int, int], Tuple[int, int]] = {}
    for t in range(pr.n_ticks):
        for i in range(P):
            if not pr.valid[t, i]:
                continue
            mb = int(pr.mb_index[t, i])
            v = int(pr.chunk_index[t, i])
            ph = int(pr.phase[t, i])
            if not 0 <= mb < m or not 0 <= v < V or ph not in _PHASE_NAME:
                out.append(error(
                    "SCH010", _loc(pr, f"tick {t} stage {i}"),
                    f"malformed slot: mb={mb} (m={m}), chunk={v} (V={V}), "
                    f"phase={ph}",
                    "indices must satisfy 0<=mb<m, 0<=chunk<V, "
                    "phase in {F,B,W}"))
                continue
            key = (ph, v * P + i, mb)
            if key in events:
                pt, pi = events[key]
                out.append(error(
                    "SCH003", _loc(pr, f"tick {t} stage {i}"),
                    f"duplicate {_PHASE_NAME[ph]} for virtual stage "
                    f"{v * P + i}, micro-batch {mb} (already at tick {pt} "
                    f"stage {pi}) — the buffer would be double-consumed",
                    "each (phase, virtual stage, micro-batch) must be "
                    "scheduled exactly once"))
                continue
            events[key] = (t, i)
    return events


def _check_coverage(pr: ScheduleProgram, events, out: List[Diagnostic]) -> None:
    """Every (virtual stage, micro-batch) needs one F — and one B and one W
    when the table is three-phase (SCH004)."""
    P, m, V = pr.n_stages, pr.n_micro, pr.n_chunks
    phases = ((PHASE_F, PHASE_B, PHASE_W) if pr.is_three_phase
              else (PHASE_F,))
    for ph in phases:
        for s in range(P * V):
            for mb in range(m):
                if (ph, s, mb) not in events:
                    out.append(error(
                        "SCH004", _loc(pr, f"virtual stage {s}"),
                        f"missing {_PHASE_NAME[ph]} tick for micro-batch "
                        f"{mb}: the program drops work",
                        "every (virtual stage, micro-batch) must appear "
                        "once per phase"))


def _check_happens_before(pr: ScheduleProgram, events,
                          out: List[Diagnostic]) -> None:
    """Every dependency edge must point strictly forward in tick time
    (SCH001); a missing producer is a use-before-def (SCH002).

    Edges, for event ``(ph, s, mb)`` at tick ``t``:
      * F(s) <- F(s-1): the upstream hand-off (s > 0);
      * B(i) <- F(i) and B(i) <- B(i+1): the activation-gradient chain
        (three-phase tables, where s == i);
      * W(i) <- B(i): the weight gradient needs its activation gradient.

    With ticks assigned, any dependency cycle must contain an edge whose
    consumer does not run strictly after its producer — so SCH001 is also
    the deadlock (cycle) check.
    """
    P = pr.n_stages

    def need(consumer_key, producer_key, why: str, deadlock: str):
        t, i = events[consumer_key]
        prod = events.get(producer_key)
        cname = _PHASE_NAME[consumer_key[0]]
        if prod is None:
            out.append(error(
                "SCH002", _loc(pr, f"tick {t} stage {i}"),
                f"{cname}(vs={consumer_key[1]}, mb={consumer_key[2]}) "
                f"consumes {why}, but that producer tick is missing "
                "(use-before-def: the buffer was never written)",
                "restore the producer slot or drop the consumer"))
            return
        pt, pi = prod
        if pt >= t:
            out.append(error(
                "SCH001", _loc(pr, f"tick {t} stage {i}"),
                f"happens-before violation: "
                f"{cname}(vs={consumer_key[1]}, mb={consumer_key[2]}) at "
                f"tick {t} needs {why} which runs at tick {pt} (stage {pi})"
                f" — {deadlock}",
                "the producer must be scheduled at a strictly earlier "
                "tick"))

    for (ph, s, mb), (t, i) in events.items():
        if ph == PHASE_F:
            if s > 0:
                need((PHASE_F, s, mb), (PHASE_F, s - 1, mb),
                     f"the forward hand-off from virtual stage {s - 1}",
                     "on real hardware both stages would wait on each "
                     "other's ppermute (deadlock)")
        elif ph == PHASE_B:
            need((PHASE_B, s, mb), (PHASE_F, s, mb),
                 "its own forward activations",
                 "the backward would read a stale or absent stash")
            if s < P * pr.n_chunks - 1:
                need((PHASE_B, s, mb), (PHASE_B, s + 1, mb),
                     f"the downstream activation gradient from virtual "
                     f"stage {s + 1}",
                     "the gradient hand-off would deadlock")
        elif ph == PHASE_W:
            need((PHASE_W, s, mb), (PHASE_B, s, mb),
                 "its own activation-gradient (B) tick",
                 "the weight gradient would use an unconsumed cotangent")


def _check_ring_handoff(pr: ScheduleProgram, out: List[Diagnostic]) -> None:
    """The executable invariant of the single-``ppermute`` runtime: every
    valid slot's producer sits exactly one tick earlier on the ring-
    adjacent device (SCH009).  For three-phase tables the runtime executes
    the *forward projection* instead, which exists iff every stage's F
    slots process micro-batches in flush order."""
    P = pr.n_stages
    if pr.is_three_phase:
        for i in range(P):
            mbs = pr.mb_index[pr.f_valid[:, i], i]
            want = np.arange(pr.n_micro)
            if mbs.shape != want.shape or (mbs != want).any():
                out.append(error(
                    "SCH009", _loc(pr, f"stage {i}"),
                    "three-phase F slots are not in flush order; no dense "
                    "forward projection exists for the tick-loop runtime",
                    "keep per-stage F order = micro-batch 0..m-1"))
        return
    for t in range(pr.n_ticks):
        for i in range(P):
            if not pr.valid[t, i]:
                continue
            s = int(pr.chunk_index[t, i]) * P + i
            mb = int(pr.mb_index[t, i])
            if s == 0:
                continue
            ip = (i - 1) % P
            ok = (t >= 1 and pr.valid[t - 1, ip]
                  and int(pr.mb_index[t - 1, ip]) == mb
                  and int(pr.chunk_index[t - 1, ip]) * P + ip == s - 1)
            if not ok:
                out.append(error(
                    "SCH009", _loc(pr, f"tick {t} stage {i}"),
                    f"virtual stage {s} mb={mb} has no producer at "
                    f"(tick {t - 1}, stage {ip}): the single-ppermute "
                    "hand-off would deliver bubble garbage into a counted "
                    "value",
                    "consecutive virtual stages must sit one tick and one "
                    "ring hop apart"))


def _check_loss_coverage(pr: ScheduleProgram, out: List[Diagnostic]) -> None:
    """Each micro-batch's loss fires exactly once, on the last virtual
    stage's F slot (SCH005)."""
    P, m, V = pr.n_stages, pr.n_micro, pr.n_chunks
    counts = np.zeros(m, np.int64)
    for t in range(pr.n_ticks):
        for i in range(P):
            if not pr.loss_valid[t, i]:
                continue
            loc = _loc(pr, f"tick {t} stage {i}")
            if not pr.valid[t, i] or int(pr.phase[t, i]) != PHASE_F:
                out.append(error(
                    "SCH005", loc,
                    "loss_valid set on a bubble or non-forward slot",
                    "loss accumulates only where forward work runs"))
                continue
            if i != P - 1 or int(pr.chunk_index[t, i]) != V - 1:
                out.append(error(
                    "SCH005", loc,
                    f"loss scheduled on virtual stage "
                    f"{int(pr.chunk_index[t, i]) * P + i}, not the last "
                    f"({P * V - 1})",
                    "only the last virtual stage holds the head"))
                continue
            mb = int(pr.mb_index[t, i])
            if 0 <= mb < m:
                counts[mb] += 1
    for mb in range(m):
        if counts[mb] != 1:
            out.append(error(
                "SCH005", _loc(pr, f"micro-batch {mb}"),
                f"loss fires {int(counts[mb])} times (want exactly 1)",
                "each micro-batch contributes its loss exactly once"))


# ---------------------------------------------------------------------------
# liveness certification
# ---------------------------------------------------------------------------

def _max_overlap(starts: np.ndarray, ends: np.ndarray) -> int:
    """Peak number of [start, end) intervals alive at once."""
    ev = sorted([(int(t), 1) for t in starts] + [(int(t), -1) for t in ends])
    c = mx = 0
    for _, d in ev:
        c += d
        mx = max(mx, c)
    return mx


def _simulate_flush_backward(P: int, m: int) -> Tuple[np.ndarray, np.ndarray]:
    """Independent event simulation of the 1F1B-flush schedule: each stage
    greedily runs the oldest ready backward, else the oldest ready forward
    under the defining warm-up constraint (a stage never holds more
    forwards than ``P - i`` un-backwarded micro-batches).  Returns (P, m)
    forward/backward tick matrices; the *measured* peak stash is then an
    interval fact, not a formula."""
    NONE = -1
    ft = np.full((P, m), NONE, np.int64)
    bt = np.full((P, m), NONE, np.int64)
    f_done = [0] * P
    b_done = [0] * P
    t = 0
    limit = 4 * m + 4 * P + 8
    while min(b_done) < m and t < limit:
        acts: List[Optional[Tuple[int, int]]] = []
        for i in range(P):
            j = b_done[i]
            b_ready = (j < m and 0 <= ft[i, j] < t
                       and (i == P - 1 or 0 <= bt[i + 1, j] < t))
            k = f_done[i]
            f_ready = (k < m and (i == 0 or 0 <= ft[i - 1, k] < t)
                       and f_done[i] - b_done[i] < P - i)
            acts.append((PHASE_B, j) if b_ready
                        else (PHASE_F, k) if f_ready else None)
        for i, act in enumerate(acts):
            if act is None:
                continue
            ph, mb = act
            if ph == PHASE_F:
                ft[i, mb] = t
                f_done[i] += 1
            else:
                bt[i, mb] = t
                b_done[i] += 1
        t += 1
    assert min(b_done) == m, "flush-backward simulation did not converge"
    return ft, bt


def _megatron_warmup_chunks(stage: int, n_stages: int, n_chunks: int) -> int:
    """Forward chunks device ``stage`` banks before its first backward in
    the depth-first interleaved 1F1B schedule (Megatron-LM
    ``forward_backward_pipelining_with_interleaving``): two per downstream
    device, one full round per extra model chunk, plus the steady-state
    chunk in flight.  Defined here *independently* of
    ``core/pipeline_balance.py`` so formula drift on either side trips
    SCH007."""
    return 2 * (n_stages - 1 - stage) + (n_chunks - 1) * n_stages + 1


def certify_live_buffers(pr: ScheduleProgram) -> List[StageCertificate]:
    """Per-stage certified peak live activation sets, by liveness analysis.

    * three-phase (``zb-h1``): measured straight off the table — forward
      stash is the peak overlap of per-micro-batch [F, B) tick intervals,
      the deferred weight-gradient pile the peak overlap of [B, W);
    * ``1f1b``: measured on an independent flush-backward event
      simulation (:func:`_simulate_flush_backward`);
    * ``gpipe`` (no remat): stash-to-flush — every forward set lives until
      the post-program backward, so the peak is the per-stage F count;
    * ``1f1b-interleaved``: the Megatron depth-first warm-up depth in
      chunks (:func:`_megatron_warmup_chunks`, capped at the ``m·V``
      chunks that exist), divided by ``V`` for full-stage units.

    The returned units are exactly the ones
    ``cost_model``/``pipeline_balance`` price, so the SCH007 cross-check
    is an equality, not a bound.
    """
    P, m, V = pr.n_stages, pr.n_micro, pr.n_chunks
    out: List[StageCertificate] = []
    if pr.is_three_phase:
        ft = np.full((P, m), -1, np.int64)
        bt = np.full((P, m), -1, np.int64)
        wt = np.full((P, m), -1, np.int64)
        by_phase = {PHASE_F: ft, PHASE_B: bt, PHASE_W: wt}
        for t in range(pr.n_ticks):
            for i in range(P):
                if pr.valid[t, i] and int(pr.phase[t, i]) in by_phase:
                    mb = int(pr.mb_index[t, i])
                    if 0 <= mb < m:
                        by_phase[int(pr.phase[t, i])][i, mb] = t
        big = pr.n_ticks + 1     # missing ticks -> interval to program end
        for i in range(P):
            f = np.where(ft[i] >= 0, ft[i], big)
            b = np.where(bt[i] >= 0, bt[i], big)
            w = np.where(wt[i] >= 0, wt[i], big)
            stash = _max_overlap(f[f <= big], np.maximum(b, f))
            pending = _max_overlap(b[b < big], np.maximum(w, b)[b < big])
            out.append(StageCertificate(
                i, float(stash), int(pending),
                stash + ZB_W_ACT_FRAC * pending))
        return out
    if pr.name == "1f1b":
        ft, bt = _simulate_flush_backward(P, m)
        for i in range(P):
            stash = _max_overlap(ft[i], bt[i])
            out.append(StageCertificate(i, float(stash), 0, float(stash)))
        return out
    if pr.name == "1f1b-interleaved":
        for i in range(P):
            chunks = min(_megatron_warmup_chunks(i, P, V), m * V)
            out.append(StageCertificate(i, chunks / V, 0, chunks / V))
        return out
    # gpipe / any no-remat flush table: stash-to-flush
    for i in range(P):
        stash = int(pr.valid[:, i].sum())
        out.append(StageCertificate(i, float(stash), 0, float(stash)))
    return out


def _check_liveness(pr: ScheduleProgram, out: List[Diagnostic]) -> None:
    """SCH006 (in-flight cap) + SCH007 (cost-model drift)."""
    P, m = pr.n_stages, pr.n_micro
    certs = certify_live_buffers(pr)
    for c in certs:
        i = c.stage
        if pr.name in ("1f1b", "zb-h1"):
            cap = min(P - i, m)
            if c.fwd_stash > cap + _TOL:
                out.append(error(
                    "SCH006", _loc(pr, f"stage {i}"),
                    f"forward stash peaks at {c.fwd_stash:g} activation "
                    f"sets, above the flush in-flight cap min(P-i, m) = "
                    f"{cap}",
                    "delay forwards until a backward retires a set"))
        if pr.name == "zb-h1":
            want_w = zb_w_pending_max(i, P, m)
            if c.w_pending != want_w:
                out.append(error(
                    "SCH007", _loc(pr, f"stage {i}"),
                    f"certified deferred-W pile is {c.w_pending}, but the "
                    f"cost model prices zb_w_pending_max = {want_w}",
                    "re-align core/pipeline_balance.zb_w_pending_max with "
                    "the compiled deferral depth"))
        modeled = inflight_microbatches(i, P, m, pr.name, pr.n_chunks)
        if abs(c.live_sets - modeled) > _TOL:
            out.append(error(
                "SCH007", _loc(pr, f"stage {i}"),
                f"certified peak live buffers = {c.live_sets:g} activation "
                f"sets, but inflight_microbatches prices {modeled:g} — "
                "the memory model and the program have drifted",
                "fix whichever side is wrong; the searcher's feasibility "
                "claims depend on them agreeing"))
    out.append(info(
        "SCH007", _loc(pr),
        "certified peak live buffers per stage: "
        + ", ".join(f"{c.live_sets:g}" for c in certs)
        + " (== cost model)" ))


def _check_bubble(pr: ScheduleProgram, out: List[Diagnostic]) -> None:
    """Re-derive the bubble from the table and pin it against the priced
    ``bubble_fraction`` (SCH008)."""
    busy = int(pr.valid.sum(axis=0).max()) if pr.n_ticks else 0
    compiled = pr.n_ticks - busy
    priced = bubble_fraction(pr.n_stages, pr.n_micro, pr.n_chunks,
                             pr.name) * pr.work_ticks_per_stage
    if abs(compiled - priced) > _TOL:
        direction = ("undersells" if compiled < priced else "oversells")
        out.append(error(
            "SCH008", _loc(pr),
            f"compiled bubble is {compiled} tick(s) but the cost model "
            f"prices {priced:g} — the model {direction} this program",
            "the search must only propose (schedule, P, m, V) combos "
            "whose compiled bubble matches the analytic term "
            "(ragged interleaved groups / zb-h1 with m < P are dropped)"))
    else:
        out.append(info(
            "SCH008", _loc(pr),
            f"compiled bubble = priced bubble = {compiled} tick(s)"))


# ---------------------------------------------------------------------------
# grid enumeration (CLI + CI + tests share one notion of "legal combo")
# ---------------------------------------------------------------------------

#: default certification grid (the acceptance grid): P x m x V
DEFAULT_GRID = ((1, 2, 4, 8), tuple(range(1, 17)), (1, 2))


def schedule_legal(name: str, n_stages: int, n_micro: int,
                   n_chunks: int = 1) -> bool:
    """Can ``compile_schedule(name, P, m, V)`` produce a program the cost
    model prices exactly?  Mirrors ``core/optimizer._schedule_candidates``:
    interleaving needs P > 1, V >= 2 and m % P == 0 (ragged groups change
    the bubble); zb-h1 needs P > 1 and a full pipeline (m >= P)."""
    if name in ("gpipe", "1f1b"):
        return n_chunks == 1 and n_stages >= 1 and n_micro >= 1
    if name == "1f1b-interleaved":
        return (n_chunks >= 2 and n_stages > 1 and n_micro >= 1
                and n_micro % n_stages == 0)
    if name == "zb-h1":
        return n_chunks == 1 and n_stages > 1 and n_micro >= n_stages
    return False


def schedule_grid(stages=DEFAULT_GRID[0], micros=DEFAULT_GRID[1],
                  chunks=DEFAULT_GRID[2]):
    """Yield every legal ``(name, P, m, V)`` combo over the given axes."""
    from repro.runtime.schedules import SCHEDULE_NAMES
    for name in SCHEDULE_NAMES:
        for P in stages:
            for m in micros:
                for V in chunks:
                    if schedule_legal(name, P, m, V):
                        yield name, P, m, V


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def verify_program(pr: ScheduleProgram) -> List[Diagnostic]:
    """Run every schedule check on one compiled program table.

    Returns the full diagnostic list (including ``info`` certification
    telemetry); error severity means the table must not be executed or
    serialized into a plan.
    """
    out: List[Diagnostic] = []
    events = _collect_events(pr, out)
    _check_coverage(pr, events, out)
    _check_happens_before(pr, events, out)
    _check_ring_handoff(pr, out)
    _check_loss_coverage(pr, out)
    _check_liveness(pr, out)
    _check_bubble(pr, out)
    return out


def certify_program(pr: ScheduleProgram) -> DiagnosticReport:
    """:func:`verify_program` wrapped in a :class:`DiagnosticReport`."""
    return DiagnosticReport().extend(verify_program(pr))
