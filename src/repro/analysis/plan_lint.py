"""Plan verifier: static checks on ``ParallelPlan`` JSON, every format
version (rule ids ``PLN001``–``PLN012``, catalog in ``docs/analysis.md``).

The search emits a plan; the runtime executes it — possibly in a
different process, weeks later, from a file somebody hand-edited.  This
pass certifies the *file*: field presence and types (so a malformed plan
is a structured diagnostic naming the offending field, not a bare
``KeyError``), format-version sanity, degree arithmetic against the mesh
the launcher will build (``launch/mesh.py``), per-layer strategy totals,
stage-boundary sharding hand-offs (``runtime/sharding.py`` policy
reduction), schedule legality (shared with the schedule verifier's
``schedule_legal``), and estimator self-consistency.

Two entry points:

  * :func:`verify_plan_json` — raw ``dict`` (any version, possibly
    malformed); structural rules run first and semantic rules only on a
    loadable plan.
  * :func:`verify_plan` — an already-typed :class:`ParallelPlan`.

``load_plan_file`` wraps both into the loading path used by the train
CLI: parse, verify, raise :class:`DiagnosticError` on error severity.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

from repro.core.plan import PLAN_FORMAT_VERSION, ParallelPlan
from repro.core.strategy import Strategy

from .diagnostics import (Diagnostic, DiagnosticReport, error, info, warning)
from .schedule_lint import schedule_legal

#: required keys in every plan JSON, with the Python types we accept
_REQUIRED = {
    "n_devices": int,
    "pp_degree": int,
    "partition": list,
    "strategies": list,
    "global_batch": int,
    "n_micro": int,
}

_SINGLE_CHUNK = ("gpipe", "1f1b", "zb-h1")


def detect_format_version(d: Dict) -> int:
    """Infer the format version of a raw plan dict (see core/plan.py):
    explicit ``format_version`` stamp (v2+), else a non-default
    ``ep_degree`` implies v5, else a non-default ``sp_degree``/``seq_len``
    implies v4, else a non-null ``serving`` section implies v3, else
    ``vpp_degree`` implies v1, else v0.  Like ``serving: null``, the
    v4/v5 keys at their defaults (1 / 0 / 1) carry no version signal —
    an older file is indistinguishable from one."""
    if "format_version" in d:
        return int(d["format_version"])
    if isinstance(d, dict) and d.get("ep_degree", 1) != 1:
        return 5
    if isinstance(d, dict) and (d.get("sp_degree", 1) != 1
                                or d.get("seq_len", 0)):
        return 4
    if isinstance(d, dict) and d.get("serving") is not None:
        return 3
    return 1 if ("vpp_degree" in d or "schedule" in d) else 0


# ---------------------------------------------------------------------------
# structural checks on the raw dict
# ---------------------------------------------------------------------------

def _check_structure(d: Dict, loc: str, out: List[Diagnostic]) -> bool:
    """PLN009: field presence + types.  Returns True when the dict is
    structurally loadable (semantic checks can proceed)."""
    ok = True
    if not isinstance(d, dict):
        out.append(error("PLN009", loc,
                         f"plan JSON must be an object, got "
                         f"{type(d).__name__}"))
        return False
    for key, typ in _REQUIRED.items():
        if key not in d:
            out.append(error(
                "PLN009", f"{loc}.{key}",
                f"required field {key!r} is missing",
                "every plan version carries this field; the file is "
                "truncated or not a plan"))
            ok = False
        elif not isinstance(d[key], typ) or isinstance(d[key], bool):
            out.append(error(
                "PLN009", f"{loc}.{key}",
                f"field {key!r} must be {typ.__name__}, got "
                f"{type(d[key]).__name__} ({d[key]!r})"))
            ok = False
    if not ok:
        return False
    for j, s in enumerate(d["strategies"]):
        floc = f"{loc}.strategies[{j}]"
        if (not isinstance(s, dict) or "levels" not in s
                or "ckpt" not in s):
            out.append(error(
                "PLN009", floc,
                "strategy entries need 'levels' and 'ckpt' keys",
                "see docs/plan-format.md for the per-layer schema"))
            ok = False
            continue
        try:
            Strategy.from_json(s)
        except (TypeError, ValueError, KeyError) as e:
            out.append(error(
                "PLN009", floc,
                f"strategy does not parse: {e!r}"))
            ok = False
    return ok


def _check_version(d: Dict, loc: str, strict: bool,
                   out: List[Diagnostic]) -> None:
    """PLN001: format_version sanity + deprecation policy."""
    ver = detect_format_version(d)
    if ver > PLAN_FORMAT_VERSION:
        out.append(error(
            "PLN001", f"{loc}.format_version",
            f"plan declares format_version={ver}, but this build reads "
            f"<= {PLAN_FORMAT_VERSION}: fields added by the newer writer "
            "would be silently dropped",
            "re-emit the plan with this build's search CLI"))
        return
    if ver < 0:
        out.append(error(
            "PLN001", f"{loc}.format_version",
            f"format_version={ver} is not a known version"))
        return
    if ver < PLAN_FORMAT_VERSION:
        mk = error if strict else warning
        out.append(mk(
            "PLN001", f"{loc}.format_version",
            f"deprecated v{ver} plan (current is v{PLAN_FORMAT_VERSION}): "
            "missing keys are filled with the defaults that version "
            "implied (schedule='1f1b', vpp_degree=1, serving=None, "
            "sp_degree=1, ep_degree=1)"
            + (" — rejected under --strict" if strict else ""),
            "re-emit with the current search CLI to pin the schedule "
            "explicitly"))


# ---------------------------------------------------------------------------
# semantic checks on a typed plan
# ---------------------------------------------------------------------------

def verify_plan(plan: ParallelPlan, *, location: str = "plan"
                ) -> List[Diagnostic]:
    """Semantic rules (PLN002–PLN008) on a typed plan."""
    out: List[Diagnostic] = []
    loc = location
    P, n_dev = plan.pp_degree, plan.n_devices

    # --- PLN002: degree divisibility --------------------------------------
    if P < 1 or n_dev < 1:
        out.append(error("PLN002", f"{loc}.pp_degree",
                         f"degrees must be >= 1 "
                         f"(n_devices={n_dev}, pp_degree={P})"))
        return out
    if n_dev % P:
        out.append(error(
            "PLN002", f"{loc}.pp_degree",
            f"n_devices={n_dev} is not divisible by pp_degree={P}: "
            "stages would get ragged device groups",
            "pp_degree must divide the device count"))
        return out
    group = n_dev // P
    for j, s in enumerate(plan.strategies):
        if s.total != group:
            out.append(error(
                "PLN002", f"{loc}.strategies[{j}]",
                f"strategy {s.name()} uses {s.total} device(s), but each "
                f"stage's group has {group} (n_devices/pp_degree)",
                "every layer's level degrees must multiply to the stage "
                "group size"))

    # --- PLN003: partition shape ------------------------------------------
    part = plan.partition
    if len(part) != P:
        out.append(error(
            "PLN003", f"{loc}.partition",
            f"partition has {len(part)} entries for pp_degree={P}"))
    if any(p < 1 for p in part):
        out.append(error(
            "PLN003", f"{loc}.partition",
            f"every stage needs >= 1 layer, got {part}"))
    if sum(part) != len(plan.strategies):
        out.append(error(
            "PLN003", f"{loc}.partition",
            f"partition sums to {sum(part)} layers but the plan carries "
            f"{len(plan.strategies)} per-layer strategies",
            "len(strategies) must equal sum(partition)"))
    if plan.vpp_degree > 1 and part and min(part) < plan.vpp_degree:
        out.append(error(
            "PLN003", f"{loc}.partition",
            f"vpp_degree={plan.vpp_degree} needs >= that many layers per "
            f"stage to form virtual chunks, got min(partition)="
            f"{min(part)}"))

    # --- PLN004: schedule legality ----------------------------------------
    sched, V, m = plan.schedule, plan.vpp_degree, plan.n_micro
    from repro.runtime.schedules import SCHEDULE_NAMES
    if sched not in SCHEDULE_NAMES:
        out.append(error(
            "PLN004", f"{loc}.schedule",
            f"unknown schedule {sched!r} (known: "
            f"{', '.join(SCHEDULE_NAMES)})"))
    elif sched in _SINGLE_CHUNK and V != 1:
        out.append(error(
            "PLN004", f"{loc}.vpp_degree",
            f"{sched} is a single-chunk schedule; vpp_degree must be 1, "
            f"got {V}"))
    elif not schedule_legal(sched, P, m, V):
        why = ("zb-h1 needs pp_degree > 1 and n_micro >= pp_degree (a "
               "full pipeline to hide deferred W ticks)"
               if sched == "zb-h1" else
               "1f1b-interleaved needs pp_degree > 1, vpp_degree >= 2 "
               "and n_micro divisible by pp_degree (ragged groups change "
               "the bubble the model prices)")
        out.append(error(
            "PLN004", f"{loc}.schedule",
            f"schedule={sched} is illegal for pp_degree={P}, "
            f"n_micro={m}, vpp_degree={V}: {why}",
            "the optimizer's _schedule_candidates never proposes this "
            "combo; hand-edited plans must respect it too"))

    # --- PLN005: batch divisibility ---------------------------------------
    if plan.global_batch % m:
        out.append(error(
            "PLN005", f"{loc}.n_micro",
            f"global_batch={plan.global_batch} is not divisible by "
            f"n_micro={m}: micro-batches would be uneven"))

    # --- PLN006: mesh factorization (launch/mesh.py) ----------------------
    # the pipeline runtime builds a (pipe=P, data=group) mesh; each stage's
    # dominant strategy must factor into it: tp divides the group, and all
    # layers of one stage agree on the tp degree (the bridge reduces a
    # segment to one policy — disagreement means silent resharding).
    if len(part) == P and sum(part) == len(plan.strategies):
        for st in range(P):
            ss = plan.stage_strategies(st)
            tps = sorted({s.tp for s in ss})
            if any(group % tp for tp in tps):
                out.append(error(
                    "PLN006", f"{loc}.strategies (stage {st})",
                    f"tp degree(s) {tps} do not divide the stage group "
                    f"({group}): no ('pipe','data') x model mesh "
                    "factorization exists (launch/mesh.py)"))
            elif len(tps) > 1:
                out.append(warning(
                    "PLN006", f"{loc}.strategies (stage {st})",
                    f"stage mixes tp degrees {tps}; the runtime bridge "
                    "(runtime/plan_bridge.py) collapses a stage to one "
                    "policy, so the minority layers silently reshard",
                    "prefer homogeneous tp within a stage"))

    # --- PLN007: stage-boundary sharding hand-off -------------------------
    if len(part) == P and sum(part) == len(plan.strategies) and P > 1:
        mb = plan.global_batch // m if m and plan.global_batch % m == 0 \
            else plan.global_batch
        for st in range(P):
            ss = plan.stage_strategies(st)
            if not ss:
                continue
            for which, s in (("first", ss[0]), ("last", ss[-1])):
                if mb % s.data_degree:
                    out.append(warning(
                        "PLN007", f"{loc}.strategies (stage {st})",
                        f"micro-batch {mb} does not shard over the "
                        f"{which} layer's data degree "
                        f"{s.data_degree} ({s.name()}): the cost model "
                        "prices this, but the shard_map runtime would "
                        "see ragged per-device activation shapes",
                        "pick n_micro so micro_batch % data_degree == 0 "
                        "before executing (estimates are unaffected)"))
        for st in range(P - 1):
            a, b = plan.stage_strategies(st), plan.stage_strategies(st + 1)
            if not a or not b:
                continue                 # empty stage already a PLN003 error
            out_deg, in_deg = a[-1].data_degree, b[0].data_degree
            if out_deg != in_deg:
                out.append(warning(
                    "PLN007", f"{loc}.strategies (stage {st}->{st + 1})",
                    f"boundary activation leaves stage {st} sharded "
                    f"{out_deg}-way but stage {st + 1} expects "
                    f"{in_deg}-way: the hand-off needs an extra "
                    "all-to-all beside the point-to-point send "
                    "(runtime/sharding.py prices only the send)",
                    "match the data degrees across stage boundaries or "
                    "accept the resharding cost"))

    # --- PLN010: serving section vs mesh/degree arithmetic ----------------
    sv = plan.serving
    if sv is not None:
        sloc = f"{loc}.serving"
        for phase, tp, pp in (("decode", sv.decode_tp, sv.decode_pp),
                              ("prefill", sv.prefill_tp, sv.prefill_pp)):
            if tp < 1 or pp < 1:
                out.append(error(
                    "PLN010", f"{sloc}.{phase}_tp",
                    f"{phase} degrees must be >= 1 (tp={tp}, pp={pp})"))
            elif n_dev % (tp * pp):
                out.append(error(
                    "PLN010", f"{sloc}.{phase}_tp",
                    f"{phase} tp*pp = {tp * pp} does not divide "
                    f"n_devices={n_dev}: no serving mesh factorization "
                    "exists (launch/mesh.py)",
                    "tp * pp must divide the device count for each phase"))
        if sv.page_size < 1:
            out.append(error(
                "PLN010", f"{sloc}.page_size",
                f"page_size must be >= 1, got {sv.page_size}"))
        else:
            if sv.page_size & (sv.page_size - 1):
                out.append(warning(
                    "PLN010", f"{sloc}.page_size",
                    f"page_size={sv.page_size} is not a power of two: "
                    "page-index arithmetic compiles to divisions instead "
                    "of shifts on most backends"))
            if sv.max_context < 1 or sv.max_context % sv.page_size:
                out.append(error(
                    "PLN010", f"{sloc}.max_context",
                    f"max_context={sv.max_context} must be a positive "
                    f"multiple of page_size={sv.page_size} (the page "
                    "table addresses whole pages)"))
        if sv.decode_batch < 1:
            out.append(error(
                "PLN010", f"{sloc}.decode_batch",
                f"decode_batch must be >= 1, got {sv.decode_batch}"))
        elif sv.kv_pool_pages and sv.kv_pool_pages < sv.decode_batch:
            out.append(error(
                "PLN010", f"{sloc}.kv_pool_pages",
                f"kv_pool_pages={sv.kv_pool_pages} < decode_batch="
                f"{sv.decode_batch}: the pool cannot give every decode "
                "lane even one page, so full-batch decode deadlocks"))
        if sv.prefill_chunk < 1:
            out.append(error(
                "PLN010", f"{sloc}.prefill_chunk",
                f"prefill_chunk must be >= 1, got {sv.prefill_chunk}"))
        if sv.slo_ms <= 0:
            out.append(error(
                "PLN010", f"{sloc}.slo_ms",
                f"slo_ms must be > 0, got {sv.slo_ms}"))
        elif sv.est_tok_ms > sv.slo_ms > 0:
            out.append(warning(
                "PLN010", f"{sloc}.est_tok_ms",
                f"predicted per-token latency ({sv.est_tok_ms:.2f} ms) "
                f"exceeds the plan's own SLO ({sv.slo_ms:.2f} ms): the "
                "search emitted a best-effort point, not an SLO-meeting "
                "one"))

    # --- PLN011: sequence parallelism (sp_degree) -------------------------
    spd = plan.sp_degree
    if spd > 1:
        if n_dev % (P * spd):
            out.append(error(
                "PLN011", f"{loc}.sp_degree",
                f"sp_degree={spd} x pp_degree={P} = {P * spd} does not "
                f"divide n_devices={n_dev}: the seq mesh axis cannot be "
                "factored out of the stage groups (launch/mesh.py)",
                "sp_degree must divide n_devices / pp_degree"))
        if plan.seq_len > 0 and plan.seq_len % spd:
            out.append(error(
                "PLN011", f"{loc}.seq_len",
                f"seq_len={plan.seq_len} is not divisible by "
                f"sp_degree={spd}: sequence shards would be ragged and "
                "the ring hand-off panels unequal "
                "(kernels/ring_attention.py)",
                "pick sp_degree dividing the sequence length"))
        elif plan.seq_len == 0:
            out.append(warning(
                "PLN011", f"{loc}.seq_len",
                f"sp_degree={spd} but the plan does not record seq_len: "
                "the seq_len % sp_degree divisibility cannot be checked "
                "statically",
                "re-emit with the current search CLI to stamp seq_len"))
    if plan.strategies:
        layer_sp = sorted({s.sp for s in plan.strategies})
        if layer_sp[-1] > spd:
            out.append(error(
                "PLN011", f"{loc}.sp_degree",
                f"per-layer strategies reach sp={layer_sp[-1]} but the "
                f"plan stamps sp_degree={spd}: the launcher would build a "
                "seq mesh axis too small for those layers",
                "sp_degree must be max(layer sp degrees)"))
        elif spd > 1 and len(layer_sp) > 1:
            out.append(warning(
                "PLN011", f"{loc}.strategies",
                f"layers mix sp degrees {layer_sp}; boundaries between "
                "differently-sharded sequences reshard tokens "
                "(all-to-all) beside the priced hand-offs",
                "prefer one sp degree across a stage"))

    # --- PLN012: expert parallelism (ep_degree) ---------------------------
    epd = plan.ep_degree
    if epd > 1:
        if n_dev % (P * spd * epd):
            out.append(error(
                "PLN012", f"{loc}.ep_degree",
                f"ep_degree={epd} x sp_degree={spd} x pp_degree={P} = "
                f"{P * spd * epd} does not divide n_devices={n_dev}: the "
                "expert mesh axis cannot be factored out of the stage "
                "groups (launch/mesh.py)",
                "pp_degree * sp_degree * ep_degree must divide n_devices"))
    if plan.strategies:
        layer_ep = sorted({s.ep for s in plan.strategies})
        if layer_ep[-1] > epd:
            out.append(error(
                "PLN012", f"{loc}.ep_degree",
                f"per-layer strategies reach ep={layer_ep[-1]} but the "
                f"plan stamps ep_degree={epd}: the launcher would build an "
                "expert mesh axis too small for those layers",
                "ep_degree must be max(layer ep degrees)"))
        elif epd > 1 and layer_ep == [1]:
            out.append(warning(
                "PLN012", f"{loc}.ep_degree",
                f"ep_degree={epd} but no layer strategy carries an ep "
                "level: the search only emits ep on MoE-bearing stacks "
                "(the cost model poisons ep > 1 on non-MoE layers and "
                "when n_experts % ep != 0), so the stamp claims an "
                "expert axis nothing uses",
                "re-emit the plan, or drop the ep_degree stamp"))
        elif epd > 1 and len(layer_ep) > 1:
            out.append(info(
                "PLN012", f"{loc}.strategies",
                f"layers mix ep degrees {layer_ep} — the expected shape "
                "for dense+MoE stacks (only MoE layers can shard the "
                "expert axis; the cost model poisons ep > 1 elsewhere)"))

    # --- PLN008: estimator self-consistency -------------------------------
    if plan.est_stage_mem is not None and len(plan.est_stage_mem) != P:
        out.append(warning(
            "PLN008", f"{loc}.est_stage_mem",
            f"est_stage_mem has {len(plan.est_stage_mem)} entries for "
            f"pp_degree={P}"))
    if plan.est_iter_time > 0 and plan.est_throughput > 0:
        implied = plan.global_batch / plan.est_iter_time
        if abs(implied - plan.est_throughput) > 0.05 * plan.est_throughput:
            out.append(warning(
                "PLN008", f"{loc}.est_throughput",
                f"est_throughput={plan.est_throughput:.3f} but "
                f"global_batch/est_iter_time={implied:.3f} "
                "(>5% apart): the estimates were not produced together"))
    if not any(d.severity == "error" for d in out):
        out.append(info(
            "PLN000", loc,
            f"plan certifies: {plan.summary()}"))
    return out


def verify_plan_json(d: Dict, *, strict: bool = False,
                     location: str = "plan") -> List[Diagnostic]:
    """Structural + version + semantic rules on a raw plan dict."""
    out: List[Diagnostic] = []
    if not _check_structure(d, location, out):
        return out
    _check_version(d, location, strict, out)
    if any(x.severity == "error" for x in out):
        return out                # a version error makes loading unsafe
    try:
        plan = ParallelPlan.from_json(d)
    except (ValueError, TypeError) as e:
        out.append(error(
            "PLN009", location,
            f"plan does not construct: {e}",
            "fix the named field"))
        return out
    out.extend(verify_plan(plan, location=location))
    return out


def certify_plan_json(d: Dict, *, strict: bool = False,
                      location: str = "plan") -> DiagnosticReport:
    return DiagnosticReport().extend(
        verify_plan_json(d, strict=strict, location=location))


# ---------------------------------------------------------------------------
# structured loading path (train CLI, tests)
# ---------------------------------------------------------------------------

def load_plan_json(d: Dict, *, strict: bool = False, location: str = "plan"
                   ) -> Tuple[ParallelPlan, DiagnosticReport]:
    """Verify then load a raw plan dict.  Raises
    :class:`~repro.analysis.diagnostics.DiagnosticError` (with the
    offending field in each diagnostic's location) instead of leaking a
    bare ``KeyError`` from ``ParallelPlan.from_json``."""
    report = certify_plan_json(d, strict=strict, location=location)
    report.raise_if_errors(context=location)
    return ParallelPlan.from_json(d), report


def load_plan_file(path: str, *, strict: bool = False
                   ) -> Tuple[ParallelPlan, DiagnosticReport]:
    """Read, verify and load a plan JSON file (the ``--plan`` path of the
    train CLI and the lint CLI)."""
    with open(path) as f:
        try:
            d = json.load(f)
        except json.JSONDecodeError as e:
            report = DiagnosticReport().extend([error(
                "PLN009", f"{path}:{e.lineno}",
                f"not valid JSON: {e.msg}")])
            report.raise_if_errors(context=path)
    report = certify_plan_json(d, strict=strict, location=path)
    report.raise_if_errors(context=path)
    return ParallelPlan.from_json(d), report
