"""Codebase linter: AST checks for jax pitfalls (rule ids ``JAX001``–
``JAX004``, catalog in ``docs/analysis.md``).

These are the failure modes this codebase has either hit or is one edit
away from hitting:

  * **JAX001** — Python side effects inside a ``lax.scan`` body.  The body
    traces once; a ``print`` fires at trace time (not per step), and a
    ``global``/``nonlocal`` write or a closure-list ``.append`` records
    tracers that leak out of the trace.
  * **JAX002** — concrete truth-value checks on traced parameters inside a
    jitted function or scan body.  ``if x:`` on a tracer raises
    ``TracerBoolConversionError`` at trace time — unless the parameter is
    declared static (``static_argnames``/``static_argnums``), which the
    linter respects.
  * **JAX003** — unhashable static arguments: a parameter named in
    ``static_argnames`` whose default is a mutable literal (list/dict/set)
    fails at call time with an unhashable-type error.
  * **JAX004** — ``jax``/``jnp`` imports in ``repro/core/``.  The search
    hot loops are pure NumPy by design (array dispatch overhead dominates
    at the DP's per-cell granularity); ``core/profiler.py`` is the one
    sanctioned exception (it *is* the jax-facing measurement shim).

The pass is purely syntactic — no imports of the linted code — so it runs
on any tree, including broken ones.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set

from .diagnostics import Diagnostic, error, warning

#: files under repro/core/ allowed to import jax (the measurement shim)
CORE_JAX_EXCEPTIONS = ("profiler.py",)

_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.DictComp,
                     ast.ListComp, ast.SetComp)


def _is_scan_call(call: ast.Call) -> bool:
    """Matches ``lax.scan(...)`` / ``jax.lax.scan(...)`` / ``scan(...)``
    (imported name)."""
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr == "scan":
        base = f.value
        if isinstance(base, ast.Name) and base.id == "lax":
            return True
        if (isinstance(base, ast.Attribute) and base.attr == "lax"
                and isinstance(base.value, ast.Name)
                and base.value.id == "jax"):
            return True
    return False


def _is_jit_expr(node: ast.AST) -> bool:
    """Matches ``jax.jit`` / ``jit`` used as a decorator or wrapper."""
    if isinstance(node, ast.Name) and node.id == "jit":
        return True
    return (isinstance(node, ast.Attribute) and node.attr == "jit"
            and isinstance(node.value, ast.Name) and node.value.id == "jax")


def _static_names_from_call(call: ast.Call,
                            func_args: Optional[ast.arguments]) -> Set[str]:
    """Parameter names declared static in a ``jit``/``partial(jit, ...)``
    call's keywords (``static_argnames`` strings, ``static_argnums``
    resolved positionally when the signature is known)."""
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) and isinstance(
                        node.value, str):
                    out.add(node.value)
        elif kw.arg == "static_argnums" and func_args is not None:
            pos = [a.arg for a in func_args.posonlyargs + func_args.args]
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) and isinstance(
                        node.value, int) and 0 <= node.value < len(pos):
                    out.add(pos[node.value])
    return out


class _FileLint(ast.NodeVisitor):
    """One file's worth of JAX001–JAX003 findings."""

    def __init__(self, path: str, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.out: List[Diagnostic] = []
        # name -> def node, for resolving scan-body references
        self.defs: Dict[str, ast.AST] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs.setdefault(node.name, node)

    def loc(self, node: ast.AST) -> str:
        return f"{self.path}:{getattr(node, 'lineno', 0)}"

    def run(self) -> List[Diagnostic]:
        self.visit(self.tree)
        return self.out

    # --- traced-context discovery ---------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        if _is_scan_call(node) and node.args:
            body = node.args[0]
            fn: Optional[ast.AST] = None
            if isinstance(body, ast.Lambda):
                fn = body
            elif isinstance(body, ast.Name):
                fn = self.defs.get(body.id)
            if fn is not None:
                self._check_scan_body(fn)
                self._check_traced_bools(fn, static=set(),
                                         context="lax.scan body")
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        static: Optional[Set[str]] = None
        for dec in node.decorator_list:
            if _is_jit_expr(dec):
                static = set()
            elif isinstance(dec, ast.Call):
                if _is_jit_expr(dec.func):
                    static = _static_names_from_call(dec, node.args)
                elif (isinstance(dec.func, ast.Attribute)
                      and dec.func.attr == "partial"
                      or isinstance(dec.func, ast.Name)
                      and dec.func.id == "partial") and dec.args \
                        and _is_jit_expr(dec.args[0]):
                    static = _static_names_from_call(dec, node.args)
        if static is not None:
            self._check_traced_bools(node, static=static,
                                     context=f"jitted '{node.name}'")
            self._check_static_defaults(node, static)
        self.generic_visit(node)

    # --- JAX001: side effects in scan bodies ----------------------------

    def _check_scan_body(self, fn: ast.AST) -> None:
        local_targets: Set[str] = set()
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = fn.args
            local_targets |= {x.arg for x in
                             a.posonlyargs + a.args + a.kwonlyargs}
            body = fn.body
        elif isinstance(fn, ast.Lambda):
            a = fn.args
            local_targets |= {x.arg for x in
                             a.posonlyargs + a.args + a.kwonlyargs}
            body = [ast.Expr(fn.body)]
        else:  # pragma: no cover - callers pass defs/lambdas only
            return
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, (ast.Global, ast.Nonlocal)):
                    self.out.append(error(
                        "JAX001", self.loc(node),
                        f"{'global' if isinstance(node, ast.Global) else 'nonlocal'} "
                        "write inside a lax.scan body: the assignment "
                        "happens once at trace time and leaks a tracer",
                        "thread state through the scan carry instead"))
                elif isinstance(node, ast.Assign):
                    for t in node.targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                local_targets.add(n.id)
                elif isinstance(node, ast.Call):
                    f = node.func
                    if isinstance(f, ast.Name) and f.id == "print":
                        self.out.append(error(
                            "JAX001", self.loc(node),
                            "print() inside a lax.scan body fires once at "
                            "trace time, not per step",
                            "use jax.debug.print for runtime values"))
                    elif (isinstance(f, ast.Attribute)
                          and f.attr in ("append", "extend", "add",
                                         "update", "setdefault")
                          and isinstance(f.value, ast.Name)
                          and f.value.id not in local_targets):
                        self.out.append(warning(
                            "JAX001", self.loc(node),
                            f"'{f.value.id}.{f.attr}(...)' mutates a "
                            "closed-over object from a lax.scan body: it "
                            "runs once at trace time and records tracers",
                            "accumulate through the scan carry / ys "
                            "output instead"))

    # --- JAX002: concrete bool checks on traced params ------------------

    def _check_traced_bools(self, fn: ast.AST, static: Set[str],
                            context: str) -> None:
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = fn.args
            body = fn.body
        elif isinstance(fn, ast.Lambda):
            a = fn.args
            body = [ast.Expr(fn.body)]
        else:  # pragma: no cover
            return
        params = {x.arg for x in a.posonlyargs + a.args + a.kwonlyargs}
        params -= static
        params.discard("self")
        # a param reassigned in the body is no longer (just) the tracer
        reassigned: Set[str] = set()
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                reassigned.add(n.id)
        params -= reassigned
        for stmt in body:
            for node in ast.walk(stmt):
                test = None
                if isinstance(node, (ast.If, ast.While)):
                    test = node.test
                elif isinstance(node, ast.IfExp):
                    test = node.test
                if (isinstance(test, ast.Name) and test.id in params):
                    self.out.append(warning(
                        "JAX002", self.loc(test),
                        f"concrete truth-value check on parameter "
                        f"'{test.id}' inside {context}: if it is traced "
                        "this raises TracerBoolConversionError at trace "
                        "time",
                        "declare it in static_argnames, or use "
                        "jnp.where/lax.cond for value-dependent "
                        "branches"))

    # --- JAX003: unhashable static args ---------------------------------

    def _check_static_defaults(self, fn: ast.FunctionDef,
                               static: Set[str]) -> None:
        a = fn.args
        pos = a.posonlyargs + a.args
        for arg, default in zip(pos[len(pos) - len(a.defaults):],
                                a.defaults):
            if arg.arg in static and isinstance(default, _MUTABLE_LITERALS):
                self.out.append(error(
                    "JAX003", self.loc(default),
                    f"static argument '{arg.arg}' of '{fn.name}' defaults "
                    "to a mutable literal: jit hashes static args, so the "
                    "first call raises unhashable-type",
                    "use a tuple/frozenset/None sentinel"))
        for arg, default in zip(a.kwonlyargs, a.kw_defaults):
            if (default is not None and arg.arg in static
                    and isinstance(default, _MUTABLE_LITERALS)):
                self.out.append(error(
                    "JAX003", self.loc(default),
                    f"static argument '{arg.arg}' of '{fn.name}' defaults "
                    "to a mutable literal: jit hashes static args, so the "
                    "first call raises unhashable-type",
                    "use a tuple/frozenset/None sentinel"))


def _check_core_purity(path: str, rel: str, tree: ast.Module
                       ) -> List[Diagnostic]:
    """JAX004: repro/core/ stays NumPy-only (module-level imports)."""
    out: List[Diagnostic] = []
    norm = rel.replace(os.sep, "/")
    if "core/" not in norm or os.path.basename(rel) in CORE_JAX_EXCEPTIONS:
        return out
    for node in ast.walk(tree):
        names: List[str] = []
        if isinstance(node, ast.Import):
            names = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            names = [node.module]
        for name in names:
            root = name.split(".")[0]
            if root == "jax":
                out.append(error(
                    "JAX004", f"{path}:{node.lineno}",
                    f"'{name}' imported in repro/core/: the search hot "
                    "loops are pure NumPy by design (per-DP-cell jnp "
                    "dispatch overhead dominates)",
                    "keep jax behind runtime/ or core/profiler.py"))
    return out


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def lint_source(code: str, path: str, *, rel: Optional[str] = None
                ) -> List[Diagnostic]:
    """Lint one file's source text.  ``rel`` is the repo-relative path used
    for the JAX004 location test (defaults to ``path``)."""
    try:
        tree = ast.parse(code, filename=path)
    except SyntaxError as e:
        return [error("JAX000", f"{path}:{e.lineno or 0}",
                      f"file does not parse: {e.msg}")]
    out = _FileLint(path, tree).run()
    out.extend(_check_core_purity(path, rel if rel is not None else path,
                                  tree))
    out.sort(key=lambda d: d.location)
    return out


def lint_paths(paths: Sequence[str]) -> List[Diagnostic]:
    """Lint every ``.py`` file under the given files/directories."""
    out: List[Diagnostic] = []
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".py"))
        else:
            files.append(p)
    for f in sorted(files):
        with open(f, encoding="utf-8") as fh:
            out.extend(lint_source(fh.read(), f))
    return out
