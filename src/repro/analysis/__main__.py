"""``python -m repro.analysis`` — the static-verifier CLI.

The implementation lives in :mod:`repro.launch.lint` next to the other
entry points (search/train); this shim only forwards."""
import sys

from repro.launch.lint import main

if __name__ == "__main__":
    sys.exit(main())
