"""Static analysis: certify plans and schedules before anything executes.

Three passes over the artifacts the search exchanges with the runtime,
sharing one structured-diagnostic model (rule ids catalogued in
``docs/analysis.md``):

  * :mod:`repro.analysis.schedule_lint` — happens-before certification of
    compiled ``ScheduleProgram`` tick tables (SCH rules): deadlock /
    use-before-def / double-consume detection, certified peak live-buffer
    counts pinned against the cost model, bubble re-derivation.
  * :mod:`repro.analysis.plan_lint` — static checks on ``ParallelPlan``
    JSON, all format versions (PLN rules).
  * :mod:`repro.analysis.jax_lint` — AST linter for jax pitfalls in the
    source tree (JAX rules).

CLI: ``python -m repro.analysis`` (see ``launch/lint.py``).  The search
CLI runs the plan + schedule passes on every plan before serializing it;
``compile_schedule(..., validate=True)`` routes through the schedule
pass.
"""
from .diagnostics import (ERROR, INFO, WARNING, Diagnostic, DiagnosticError,
                          DiagnosticReport, error, info, warning)
from .jax_lint import lint_paths, lint_source
from .plan_lint import (certify_plan_json, detect_format_version,
                        load_plan_file, load_plan_json, verify_plan,
                        verify_plan_json)
from .schedule_lint import (DEFAULT_GRID, StageCertificate, certify_live_buffers,
                            certify_program, schedule_grid, schedule_legal,
                            verify_program)

__all__ = [
    "Diagnostic", "DiagnosticReport", "DiagnosticError",
    "ERROR", "WARNING", "INFO", "error", "warning", "info",
    "verify_program", "certify_program", "certify_live_buffers",
    "StageCertificate", "schedule_legal", "schedule_grid", "DEFAULT_GRID",
    "verify_plan", "verify_plan_json", "certify_plan_json",
    "load_plan_json", "load_plan_file", "detect_format_version",
    "lint_source", "lint_paths",
]
