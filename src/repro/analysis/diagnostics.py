"""Structured diagnostics shared by every static-analysis pass.

A :class:`Diagnostic` is one finding: a stable rule id (``SCH001`` /
``PLN004`` / ``JAX002`` — catalogued in ``docs/analysis.md``), a severity,
a human-locatable position, a one-line message and an optional fix hint.
Passes return plain lists of diagnostics; :class:`DiagnosticReport`
aggregates them for the CLI (``launch/lint.py``) and for callers that want
to *raise* on errors (:class:`DiagnosticError`), e.g.
``compile_schedule(..., validate=True)``.

Severities:

  * ``error``   — the artifact is wrong: the schedule would deadlock /
    read stale buffers, the plan cannot execute, or the cost model and
    the compiled program disagree (drift).  Non-zero CLI exit.
  * ``warning`` — suspicious but executable (deprecated plan version,
    probable jax pitfall).  ``--strict`` escalates selected warnings.
  * ``info``    — certification telemetry (what was proven, with numbers).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional

ERROR = "error"
WARNING = "warning"
INFO = "info"
_SEVERITIES = (ERROR, WARNING, INFO)


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One static-analysis finding."""

    rule: str          # stable rule id, e.g. "SCH001"
    severity: str      # "error" | "warning" | "info"
    location: str      # where: "zb-h1[P=4,m=8] stage 2", "plan.schedule",
                       # or "src/repro/foo.py:42"
    message: str       # what is wrong (one line)
    hint: str = ""     # how to fix it (optional, one line)

    def __post_init__(self):
        if self.severity not in _SEVERITIES:
            raise ValueError(f"severity must be one of {_SEVERITIES}, "
                             f"got {self.severity!r}")

    def format(self) -> str:
        s = f"{self.severity}[{self.rule}] {self.location}: {self.message}"
        if self.hint:
            s += f"  (hint: {self.hint})"
        return s

    def to_json(self) -> Dict[str, str]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, str]) -> "Diagnostic":
        return Diagnostic(rule=d["rule"], severity=d["severity"],
                          location=d["location"], message=d["message"],
                          hint=d.get("hint", ""))


@dataclasses.dataclass
class DiagnosticReport:
    """An ordered collection of diagnostics with summary helpers."""

    diagnostics: List[Diagnostic] = dataclasses.field(default_factory=list)

    def extend(self, diags: Iterable[Diagnostic]) -> "DiagnosticReport":
        self.diagnostics.extend(diags)
        return self

    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    def rules(self) -> List[str]:
        """Distinct rule ids present, sorted (mutation tests key on this)."""
        return sorted({d.rule for d in self.diagnostics})

    @property
    def ok(self) -> bool:
        return not self.errors()

    def format(self, *, min_severity: str = INFO) -> str:
        keep = _SEVERITIES[: _SEVERITIES.index(min_severity) + 1]
        lines = [d.format() for d in self.diagnostics if d.severity in keep]
        lines.append(f"{len(self.errors())} error(s), "
                     f"{len(self.warnings())} warning(s), "
                     f"{len(self.diagnostics)} diagnostic(s) total")
        return "\n".join(lines)

    def to_json(self) -> Dict:
        return {
            "errors": len(self.errors()),
            "warnings": len(self.warnings()),
            "diagnostics": [d.to_json() for d in self.diagnostics],
        }

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=2)

    def raise_if_errors(self, context: str = "") -> None:
        if not self.ok:
            raise DiagnosticError(self.errors(), context=context)


class DiagnosticError(ValueError):
    """Raised by validate/strict paths when error-severity findings exist.

    Carries the structured diagnostics so callers (and tests) can inspect
    rule ids instead of parsing the message.
    """

    def __init__(self, diagnostics: List[Diagnostic], context: str = ""):
        self.diagnostics = list(diagnostics)
        head = f"{context}: " if context else ""
        lines = "\n".join(d.format() for d in self.diagnostics)
        super().__init__(f"{head}{len(self.diagnostics)} error "
                         f"diagnostic(s)\n{lines}")

    def rules(self) -> List[str]:
        return sorted({d.rule for d in self.diagnostics})


def error(rule: str, location: str, message: str, hint: str = "") -> Diagnostic:
    return Diagnostic(rule, ERROR, location, message, hint)


def warning(rule: str, location: str, message: str, hint: str = "") -> Diagnostic:
    return Diagnostic(rule, WARNING, location, message, hint)


def info(rule: str, location: str, message: str, hint: str = "") -> Diagnostic:
    return Diagnostic(rule, INFO, location, message, hint)
