"""Continuous-batching serving engine over the paged KV cache.

Host-side orchestration around two jit-compiled device functions (built by
``runtime/executor.py``):

  * a **chunked prefill** step — processes one fixed-shape prompt chunk
    ``(prefill_batch, prefill_chunk)`` for newly admitted requests, writing
    their K/V into the shared page pools (``base`` is a traced scalar, so
    every chunk of every batch reuses a single compilation), and
  * a **decode** step — advances all active lanes one token against the
    page pools.

Prefill is disaggregated from decode: queued requests are admitted in
batches, prefilled chunk-by-chunk between decode rounds, and dropped into
free decode lanes — the decode batch never waits for a prompt to be fed
token-by-token.  Slots are recycled as requests finish (EOS / max_new) and
their pages return to the pool, so total KV memory is bounded by pages
actually cached, not ``lanes * max_context``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.transformer import supports_paged_decode
from repro.runtime.executor import (make_paged_decode_step,
                                    make_paged_prefill_step)
from repro.runtime.sharding import ShardPolicy

from .metrics import RequestMetrics, ServeMetrics
from .page_table import PageManager, PageState


@dataclasses.dataclass
class ServeRequest:
    """One generation request with scheduling metadata."""

    rid: str
    prompt: List[int]
    max_new: int
    arrival_s: float = 0.0          # offset from engine start
    deadline_ms: float = 0.0        # per-token latency SLO (0 = none)
    tokens: List[int] = dataclasses.field(default_factory=list)  # generated
    done: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static geometry of one engine instance."""

    page_size: int = 16
    n_pages: int = 256              # shared pool rows per layer
    decode_slots: int = 8           # continuous-batching lanes
    max_context: int = 256          # per-lane ceiling (pages_per_slot * psz)
    prefill_batch: int = 4          # prompts prefetched per prefill round
    prefill_chunk: int = 32         # tokens per prefill jit call
    eos_id: Optional[int] = None

    def __post_init__(self):
        if self.max_context % self.page_size:
            raise ValueError(
                f"max_context={self.max_context} must be a multiple of "
                f"page_size={self.page_size}")

    @property
    def pages_per_slot(self) -> int:
        return self.max_context // self.page_size


class ServingEngine:
    """Greedy continuous-batching server for dense / MoE decoder LMs."""

    def __init__(self, cfg: ModelConfig, params, mesh, ecfg: EngineConfig,
                 policy: Optional[ShardPolicy] = None):
        if not supports_paged_decode(cfg):
            raise NotImplementedError(
                f"paged serving does not support arch_type={cfg.arch_type!r}")
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        policy = policy or ShardPolicy(tp=False, zero=False)
        self.pm = PageManager(n_pages=ecfg.n_pages,
                              n_slots=ecfg.decode_slots,
                              page_size=ecfg.page_size,
                              pages_per_slot=ecfg.pages_per_slot)
        self._decode = make_paged_decode_step(
            cfg, mesh, policy, ecfg.decode_slots, ecfg.n_pages,
            ecfg.page_size, self.pm.pages_per_slot).fn
        self._prefill = make_paged_prefill_step(
            cfg, mesh, policy, ecfg.prefill_batch, ecfg.prefill_chunk,
            ecfg.n_pages, ecfg.page_size, self.pm.pages_per_slot).fn
        from repro.models.transformer import init_paged_state
        self.pools = init_paged_state(cfg, ecfg.n_pages, ecfg.page_size)
        self.state: PageState = self.pm.init()
        self.metrics = ServeMetrics()
        # host-side per-slot bookkeeping
        self._slot_req: List[Optional[ServeRequest]] = \
            [None] * ecfg.decode_slots
        self._slot_rm: List[Optional[RequestMetrics]] = \
            [None] * ecfg.decode_slots

    # ---- admission + prefill --------------------------------------------
    def _free_slots(self) -> List[int]:
        active = np.asarray(self.state.active)
        return [i for i in range(self.ecfg.decode_slots) if not active[i]]

    def _admit_batch(self, queue: Deque[ServeRequest], now: float
                     ) -> List[int]:
        """Claim slots + prompt pages for up to ``prefill_batch`` queued
        requests (arrival order); returns the admitted slot ids."""
        admitted: List[int] = []
        free = self._free_slots()
        while (queue and free and len(admitted) < self.ecfg.prefill_batch):
            req = queue[0]
            if req.arrival_s > now:        # sorted by arrival: rest is later
                break
            if len(req.prompt) > self.ecfg.max_context:
                raise ValueError(
                    f"request {req.rid!r}: prompt length {len(req.prompt)} "
                    f"exceeds max_context={self.ecfg.max_context}")
            slot = free[0]
            st, ok = self.pm.admit(self.state, slot, len(req.prompt))
            if not bool(ok):
                break                      # pool full — retry next round
            self.state = st
            queue.popleft()
            free.pop(0)
            self._slot_req[slot] = req
            self._slot_rm[slot] = RequestMetrics(
                rid=req.rid, arrival_s=now,
                prompt_tokens=len(req.prompt),
                deadline_ms=req.deadline_ms)
            admitted.append(slot)
        return admitted

    def _prefill_admitted(self, slots: List[int], t0: float) -> None:
        """Chunked prefill for the admitted slots; records TTFT and seeds
        each lane's first generated token."""
        ecfg, pm = self.ecfg, self.pm
        PB, S = ecfg.prefill_batch, ecfg.prefill_chunk
        reqs = [self._slot_req[s] for s in slots]
        plens = [len(r.prompt) for r in reqs]
        max_len = max(plens)
        # host-padded prompt block (PB, ceil(max_len / S) * S)
        n_chunks = -(-max_len // S)
        block = np.zeros((PB, n_chunks * S), np.int32)
        for i, r in enumerate(reqs):
            block[i, :len(r.prompt)] = r.prompt
        rows = np.full((PB, pm.pages_per_slot), -1, np.int32)
        rows[:len(slots)] = np.asarray(self.state.page_rows)[slots]
        prompt_len = np.zeros((PB,), np.int32)
        prompt_len[:len(slots)] = plens
        rows_j = jnp.asarray(rows)
        plen_j = jnp.asarray(prompt_len)
        for c in range(n_chunks):
            base = c * S
            logits, self.pools = self._prefill(
                self.params, self.pools, jnp.asarray(block[:, base:base + S]),
                rows_j, jnp.int32(base), plen_j)
            self.metrics.prefill_chunks += 1
            first = np.asarray(jnp.argmax(logits, axis=-1))
            tnow = time.perf_counter() - t0
            for i, (slot, r) in enumerate(zip(slots, reqs)):
                if base <= plens[i] - 1 < base + S:    # prompt ends here
                    r.tokens.append(int(first[i]))
                    rm = self._slot_rm[slot]
                    rm.first_token_s = tnow
                    rm.new_tokens = 1
        # lanes now hold their full prompt
        self.state = self.state._replace(
            lengths=self.state.lengths.at[jnp.asarray(slots)].set(
                jnp.asarray(plens, jnp.int32)))
        for slot, r in zip(slots, reqs):
            if r.max_new <= 1 or (self.ecfg.eos_id is not None
                                  and r.tokens[-1] == self.ecfg.eos_id):
                self._finish(slot, time.perf_counter() - t0)

    # ---- decode ----------------------------------------------------------
    def _finish(self, slot: int, tnow: float) -> None:
        req, rm = self._slot_req[slot], self._slot_rm[slot]
        req.done = True
        rm.new_tokens = len(req.tokens)
        rm.finish_s = tnow
        self.metrics.requests.append(rm)
        self._slot_req[slot] = None
        self._slot_rm[slot] = None
        self.state = self.pm.free_slot(self.state, slot)

    def _decode_round(self, t0: float) -> None:
        """Advance every steppable lane one token."""
        want = self.state.active
        st, ok = self.pm.ensure_append_capacity(self.state, want)
        self.state = st
        ok_np = np.asarray(ok)
        if not ok_np.any():
            if np.asarray(self.state.active).any():
                raise RuntimeError(
                    "page pool exhausted: no active lane can append (grow "
                    "n_pages or lower decode_slots)")
            return
        token = np.zeros((self.ecfg.decode_slots,), np.int32)
        for i, r in enumerate(self._slot_req):
            if r is not None and ok_np[i]:
                token[i] = r.tokens[-1]
        lengths = jnp.where(ok, self.state.lengths, -1)
        logits, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(token),
            self.state.page_rows, lengths)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.state = self.pm.advance(self.state, ok)
        self.metrics.decode_steps += 1
        tnow = time.perf_counter() - t0
        for i in range(self.ecfg.decode_slots):
            if not ok_np[i]:
                continue
            req = self._slot_req[i]
            req.tokens.append(int(nxt[i]))
            finished = (len(req.tokens) >= req.max_new
                        or (self.ecfg.eos_id is not None
                            and int(nxt[i]) == self.ecfg.eos_id))
            if finished:
                self._finish(i, tnow)

    # ---- top level -------------------------------------------------------
    def run(self, requests: List[ServeRequest],
            verbose: bool = False) -> ServeMetrics:
        """Serve ``requests`` to completion; returns the metrics record.

        Requests are admitted in arrival order as lanes and pages free up;
        ``arrival_s`` is honored against the engine's wall clock (a request
        "arriving later" than the current elapsed time stays queued)."""
        t0 = time.perf_counter()
        queue: Deque[ServeRequest] = deque(
            sorted(requests, key=lambda r: r.arrival_s))
        while queue or np.asarray(self.state.active).any():
            now = time.perf_counter() - t0
            slots = self._admit_batch(queue, now)
            if slots:
                self._prefill_admitted(slots, t0)
            self.metrics.queue_depth.append(len(queue))
            self.metrics.page_occupancy.append(
                float(self.pm.occupancy(self.state)))
            if np.asarray(self.state.active).any():
                self._decode_round(t0)
            elif queue:
                if queue[0].arrival_s <= now and not slots:
                    raise RuntimeError(
                        f"request {queue[0].rid!r} cannot be admitted into "
                        f"an idle engine: prompt needs "
                        f"{-(-len(queue[0].prompt) // self.ecfg.page_size)} "
                        f"pages but the pool has {self.ecfg.n_pages} total "
                        "(grow n_pages)")
                # everything queued is in the future; idle until it lands
                time.sleep(max(0.0, min(0.001, queue[0].arrival_s - now)))
            if verbose:
                done = sum(1 for r in requests if r.done)
                print(f"[engine] done={done}/{len(requests)} "
                      f"queue={len(queue)} "
                      f"occ={float(self.pm.occupancy(self.state)):.2f}")
        self.metrics.wall_s = time.perf_counter() - t0
        return self.metrics
