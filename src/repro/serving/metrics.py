"""Host-side serving telemetry: per-request latency accounting plus
engine-level queue/occupancy samples, aggregated into a JSON-able summary
(the schema ``benchmarks/bench_serve.py`` writes to ``BENCH_serve.json``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


def _pct(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1) + 0.5))
    return sorted_vals[i]


@dataclasses.dataclass
class RequestMetrics:
    """Latency record of one served request (wall-clock seconds)."""

    rid: str
    arrival_s: float
    prompt_tokens: int = 0
    new_tokens: int = 0
    first_token_s: Optional[float] = None    # absolute time of first token
    finish_s: Optional[float] = None
    deadline_ms: float = 0.0                 # 0 = no per-token SLO attached

    @property
    def ttft_ms(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return (self.first_token_s - self.arrival_s) * 1e3

    @property
    def tok_ms(self) -> Optional[float]:
        """Mean per-token decode latency after the first token."""
        if (self.finish_s is None or self.first_token_s is None
                or self.new_tokens <= 1):
            return None
        return ((self.finish_s - self.first_token_s)
                / (self.new_tokens - 1)) * 1e3


@dataclasses.dataclass
class ServeMetrics:
    """Aggregated over one engine run."""

    requests: List[RequestMetrics] = dataclasses.field(default_factory=list)
    queue_depth: List[int] = dataclasses.field(default_factory=list)
    page_occupancy: List[float] = dataclasses.field(default_factory=list)
    decode_steps: int = 0
    prefill_chunks: int = 0
    wall_s: float = 0.0

    def summary(self) -> Dict:
        done = [r for r in self.requests if r.finish_s is not None]
        ttfts = sorted(r.ttft_ms for r in done if r.ttft_ms is not None)
        toks = sorted(r.tok_ms for r in done if r.tok_ms is not None)
        total_new = sum(r.new_tokens for r in done)
        return {
            "requests": len(self.requests),
            "completed": len(done),
            "new_tokens": total_new,
            "decode_steps": self.decode_steps,
            "prefill_chunks": self.prefill_chunks,
            "wall_s": self.wall_s,
            "tok_per_s": (total_new / self.wall_s if self.wall_s else 0.0),
            "ttft_ms_p50": _pct(ttfts, 0.5),
            "ttft_ms_p99": _pct(ttfts, 0.99),
            "tok_ms_p50": _pct(toks, 0.5),
            "tok_ms_p99": _pct(toks, 0.99),
            "queue_depth_max": max(self.queue_depth, default=0),
            "page_occupancy_mean": (sum(self.page_occupancy)
                                    / len(self.page_occupancy)
                                    if self.page_occupancy else 0.0),
            "page_occupancy_max": max(self.page_occupancy, default=0.0),
        }
