"""Paged KV-cache bookkeeping: a functional page table over dense int32
arrays, usable eagerly from the host engine or traced under ``jax.jit``.

The design follows the vLLM / maxtext ``page_manager`` idiom: one shared
pool of fixed-size pages per layer holds every lane's K/V, and a *single*
page table (shared by all layers — each layer indexes its own pool with the
same rows) maps (slot, logical page) -> pool row.  All state lives in
:class:`PageState`, a pytree of dense arrays updated functionally; the
static geometry lives in :class:`PageManager`.  There is no Python-object
free list: allocation is rank-matching with ``cumsum`` over boolean masks,
and every scatter routes invalid positions out of bounds where
``mode="drop"`` discards them — the same trick the paged attention kernels
use for inactive lanes.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class PageState(NamedTuple):
    """Dense-array page table (a jax pytree).

    ``page_owner`` (n_pages,) — slot owning each pool row, -1 = free.
    ``page_rows`` (n_slots, pages_per_slot) — pool row backing each lane's
    logical page, -1 = unassigned.
    ``lengths`` (n_slots,) — tokens currently cached per lane (= the write
    position of the next token).
    ``active`` (n_slots,) bool — lane holds a live request.
    """

    page_owner: jax.Array
    page_rows: jax.Array
    lengths: jax.Array
    active: jax.Array


@dataclasses.dataclass(frozen=True)
class PageManager:
    """Static geometry + pure page-table operations.

    ``n_pages`` pool rows of ``page_size`` tokens are shared by ``n_slots``
    decode lanes, each addressing at most ``pages_per_slot`` logical pages
    (so per-lane max context = pages_per_slot * page_size).  Methods take
    and return :class:`PageState`; none mutate.
    """

    n_pages: int
    n_slots: int
    page_size: int
    pages_per_slot: int

    def __post_init__(self):
        if min(self.n_pages, self.n_slots, self.page_size,
               self.pages_per_slot) < 1:
            raise ValueError("all PageManager dimensions must be >= 1")

    @property
    def max_context(self) -> int:
        return self.pages_per_slot * self.page_size

    def init(self) -> PageState:
        return PageState(
            page_owner=jnp.full((self.n_pages,), -1, jnp.int32),
            page_rows=jnp.full((self.n_slots, self.pages_per_slot), -1,
                               jnp.int32),
            lengths=jnp.zeros((self.n_slots,), jnp.int32),
            active=jnp.zeros((self.n_slots,), bool),
        )

    # ---- queries ---------------------------------------------------------
    def pages_needed(self, n_tokens) -> jax.Array:
        """Pages required to hold ``n_tokens`` (ceil division)."""
        n = jnp.asarray(n_tokens, jnp.int32)
        return (n + self.page_size - 1) // self.page_size

    def free_pages(self, st: PageState) -> jax.Array:
        return jnp.sum(st.page_owner < 0).astype(jnp.int32)

    def used_pages(self, st: PageState) -> jax.Array:
        return jnp.sum(st.page_owner >= 0).astype(jnp.int32)

    def occupancy(self, st: PageState) -> jax.Array:
        return self.used_pages(st) / self.n_pages

    # ---- allocation ------------------------------------------------------
    def reserve(self, st: PageState, slot, n_need
                ) -> Tuple[PageState, jax.Array]:
        """Assign the first ``n_need`` free pool rows to ``slot``'s next
        unassigned logical pages.  Returns ``(new_state, ok)``; on failure
        (not enough free rows, or the slot would exceed pages_per_slot)
        the state is returned unchanged and ``ok`` is False."""
        slot = jnp.asarray(slot, jnp.int32)
        n_need = jnp.asarray(n_need, jnp.int32)
        free = st.page_owner < 0                             # (n_pages,)
        rank = jnp.cumsum(free) - 1                          # rank among free
        chosen = free & (rank < n_need)
        cur = jnp.sum(st.page_rows[slot] >= 0).astype(jnp.int32)
        ok = ((jnp.sum(free) >= n_need)
              & (cur + n_need <= self.pages_per_slot))
        # logical index each chosen row lands in; non-chosen rows route OOB
        logical = jnp.where(chosen & ok, cur + rank, self.pages_per_slot)
        new_rows = st.page_rows.at[slot, logical].set(
            jnp.arange(self.n_pages, dtype=jnp.int32), mode="drop")
        new_owner = jnp.where(chosen & ok, slot, st.page_owner)
        return PageState(new_owner, new_rows, st.lengths, st.active), ok

    def admit(self, st: PageState, slot, prompt_len
              ) -> Tuple[PageState, jax.Array]:
        """Claim ``slot`` for a new request and reserve pages covering its
        ``prompt_len`` prompt tokens.  The lane starts at length 0 (prefill
        fills it); decode-time pages come from :meth:`ensure_append_capacity`.
        """
        slot = jnp.asarray(slot, jnp.int32)
        st2, ok = self.reserve(st, slot, self.pages_needed(prompt_len))
        new_active = st2.active.at[slot].set(ok)
        new_lengths = st2.lengths.at[slot].set(0)
        st3 = PageState(st2.page_owner, st2.page_rows, new_lengths,
                        new_active)
        return jax.tree.map(lambda a, b: jnp.where(ok, a, b), st3, st), ok

    def free_slot(self, st: PageState, slot) -> PageState:
        """Release every page owned by ``slot`` and deactivate the lane."""
        slot = jnp.asarray(slot, jnp.int32)
        new_owner = jnp.where(st.page_owner == slot, -1, st.page_owner)
        new_rows = st.page_rows.at[slot].set(-1)
        return PageState(new_owner, new_rows,
                         st.lengths.at[slot].set(0),
                         st.active.at[slot].set(False))

    def ensure_append_capacity(self, st: PageState, want: jax.Array
                               ) -> Tuple[PageState, jax.Array]:
        """Guarantee each lane in ``want`` (n_slots, bool) has a page
        assigned for its next write position ``lengths[i]``.

        Vectorized multi-lane allocation: lanes missing a page are ranked
        by ``cumsum``, free pool rows are ranked the same way, and rank r
        matches rank r.  Returns ``(new_state, ok)`` with ``ok`` (n_slots,)
        False for lanes that could not get a page this round (pool
        exhausted or lane at pages_per_slot) — the engine skips those lanes
        for one step and retries after other requests release pages."""
        want = want & st.active
        li = st.lengths // self.page_size                    # logical page
        li_c = jnp.clip(li, 0, self.pages_per_slot - 1)
        have = jnp.take_along_axis(st.page_rows, li_c[:, None],
                                   axis=1)[:, 0] >= 0
        fits = li < self.pages_per_slot
        need = want & fits & ~have
        lane_rank = jnp.cumsum(need) - 1                     # (n_slots,)
        free = st.page_owner < 0
        free_rank = jnp.where(free, jnp.cumsum(free) - 1, self.n_slots)
        # page_of_rank[r] = r-th free pool row (sentinel n_pages if none)
        page_of_rank = jnp.full((self.n_slots,), self.n_pages,
                                jnp.int32).at[free_rank].set(
            jnp.arange(self.n_pages, dtype=jnp.int32), mode="drop")
        got = page_of_rank[jnp.clip(lane_rank, 0, self.n_slots - 1)]
        granted = need & (got < self.n_pages)
        slot_ids = jnp.arange(self.n_slots, dtype=jnp.int32)
        new_rows = st.page_rows.at[
            jnp.where(granted, slot_ids, self.n_slots), li_c].set(
            got, mode="drop")
        new_owner = st.page_owner.at[
            jnp.where(granted, got, self.n_pages)].set(
            slot_ids, mode="drop")
        ok = want & fits & (have | granted)
        return PageState(new_owner, new_rows, st.lengths, st.active), ok

    def advance(self, st: PageState, stepped: jax.Array) -> PageState:
        """Bump ``lengths`` for lanes that wrote a token this step."""
        return st._replace(
            lengths=st.lengths + stepped.astype(jnp.int32))
