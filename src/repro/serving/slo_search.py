"""SLO-aware serving plan search.

Reuses the Galvatron-BMW budget-axis frontier engine for inference: decode
is **bandwidth-bound** — each step must stream the (active) weights plus
every lane's cached KV pages through HBM — so a per-token latency SLO is
exactly a per-step *byte budget*::

    budget_bytes = slo_s * hbm_bandwidth * efficiency

That budget doubles as the memory budget ``sweep_budgets()`` already
sweeps: a plan whose per-device working set exceeds it cannot stream that
much per step, hence cannot meet the SLO.  The optimizer runs with an
*inference* cost configuration (weights only — no gradients or optimizer
states, ``bytes_per_param_states = bytes_per_param``), and each frontier
point is then refined into a :class:`repro.core.plan.ServingSection` by the
analytic serving cost model below: the largest decode batch meeting the
SLO, a page size minimizing fragmentation, prefill degrees chosen
compute-bound, and predicted TTFT / per-token latency / throughput.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.cost_model import CostModelConfig
from repro.core.frontier import PlanFrontier
from repro.core.hardware import ClusterSpec
from repro.core.layerspec import LayerSpec
from repro.core.optimizer import GalvatronOptimizer, OptimizerConfig
from repro.core.plan import ParallelPlan, ServingSection

#: fraction of peak HBM bandwidth a decode step actually achieves
DECODE_BW_EFFICIENCY = 0.6
#: KV/weight bytes per element at serving time (bf16)
SERVE_ACT_BYTES = 2.0
#: candidate page sizes (tokens per page)
PAGE_SIZE_CANDIDATES = (8, 16, 32, 64)
#: candidate decode batch sizes
DECODE_BATCH_CANDIDATES = (1, 2, 4, 8, 16, 32, 64, 128, 256)


@dataclasses.dataclass(frozen=True)
class ServingModelStats:
    """Per-token workload of a model at serving time (one device's view is
    obtained by dividing by the TP degree)."""

    param_bytes: float            # total weight bytes (active params)
    kv_bytes_per_token: float     # K+V bytes per cached token, all layers
    flops_per_token: float        # decode FLOPs per generated token

    @staticmethod
    def from_layer_specs(specs: Sequence[LayerSpec]) -> "ServingModelStats":
        active = sum(s.active_param_count() for s in specs)
        kv = 0.0
        for s in specs:
            if s.kind in ("attn_mlp", "moe") and s.seq_len:
                # bnd bytes/sample = seq * d * act_bytes; KV per token is
                # 2 * kv_dim * act_bytes — recover d from the boundary
                # activation and apply the GQA ratio heuristically (1/4)
                d_bytes = s.bnd_bytes_per_sample / s.seq_len
                kv += 2 * d_bytes / 4
        return ServingModelStats(
            param_bytes=active * SERVE_ACT_BYTES,
            kv_bytes_per_token=kv,
            flops_per_token=2.0 * active)

    @staticmethod
    def from_model_config(cfg) -> "ServingModelStats":
        """Exact analytic stats from a ``repro.models.ModelConfig``."""
        d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
        kv_dim = cfg.kv_dim
        p_attn = d * cfg.q_dim + 2 * d * kv_dim + cfg.q_dim * d
        if cfg.n_experts > 1:
            p_ff_active = 3 * d * cfg.d_ff * cfg.top_k
        else:
            p_ff_active = 3 * d * cfg.d_ff
        p_embed = V * d * (1 if cfg.tie_embeddings else 2)
        active = p_embed + L * (p_attn + p_ff_active + 2 * d)
        return ServingModelStats(
            param_bytes=active * SERVE_ACT_BYTES,
            kv_bytes_per_token=L * 2 * kv_dim * SERVE_ACT_BYTES,
            flops_per_token=2.0 * active)


@dataclasses.dataclass(frozen=True)
class ServingCostModel:
    """Analytic decode/prefill latency (§V-style roofline, per device)."""

    cluster: ClusterSpec
    stats: ServingModelStats
    bw_efficiency: float = DECODE_BW_EFFICIENCY

    def _bw(self) -> float:
        return self.cluster.device.hbm_bandwidth * self.bw_efficiency

    def decode_step_s(self, batch: int, mean_context: float,
                      tp: int, pp: int) -> float:
        """One decode step: max of the bandwidth and compute rooflines.
        PP splits the weights but serializes micro-steps, so per-token
        latency sees the full pipeline depth (no batch pipelining gain for
        a single decode step)."""
        shard = max(1, tp) * max(1, pp)
        traffic = (self.stats.param_bytes / shard
                   + batch * mean_context * self.stats.kv_bytes_per_token
                   / max(1, tp))
        t_bw = traffic / self._bw()
        mfu = 0.45
        t_fl = (batch * self.stats.flops_per_token
                / (shard * self.cluster.device.peak_flops * mfu))
        # cross-stage hop latency for PP
        t_hop = 0.0
        if pp > 1:
            lat, _ = self.cluster.collective_coeffs("ppermute", pp)
            t_hop = lat * (pp - 1)
        return max(t_bw, t_fl) + t_hop

    def prefill_s(self, prompt_tokens: int, tp: int, pp: int) -> float:
        """Prefill is compute-bound (batched matmuls over the prompt)."""
        mfu = 0.45
        shard = max(1, tp) * max(1, pp)
        return (prompt_tokens * self.stats.flops_per_token
                / (shard * self.cluster.device.peak_flops * mfu))

    def kv_pool_bytes(self, n_pages: int, page_size: int, tp: int) -> float:
        return (n_pages * page_size * self.stats.kv_bytes_per_token
                / max(1, tp))

    def slo_budget_bytes(self, slo_ms: float) -> float:
        """Per-token SLO -> per-step streamable bytes -> memory budget."""
        return (slo_ms / 1e3) * self._bw()


@dataclasses.dataclass
class SloPoint:
    """One point of the serving frontier."""

    slo_ms: float
    budget_bytes: float
    plan: Optional[ParallelPlan]          # carries the ServingSection

    @property
    def feasible(self) -> bool:
        return self.plan is not None and self.plan.serving is not None


class ServingPlanSearch:
    """Wraps :class:`GalvatronOptimizer` with the serving cost model.

    ``specs``/``cluster`` describe the model and hardware exactly as for
    the training search; the optimizer itself runs with inference memory
    accounting (weights only)."""

    def __init__(self, specs: Sequence[LayerSpec], cluster: ClusterSpec,
                 config: Optional[OptimizerConfig] = None,
                 stats: Optional[ServingModelStats] = None):
        self.specs = list(specs)
        self.cluster = cluster
        self.stats = stats or ServingModelStats.from_layer_specs(specs)
        self.cost = ServingCostModel(cluster, self.stats)
        inference_cost = CostModelConfig(
            bytes_per_param_states=SERVE_ACT_BYTES,   # no grads / optimizer
            bytes_per_param=SERVE_ACT_BYTES)
        self.opt = GalvatronOptimizer(specs, cluster, config,
                                      cost_config=inference_cost)

    # ---- per-point refinement -------------------------------------------
    def _derive_serving(self, plan: ParallelPlan, slo_ms: float, *,
                        max_context: int, mean_context: float,
                        ttft_slo_ms: float) -> ServingSection:
        tp = max((s.tp for s in plan.strategies), default=1)
        pp = plan.pp_degree
        # decode batch: largest candidate meeting the SLO roofline and the
        # per-device HBM capacity (weights + KV pool for that batch)
        hbm = self.cluster.device.hbm_bytes
        best_b = 1
        for b in DECODE_BATCH_CANDIDATES:
            t = self.cost.decode_step_s(b, mean_context, tp, pp) * 1e3
            kv = (b * max_context * self.stats.kv_bytes_per_token
                  / max(1, tp))
            w = self.stats.param_bytes / (max(1, tp) * max(1, pp))
            if t <= slo_ms and kv + w <= hbm:
                best_b = b
        # page size: minimize fragmentation (half a page per request) plus
        # table overhead (one int32 row entry per page per lane)
        def waste(psz: int) -> float:
            frag = psz / 2 * self.stats.kv_bytes_per_token
            table = (max_context / psz) * 4.0
            return frag * best_b + table * best_b
        page_size = min((p for p in PAGE_SIZE_CANDIDATES
                         if max_context % p == 0),
                        key=waste, default=max(
                            p for p in PAGE_SIZE_CANDIDATES
                            if p <= max_context))
        # pool sized for the full decode batch at mean context + headroom
        tokens = best_b * (mean_context + page_size)
        kv_pool_pages = max(best_b,
                            int(-(-tokens // page_size)))
        tok_s = self.cost.decode_step_s(best_b, mean_context, tp, pp)
        ttft_s = (self.cost.prefill_s(int(mean_context), tp, pp)
                  + tok_s)
        prefill_chunk = max(page_size, min(512, max_context))
        return ServingSection(
            slo_ms=slo_ms,
            ttft_slo_ms=ttft_slo_ms,
            page_size=page_size,
            max_context=max_context,
            decode_batch=best_b,
            prefill_chunk=prefill_chunk,
            decode_tp=tp, decode_pp=pp,
            # prefill is compute-bound: prefer TP over PP at equal device
            # count (no pipeline fill latency on the critical TTFT path)
            prefill_tp=tp * pp, prefill_pp=1,
            kv_pool_pages=kv_pool_pages,
            est_tok_ms=tok_s * 1e3,
            est_ttft_ms=ttft_s * 1e3,
            est_tok_per_s=best_b / tok_s if tok_s > 0 else 0.0,
        )

    # ---- top level -------------------------------------------------------
    def sweep_slos(self, slo_ms_list: Sequence[float], *,
                   max_context: int = 2048,
                   mean_context: Optional[float] = None,
                   ttft_slo_ms: float = 0.0,
                   backend: Optional[str] = None,
                   verbose: bool = False
                   ) -> Tuple[List[SloPoint], PlanFrontier]:
        """Walk the latency-SLO axis through ``sweep_budgets()``.

        Returns one :class:`SloPoint` per requested SLO (same order) plus
        the underlying byte-budget :class:`PlanFrontier`.  Infeasible SLOs
        (no plan can stream its working set fast enough) get
        ``plan=None``."""
        mean_ctx = float(mean_context if mean_context is not None
                         else max_context / 2)
        budgets = [self.cost.slo_budget_bytes(s) for s in slo_ms_list]
        frontier = self.opt.sweep_budgets(budgets, backend=backend,
                                          verbose=verbose)
        points: List[SloPoint] = []
        for slo_ms, budget in zip(slo_ms_list, budgets):
            plan = frontier.plan_at(budget)
            if plan is not None:
                serving = self._derive_serving(
                    plan, slo_ms, max_context=max_context,
                    mean_context=mean_ctx, ttft_slo_ms=ttft_slo_ms)
                plan = dataclasses.replace(plan, serving=serving)
            points.append(SloPoint(slo_ms=slo_ms, budget_bytes=budget,
                                   plan=plan))
        return points, frontier
