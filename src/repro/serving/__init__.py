"""Serving engine: paged KV cache, continuous batching with
prefill/decode disaggregation, and the SLO-aware serving plan search
(docs/serving.md)."""
from .engine import EngineConfig, ServeRequest, ServingEngine
from .metrics import RequestMetrics, ServeMetrics
from .page_table import PageManager, PageState
from .slo_search import (DECODE_BW_EFFICIENCY, PAGE_SIZE_CANDIDATES,
                         ServingCostModel, ServingModelStats,
                         ServingPlanSearch, SloPoint)

__all__ = ["DECODE_BW_EFFICIENCY", "EngineConfig", "PAGE_SIZE_CANDIDATES",
           "PageManager", "PageState", "RequestMetrics", "ServeMetrics",
           "ServeRequest", "ServingCostModel", "ServingEngine",
           "ServingModelStats", "ServingPlanSearch", "SloPoint"]
