"""repro: Galvatron-BMW — automatic hybrid-parallel training, in JAX.

Layers:
  repro.core      search engine (decision tree + DP + BMW balance + estimator)
  repro.models    pure-JAX model zoo (dense/MoE/SSM/hybrid/enc-dec/VLM)
  repro.runtime   plan -> pjit/shard_map execution
  repro.kernels   Pallas TPU kernels (flash attention, SSD scan, rmsnorm)
  repro.configs   assigned architectures + paper models
  repro.launch    production meshes, dry-run, train/serve drivers
"""
__version__ = "1.0.0"
