from .pipeline import (DataConfig, synthetic_lm_batches, text_corpus_batches,
                       batch_specs)

__all__ = ["DataConfig", "synthetic_lm_batches", "text_corpus_batches",
           "batch_specs"]
