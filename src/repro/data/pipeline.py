"""Deterministic data pipeline.

Two sources, both host-side numpy generators that yield globally-consistent
batches (every host computes the same stream; the executor's in_shardings
scatter them to the right devices):

  * ``synthetic_lm_batches`` — seeded Zipf-like token stream for
    benchmarking and smoke tests,
  * ``text_corpus_batches`` — byte-level tokenization of a local text file
    (self-contained; no external tokenizer), packed into fixed-length
    sequences for the end-to-end example run.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 1234
    vision_tokens: int = 0
    d_vision: int = 0
    encoder_seq: int = 0
    d_model: int = 0            # for audio frame stubs
    pad_id: int = 0


def _lm_batch(rng: np.random.Generator, cfg: DataConfig) -> Dict[str, np.ndarray]:
    # Zipf-ish marginal so losses behave like text, fully deterministic.
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(cfg.vocab_size, size=(cfg.global_batch, cfg.seq_len + 1),
                      p=probs).astype(np.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.vision_tokens:
        batch["patches"] = rng.standard_normal(
            (cfg.global_batch, cfg.vision_tokens, cfg.d_vision)).astype(np.float32)
    if cfg.encoder_seq:
        batch["frames"] = rng.standard_normal(
            (cfg.global_batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    return batch


def synthetic_lm_batches(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(cfg.seed)
    while True:
        yield _lm_batch(rng, cfg)


def text_corpus_batches(path: str | pathlib.Path,
                        cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Byte-level LM over a local text file, packed and epoch-shuffled."""
    data = np.frombuffer(pathlib.Path(path).read_bytes(), dtype=np.uint8)
    data = data.astype(np.int32) % cfg.vocab_size
    n_tok = cfg.seq_len + 1
    n_seqs = len(data) // n_tok
    assert n_seqs > 0, "corpus smaller than one sequence"
    packed = data[: n_seqs * n_tok].reshape(n_seqs, n_tok)
    rng = np.random.default_rng(cfg.seed)
    while True:
        order = rng.permutation(n_seqs)
        for i in range(0, n_seqs - cfg.global_batch + 1, cfg.global_batch):
            rows = packed[order[i:i + cfg.global_batch]]
            yield {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def batch_specs(cfg: DataConfig):
    """jax.ShapeDtypeStruct stand-ins matching the generator output."""
    import jax
    import jax.numpy as jnp
    out = {
        "tokens": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len), jnp.int32),
    }
    if cfg.vision_tokens:
        out["patches"] = jax.ShapeDtypeStruct(
            (cfg.global_batch, cfg.vision_tokens, cfg.d_vision), jnp.float32)
    if cfg.encoder_seq:
        out["frames"] = jax.ShapeDtypeStruct(
            (cfg.global_batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return out
