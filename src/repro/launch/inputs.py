"""``input_specs()`` — ShapeDtypeStruct stand-ins for every model input of
every (architecture x input shape), weak-type-correct and shardable, with
zero device allocation."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import INPUT_SHAPES, InputShape, ModelConfig

# sliding-window span used to make `long_500k` sub-quadratic on attention
# architectures (dense/moe/vlm/audio); SSM/hybrid run it natively.
LONG_CONTEXT_WINDOW = 8192


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-shape model adjustments (DESIGN.md §4)."""
    if (shape.name == "long_500k" and cfg.arch_type not in ("ssm", "hybrid")
            and cfg.sliding_window is None):
        return cfg.with_(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Batch inputs for train/prefill modes."""
    B, S = shape.global_batch, shape.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.arch_type == "vlm":
        out["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_vision), jnp.float32)
    if cfg.is_encoder_decoder:
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if shape.mode != "train":
        out.pop("labels")
    return out


def decode_dims(cfg: ModelConfig, shape: InputShape) -> Tuple[int, int]:
    """(batch, kv-context) for decode shapes."""
    return shape.global_batch, shape.seq_len
