"""Training driver: Galvatron-searched plan -> sharded training run.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \\
        --steps 100 --batch 8 --seq 128

On this CPU container the driver runs reduced configs on the local device
mesh; on a real pod the same entry point takes the production mesh and the
full config (the dry-run proves those lower).
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.configs.specs import layerspecs_for
from repro.core import (GalvatronOptimizer, ParallelPlan, galvatron_variant,
                        tpu_v5e_pod)
from repro.data import DataConfig, batch_specs, synthetic_lm_batches, text_corpus_batches
from repro.checkpointing import save_train_state
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamWConfig
from repro.runtime import ShardPolicy, init_train_state, make_train_step


def search_plan(cfg, seq_len: int, n_devices: int = 64) -> ParallelPlan:
    specs = layerspecs_for(cfg, seq_len)
    ocfg = galvatron_variant("bmw")
    ocfg.batch_grid = [64, 128, 256]
    ocfg.n_bins = 96
    ocfg.micro_candidates = 2
    ocfg.max_pp = 4
    # the schedule is a searched dimension (DESIGN.md §5, docs/schedules.md):
    # plain 1F1B vs interleaved virtual stages (bubble for hand-off traffic)
    # vs zero-bubble ZB-H1 (bubble for deferred weight-grad memory)
    ocfg.schedules = ("1f1b", "1f1b-interleaved", "zb-h1")
    ocfg.vpp_candidates = (2,)
    plan = GalvatronOptimizer(specs, tpu_v5e_pod(n_devices), ocfg).optimize()
    if plan is None:
        raise RuntimeError("no feasible plan")
    return plan


def run_pipeline(cfg, plan: ParallelPlan, args, gen) -> None:
    """Execute the plan's searched pipeline schedule via the shard_map
    runtime, scaled down to whatever pipe degree the local devices and the
    (possibly reduced) layer count support."""
    from repro.launch.mesh import make_pipeline_mesh
    from repro.models import init_lm
    from repro.optim import adamw_init, adamw_update
    from repro.runtime import make_pipeline_loss, stage_split_params

    n_dev = len(jax.devices())
    P = 1
    for cand in range(min(n_dev, plan.pp_degree, cfg.n_layers), 0, -1):
        if n_dev % cand == 0 and cfg.n_layers % cand == 0:
            P = cand
            break
    sched, V = plan.schedule, plan.vpp_degree
    while V > 1 and cfg.n_layers % (P * V):
        V -= 1
    if V == 1 and sched == "1f1b-interleaved":
        sched = "1f1b"          # interleaving degenerated away locally
    m = math.gcd(plan.n_micro, args.batch)
    # the data axis shards the per-micro batch; shrink it (idling spare
    # devices) rather than hand shard_map a non-divisible batch dim
    n_data = math.gcd(n_dev // P, args.batch // m)
    mesh = make_pipeline_mesh(P, n_data)
    print(f"pipeline runtime: schedule={sched} P={P} V={V} m={m} "
          f"(plan asked {plan.schedule} P={plan.pp_degree} "
          f"V={plan.vpp_degree} m={plan.n_micro})")
    ocfg = AdamWConfig(lr=args.lr)
    with mesh:
        loss_fn = make_pipeline_loss(cfg, mesh, m, schedule=sched,
                                     n_chunks=V)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        ps = stage_split_params(params, P, V)
        opt = adamw_init(ps, ocfg)

        @jax.jit
        def step(ps, opt, batch):
            loss, grads = loss_fn(ps, batch)
            ps, opt, metrics = adamw_update(ps, grads, opt, ocfg)
            metrics["loss"] = loss
            return ps, opt, metrics

        t0 = time.time()
        tokens_seen = 0
        for i in range(1, args.steps + 1):
            b = next(gen)
            batch = {k: jnp.asarray(v).reshape(m, args.batch // m, args.seq)
                     for k, v in b.items()}
            ps, opt, metrics = step(ps, opt, batch)
            tokens_seen += args.batch * args.seq
            if i % args.log_every == 0 or i == args.steps:
                dt = time.time() - t0
                print(f"step {i:5d}  loss={float(metrics['loss']):.4f}  "
                      f"tok/s={tokens_seen/dt:,.0f}")
    print("done.")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--corpus", default=None, help="text file (byte-level LM)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--plan", default=None, metavar="FILE",
                    help="load a searched plan JSON (verified by "
                         "repro.analysis on load) instead of re-searching")
    ap.add_argument("--strict", action="store_true",
                    help="reject deprecated v0/v1 --plan files with a "
                         "structured deprecation diagnostic (PLN001)")
    ap.add_argument("--plan-out", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--pipeline", action="store_true",
                    help="execute the searched pipeline schedule via the "
                         "shard_map runtime (pipe mesh over local devices) "
                         "instead of the GSPMD executor path")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.layers or 2,
                          d_model=args.d_model or 256)
    elif args.layers or args.d_model:
        cfg = cfg.with_(n_layers=args.layers or cfg.n_layers,
                        d_model=args.d_model or cfg.d_model)

    # 1) the plan: loaded from a verified file, or searched fresh by the
    #    paper's engine (for the target pod), including the
    #    pipeline-schedule dimension
    if args.plan:
        from repro.analysis import load_plan_file
        plan, report = load_plan_file(args.plan, strict=args.strict)
        for d in report.warnings():
            print(d.format())
        print(f"loaded plan {args.plan} (verified: "
              f"{len(report.warnings())} warning(s))")
    else:
        plan = search_plan(cfg, args.seq)
    print("plan:", plan.summary())
    print(f"schedule: {plan.schedule} vpp={plan.vpp_degree} "
          f"m={plan.n_micro}")
    if args.plan_out:
        pathlib.Path(args.plan_out).write_text(plan.dumps())

    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size,
                      vision_tokens=cfg.vision_tokens,
                      d_vision=cfg.d_vision,
                      encoder_seq=cfg.encoder_seq, d_model=cfg.d_model)
    gen = (text_corpus_batches(args.corpus, dcfg) if args.corpus
           else synthetic_lm_batches(dcfg))

    # 2a) pipeline mode: execute the searched schedule itself
    if args.pipeline:
        run_pipeline(cfg, plan, args, gen)
        return

    # 2b) map the plan onto the local mesh (GSPMD executor path)
    policy = ShardPolicy.from_strategy(
        plan.strategies[len(plan.strategies) // 2],
        remat_segments=[s.ckpt for s in plan.strategies[:1]])
    mesh = make_local_mesh()

    with mesh:
        step = make_train_step(cfg, mesh, policy, batch_specs(dcfg),
                               AdamWConfig(lr=args.lr))
        params, opt = init_train_state(cfg, mesh, policy)
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
        print(f"model: {args.arch} ({n_params/1e6:.1f}M params), "
              f"mesh={dict(mesh.shape)}, policy={policy}")
        t0 = time.time()
        tokens_seen = 0
        for i in range(1, args.steps + 1):
            batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
            params, opt, metrics = step.fn(params, opt, batch)
            tokens_seen += args.batch * args.seq
            if i % args.log_every == 0 or i == args.steps:
                dt = time.time() - t0
                print(f"step {i:5d}  loss={float(metrics['loss']):.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.3f}  "
                      f"tok/s={tokens_seen/dt:,.0f}")
            if args.ckpt_dir and i % args.ckpt_every == 0:
                d = save_train_state(i, params, opt, args.ckpt_dir)
                print(f"  checkpoint -> {d}")
    print("done.")


if __name__ == "__main__":
    main()
