"""Training driver: Galvatron-searched plan -> sharded training run.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \\
        --steps 100 --batch 8 --seq 128

On this CPU container the driver runs reduced configs on the local device
mesh; on a real pod the same entry point takes the production mesh and the
full config (the dry-run proves those lower).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.configs.specs import layerspecs_for
from repro.core import (GalvatronOptimizer, ParallelPlan, galvatron_variant,
                        tpu_v5e_pod)
from repro.data import DataConfig, batch_specs, synthetic_lm_batches, text_corpus_batches
from repro.checkpointing import save_train_state
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamWConfig
from repro.runtime import ShardPolicy, init_train_state, make_train_step


def search_plan(cfg, seq_len: int, n_devices: int = 64) -> ParallelPlan:
    specs = layerspecs_for(cfg, seq_len)
    ocfg = galvatron_variant("bmw")
    ocfg.batch_grid = [64, 128, 256]
    ocfg.n_bins = 96
    ocfg.micro_candidates = 2
    ocfg.max_pp = 4
    plan = GalvatronOptimizer(specs, tpu_v5e_pod(n_devices), ocfg).optimize()
    if plan is None:
        raise RuntimeError("no feasible plan")
    return plan


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--corpus", default=None, help="text file (byte-level LM)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--plan-out", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.layers or 2,
                          d_model=args.d_model or 256)
    elif args.layers or args.d_model:
        cfg = cfg.with_(n_layers=args.layers or cfg.n_layers,
                        d_model=args.d_model or cfg.d_model)

    # 1) the paper's engine searches the plan (for the target pod)
    plan = search_plan(cfg, args.seq)
    print("searched plan:", plan.summary())
    if args.plan_out:
        pathlib.Path(args.plan_out).write_text(plan.dumps())

    # 2) map the plan onto the local mesh
    policy = ShardPolicy.from_strategy(
        plan.strategies[len(plan.strategies) // 2],
        remat_segments=[s.ckpt for s in plan.strategies[:1]])
    mesh = make_local_mesh()

    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size,
                      vision_tokens=cfg.vision_tokens,
                      d_vision=cfg.d_vision,
                      encoder_seq=cfg.encoder_seq, d_model=cfg.d_model)
    gen = (text_corpus_batches(args.corpus, dcfg) if args.corpus
           else synthetic_lm_batches(dcfg))

    with mesh:
        step = make_train_step(cfg, mesh, policy, batch_specs(dcfg),
                               AdamWConfig(lr=args.lr))
        params, opt = init_train_state(cfg, mesh, policy)
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
        print(f"model: {args.arch} ({n_params/1e6:.1f}M params), "
              f"mesh={dict(mesh.shape)}, policy={policy}")
        t0 = time.time()
        tokens_seen = 0
        for i in range(1, args.steps + 1):
            batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
            params, opt, metrics = step.fn(params, opt, batch)
            tokens_seen += args.batch * args.seq
            if i % args.log_every == 0 or i == args.steps:
                dt = time.time() - t0
                print(f"step {i:5d}  loss={float(metrics['loss']):.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.3f}  "
                      f"tok/s={tokens_seen/dt:,.0f}")
            if args.ckpt_dir and i % args.ckpt_every == 0:
                d = save_train_state(i, params, opt, args.ckpt_dir)
                print(f"  checkpoint -> {d}")
    print("done.")


if __name__ == "__main__":
    main()
