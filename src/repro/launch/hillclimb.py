"""§Perf hillclimb driver: run a (arch, shape) pair with an optimization
variant and append the roofline row (tagged) to experiments/perf.jsonl.

    PYTHONPATH=src python -m repro.launch.hillclimb kimi-shmap
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import json
import pathlib
import sys

VARIANTS = {
    # pair 2: kimi-k2 x train_4k (most collective-bound)
    "kimi-shmap": dict(arch="kimi-k2-1t-a32b", shape="train_4k",
                       config_overrides={"moe_dispatch": "shmap"}),
    "kimi-shmap-seq": dict(arch="kimi-k2-1t-a32b", shape="train_4k",
                           config_overrides={"moe_dispatch": "shmap"},
                           policy_overrides={"seq_shard": True}),
    "kimi-shmap-cf1": dict(arch="kimi-k2-1t-a32b", shape="train_4k",
                           config_overrides={"moe_dispatch": "shmap",
                                             "capacity_factor": 1.0}),
    # pair 3: qwen2-72b x train_4k (flagship dense; memory + collective)
    "q72-seq": dict(arch="qwen2-72b", shape="train_4k",
                    policy_overrides={"seq_shard": True}),
    "q72-seq-nozero": dict(arch="qwen2-72b", shape="train_4k",
                           policy_overrides={"seq_shard": True,
                                             "zero": False}),
    # pair 1: arctic-480b x prefill_32k (worst useful fraction)
    "arctic-shmap": dict(arch="arctic-480b", shape="prefill_32k",
                         config_overrides={"moe_dispatch": "shmap"}),
    "arctic-shmap-cf1": dict(arch="arctic-480b", shape="prefill_32k",
                             config_overrides={"moe_dispatch": "shmap",
                                               "capacity_factor": 1.0}),
    # extra beyond-paper runs
    "q72-prefill-seq": dict(arch="qwen2-72b", shape="prefill_32k",
                            policy_overrides={"seq_shard": True}),
    "qwen3-4b-seq": dict(arch="qwen3-4b", shape="train_4k",
                         policy_overrides={"seq_shard": True}),
    # decode ablation: KV-cache context sharded over model axis (default)
    # vs KV-head sharding fallback
    "q72-decode-noseqcache": dict(arch="qwen2-72b", shape="decode_32k",
                                  policy_overrides={"shard_cache_seq": False}),
}


def main():
    from repro.launch.dryrun import run_one
    name = sys.argv[1]
    spec = VARIANTS[name]
    multi = "--multi-pod" in sys.argv
    row = run_one(spec["arch"], spec["shape"], multi_pod=multi,
                  policy_overrides=spec.get("policy_overrides"),
                  config_overrides=spec.get("config_overrides"),
                  variant=name)
    out = pathlib.Path("experiments/perf.jsonl")
    out.parent.mkdir(exist_ok=True)
    with out.open("a") as f:
        f.write(json.dumps(row) + "\n")
    print("written", name)


if __name__ == "__main__":
    main()
