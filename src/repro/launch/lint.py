"""Static-verifier CLI (``python -m repro.analysis``).

Runs any combination of the three analysis passes and exits non-zero when
error-severity diagnostics exist (docs/analysis.md has the rule catalog):

    # certify every legal schedule combo on the acceptance grid
    PYTHONPATH=src python -m repro.analysis --all-schedules

    # a custom grid: P=2,4 x m=1..8 x V=1,2
    PYTHONPATH=src python -m repro.analysis \\
        --all-schedules "P=2,4;m=1..8;V=1,2"

    # lint plan files (schedule table included) + the source tree,
    # writing the machine-readable report CI uploads as an artifact
    PYTHONPATH=src python -m repro.analysis --plan plan.json --src src \\
        --report lint-report.json

``--strict`` escalates deprecated-plan-version warnings (PLN001) to
errors.  Exit status: 0 clean, 1 error diagnostics, 2 usage error.
"""
from __future__ import annotations

import argparse
import re
import sys
from typing import List, Sequence, Tuple

from repro.analysis import (DEFAULT_GRID, DiagnosticReport, certify_plan_json,
                            lint_paths, schedule_grid, verify_program)

_AXIS = {"P": 0, "m": 1, "V": 2}


def parse_grid(spec: str) -> Tuple[Tuple[int, ...], ...]:
    """Parse ``"P=1,2,4,8;m=1..16;V=1,2"`` (any subset of axes; missing
    axes fall back to the acceptance grid)."""
    axes = list(DEFAULT_GRID)
    for part in filter(None, (p.strip() for p in spec.split(";"))):
        m = re.fullmatch(r"([PmV])=([0-9.,]+)", part)
        if not m:
            raise ValueError(
                f"bad grid component {part!r}; want e.g. P=1,2,4 or m=1..16")
        vals: List[int] = []
        for tok in m.group(2).split(","):
            if ".." in tok:
                lo, hi = tok.split("..", 1)
                vals.extend(range(int(lo), int(hi) + 1))
            elif tok:
                vals.append(int(tok))
        if not vals:
            raise ValueError(f"empty axis in grid component {part!r}")
        axes[_AXIS[m.group(1)]] = tuple(vals)
    return tuple(axes)


def _run_schedule_grid(spec: str, report: DiagnosticReport,
                       verbose: bool) -> int:
    from repro.runtime.schedules import compile_schedule

    stages, micros, chunks = parse_grid(spec) if spec else DEFAULT_GRID
    n = 0
    for name, P, m, V in schedule_grid(stages, micros, chunks):
        pr = compile_schedule(name, P, m, V if V > 1 else None)
        diags = verify_program(pr)
        report.extend(d for d in diags
                      if verbose or d.severity != "info")
        n += 1
    print(f"schedule grid: certified {n} legal (schedule, P, m, V) "
          f"combo(s) over P={list(stages)} m={list(micros)} "
          f"V={list(chunks)}")
    return n


def _run_plan(path: str, strict: bool, report: DiagnosticReport,
              verbose: bool) -> None:
    import json

    from repro.analysis.diagnostics import error
    from repro.runtime.schedules import compile_schedule

    try:
        with open(path) as f:
            d = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        report.extend([error("PLN009", path, f"cannot read plan: {e}")])
        return
    plan_report = certify_plan_json(d, strict=strict, location=path)
    report.extend(x for x in plan_report.diagnostics
                  if verbose or x.severity != "info")
    if plan_report.ok:
        # the plan parses and is legal: certify the schedule it prescribes
        prog = compile_schedule(d.get("schedule", "1f1b"), d["pp_degree"],
                                d["n_micro"], d.get("vpp_degree", 1))
        report.extend(x for x in verify_program(prog)
                      if verbose or x.severity != "info")
    print(f"plan {path}: {len(plan_report.errors())} error(s), "
          f"{len(plan_report.warnings())} warning(s)")


def main(argv: Sequence[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verifier: schedule happens-before "
                    "certification, plan lint, jax-pitfall lint "
                    "(rule catalog: docs/analysis.md).")
    ap.add_argument("--plan", action="append", default=[], metavar="FILE",
                    help="plan JSON file to verify (repeatable); the "
                         "schedule it prescribes is certified too")
    ap.add_argument("--all-schedules", nargs="?", const="", default=None,
                    metavar="GRID",
                    help="certify every legal schedule combo; optional "
                         "grid spec like 'P=1,2,4,8;m=1..16;V=1,2' "
                         "(default: that acceptance grid)")
    ap.add_argument("--src", action="append", default=[], metavar="DIR",
                    help="source file/tree to lint for jax pitfalls "
                         "(repeatable)")
    ap.add_argument("--strict", action="store_true",
                    help="escalate deprecated plan versions (v0/v1) to "
                         "errors")
    ap.add_argument("--report", metavar="FILE",
                    help="write the full diagnostic report as JSON")
    ap.add_argument("--verbose", action="store_true",
                    help="keep info-severity certification telemetry in "
                         "the output/report")
    args = ap.parse_args(argv)

    if not args.plan and args.all_schedules is None and not args.src:
        ap.error("nothing to do: pass --plan, --all-schedules and/or --src")

    report = DiagnosticReport()
    if args.all_schedules is not None:
        try:
            _run_schedule_grid(args.all_schedules, report, args.verbose)
        except ValueError as e:
            ap.error(str(e))
    for path in args.plan:
        _run_plan(path, args.strict, report, args.verbose)
    if args.src:
        diags = lint_paths(args.src)
        report.extend(diags)
        print(f"src lint: {len(diags)} finding(s) over "
              f"{', '.join(args.src)}")

    out = report.format(min_severity="info" if args.verbose else "warning")
    print(out)
    if args.report:
        with open(args.report, "w") as f:
            f.write(report.dumps() + "\n")
        print(f"wrote {args.report}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
