"""Production meshes.

Single pod: 256 TPU v5e chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the ``pod``
axis carries data parallelism (or pipeline stages — Takeaway #1 puts PP on
the slowest links, which is exactly the pod boundary).

These are FUNCTIONS so importing this module never touches jax device
state; callers (dryrun.py) must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    """Version-compat mesh constructor.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist on newer jax
    releases (>= 0.5); on older ones (e.g. 0.4.37) ``jax.make_mesh`` takes
    just (shape, axes), and very old releases lack ``make_mesh`` entirely.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    import numpy as np
    devices = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_pipeline_mesh(n_stages: int = 2, n_data: int = 4):
    """PP x DP mesh for the shard_map pipeline runtime (tests/examples)."""
    return _mk((n_stages, n_data), ("pipe", "data"))


def make_ring_mesh(n_seq: int = 0, n_data: int = 1):
    """DP x SP mesh for ring-attention sequence parallelism.

    The ``seq`` axis carries the searched ``plan.sp_degree``: K/V panels
    rotate around it (runtime/sequence.py) and batch token dims shard
    over it (runtime/sharding.py).  ``n_seq=0`` takes every device left
    after the ``data`` axis.
    """
    n = len(jax.devices())
    n_seq = n_seq or n // n_data
    return _mk((n_data, n_seq), ("data", "seq"))


def make_expert_mesh(n_ep: int = 0, n_data: int = 1):
    """DP x EP mesh for expert parallelism.

    The ``expert`` axis carries the searched ``plan.ep_degree`` (format
    v5): expert weights shard over it (runtime/sharding.py), the batch
    dim co-shards over data x expert, and MoE dispatch runs the
    all-to-all path (models/moe.py::_moe_ep).  ``n_ep=0`` takes every
    device left after the ``data`` axis.
    """
    n = len(jax.devices())
    n_ep = n_ep or n // n_data
    return _mk((n_data, n_ep), ("data", "expert"))


def make_local_mesh(model: int = 1):
    """Whatever this host offers (examples, smoke tests)."""
    n = len(jax.devices())
    model = min(model, n)
    return _mk((n // model, model), ("data", "model"))
