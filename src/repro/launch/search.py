"""Budget-sweep strategy search CLI (the frontier engine, DESIGN.md §6).

Computes the paper's throughput-vs-memory story in one invocation: either a
single plan (``--budget``) or the whole Pareto frontier over a budget axis
(``--budget-sweep``), searched in ~one pass instead of one full search per
budget.

    # 8-point frontier for the paper's BERT-Huge-32 on the 8-GPU cluster
    PYTHONPATH=src python -m repro.launch.search --model bert-huge-32 \\
        --cluster 8x-rtx-titan-pcie --budget-sweep 4,6,...,18 \\
        --out frontier.json

    # assigned architecture on a TPU pod, process-pool (B, P) fan-out
    PYTHONPATH=src python -m repro.launch.search --arch qwen3-4b --seq 2048 \\
        --cluster tpu-v5e-pod-256 --budget-sweep 8,10,12,16 \\
        --backend processes --jobs 8

``--budget-sweep`` takes GB values: an explicit comma list (``4,6,8``) or an
arithmetic ellipsis ``a,b,...,z`` expanded with step ``b - a`` (so
``8,16,...,80`` means 8, 16, 24, …, 80).  The frontier (budgets, plans,
predicted throughputs, knee points) is printed as a table and written as
JSON via ``PlanFrontier.dumps`` when ``--out`` is given; a single-budget
run writes the plan JSON instead.  ``--backend`` picks how the independent
(B, P) outer candidates execute (serial / threads / processes pools /
vectorized stacked-DP batching; ``--jobs`` sizes the pools) and
frontier-guided batch-axis pruning is on unless ``--no-prune`` — every
combination returns byte-identical plans with aggregated cache + pruning
telemetry in the summary line (docs/search.md).

The model comes from ``--arch`` (an assigned architecture id, searched at
``--seq``) or ``--model`` (a paper evaluation model, fixed geometry).  The
cluster comes from ``--cluster`` (a preset name from ``repro.core.CLUSTERS``)
with optional ``--devices`` override.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
from typing import List

from repro.core import (CLUSTERS, GalvatronOptimizer, galvatron_variant)
from repro.core.optimizer import SEARCH_BACKENDS, normalize_batch_grid

GB = 1024 ** 3


def certify_plans(plans, *, strict: bool = False, log=print) -> bool:
    """Run the static verifier on every plan the search is about to emit.

    Every plan is checked by the plan verifier (``repro.analysis``) and
    its prescribed schedule table by the happens-before certifier —
    the search can never serialize an uncertified plan.  Error-severity
    findings (and, under ``strict``, warnings too) veto serialization;
    diagnostics are printed either way.

    Returns True when every plan certifies.
    """
    from repro.analysis import verify_plan_json, verify_program
    from repro.runtime.schedules import compile_schedule

    ok = True
    for k, plan in enumerate(plans):
        loc = f"plan[{k}]" if len(plans) > 1 else "plan"
        diags = verify_plan_json(plan.to_json(), location=loc)
        if not any(d.severity == "error" for d in diags):
            diags += verify_program(compile_schedule(
                plan.schedule, plan.pp_degree, plan.n_micro,
                plan.vpp_degree))
        bad = [d for d in diags if d.severity == "error"
               or (strict and d.severity == "warning")]
        for d in bad:
            log(d.format())
        if bad:
            ok = False
    return ok


def parse_sweep_values(text: str) -> List[float]:
    """Comma list ``4,6,8`` or arithmetic ellipsis ``a,b,...,z`` (step
    ``b - a``), unit-free."""
    parts = [p.strip() for p in text.split(",") if p.strip()]
    if "..." in parts:
        i = parts.index("...")
        if i < 2 or i != len(parts) - 2:
            raise ValueError(
                f"ellipsis sweep must look like a,b,...,z  (got {text!r})")
        head = [float(p) for p in parts[:i]]
        stop = float(parts[i + 1])
        step = head[-1] - head[-2]
        if step <= 0:
            raise ValueError(f"non-increasing ellipsis step in {text!r}")
        vals = list(head)
        while vals[-1] + step <= stop + 1e-9:
            vals.append(vals[-1] + step)
        return vals
    return [float(p) for p in parts]


def parse_budget_sweep(text: str) -> List[float]:
    """GB values: ``4,6,8`` or arithmetic ellipsis ``a,b,...,z``."""
    return [v * GB for v in parse_sweep_values(text)]


def _specs_for(args):
    if args.model:
        from repro.configs.paper_models import paper_model_specs
        return paper_model_specs(args.model), args.model
    if args.arch:
        from repro.configs import get_config
        from repro.configs.specs import layerspecs_for
        cfg = get_config(args.arch)
        return layerspecs_for(cfg, args.seq), f"{args.arch}@seq{args.seq}"
    raise SystemExit("one of --arch / --model is required")


def _cluster_for(args):
    if args.cluster not in CLUSTERS:
        raise SystemExit(f"unknown cluster {args.cluster!r}; "
                         f"have {sorted(CLUSTERS)}")
    cluster = CLUSTERS[args.cluster]
    if args.devices:
        cluster = cluster.with_devices(args.devices)
    return cluster


def build_optimizer(specs, cluster, args) -> GalvatronOptimizer:
    """Construct the search engine from parsed CLI args.

    Args:
      specs: per-layer :class:`~repro.core.layerspec.LayerSpec` workload.
      cluster: the :class:`~repro.core.hardware.ClusterSpec` to plan for.
      args: the parsed ``argparse`` namespace (see ``--help``).

    Returns:
      A configured :class:`~repro.core.GalvatronOptimizer`.

    Raises:
      ValueError: unknown ``--variant`` preset.
    """
    ocfg = galvatron_variant(args.variant)
    if args.batch_grid:
        # validate + canonicalize here (dedupe / sort / reject non-positive
        # entries) so a bad --batch-grid fails loudly at startup instead of
        # silently corrupting the two-consecutive-OOM batch stop
        ocfg.batch_grid = normalize_batch_grid(
            [int(b) for b in args.batch_grid.split(",")])
    ocfg.n_bins = args.n_bins
    ocfg.micro_candidates = args.micro_candidates
    if args.max_pp:
        ocfg.max_pp = args.max_pp
    if args.schedules:
        ocfg.schedules = tuple(args.schedules.split(","))
    if getattr(args, "backend", ""):
        ocfg.search_backend = args.backend
    if getattr(args, "jobs", 0):
        ocfg.jobs = args.jobs
    ocfg.prune_batch_axis = bool(getattr(args, "prune", False))
    if getattr(args, "sp", False):
        ocfg.use_sp = True
    if getattr(args, "max_sp", 0):
        ocfg.max_sp = args.max_sp
    if getattr(args, "ep", False):
        ocfg.use_ep = True
    if getattr(args, "max_ep", 0):
        ocfg.max_ep = args.max_ep
    cost_cfg = None
    if getattr(args, "min_samples_per_device", 0.0):
        from repro.core.cost_model import CostModelConfig
        cost_cfg = CostModelConfig(
            min_samples_per_device=args.min_samples_per_device)
    return GalvatronOptimizer(specs, cluster, ocfg, cost_cfg)


def main(argv=None) -> int:
    """CLI entry point (see module docstring and ``--help``).

    Args:
      argv: argument list (default: ``sys.argv[1:]``).

    Returns:
      Process exit code — 0 on success, 1 when no feasible plan exists
      under a single ``--budget``.
    """
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    src = ap.add_argument_group("model")
    src.add_argument("--arch", help="assigned architecture id (see configs)")
    src.add_argument("--model", help="paper evaluation model name")
    src.add_argument("--seq", type=int, default=2048,
                     help="sequence length for --arch models")
    ap.add_argument("--cluster", default="8x-rtx-titan-pcie",
                    help="cluster preset name from repro.core.CLUSTERS")
    ap.add_argument("--devices", type=int, default=0,
                    help="override the preset's device count")
    ap.add_argument("--budget", type=float, default=0.0,
                    help="single memory budget in GB (one optimize() plan)")
    ap.add_argument("--budget-sweep", default="",
                    help='GB list "4,6,8" or ellipsis "8,16,...,80"')
    srv = ap.add_argument_group("serving (SLO-axis search)")
    srv.add_argument("--slo-sweep", default="",
                     help="per-token latency SLOs in ms "
                          '("20,30,50" or ellipsis "10,20,...,80"): decode '
                          "is bandwidth-bound, so each SLO maps to the byte "
                          "budget slo * hbm_bw * efficiency and rides the "
                          "same frontier engine as --budget-sweep; emitted "
                          "plans carry a v3 serving section "
                          "(docs/serving.md)")
    srv.add_argument("--max-context", type=int, default=2048,
                     help="serving plans: per-request context ceiling")
    srv.add_argument("--mean-context", type=float, default=0.0,
                     help="serving plans: expected mean context for KV "
                          "traffic/pool sizing (default max-context / 2)")
    srv.add_argument("--ttft-slo", type=float, default=0.0,
                     help="serving plans: optional TTFT target in ms "
                          "(recorded in the serving section)")
    ap.add_argument("--quant", type=float, default=0.0,
                    help="quantization-grid anchor in GB (default: the "
                         "largest swept budget).  The DP resolves memory in "
                         "quant/n-bins steps, so a wide sweep quantizes its "
                         "small budgets coarsely; anchor at the smallest "
                         "budget for dedicated-search resolution everywhere "
                         "at higher search cost")
    ap.add_argument("--backend", default="", choices=("",) + SEARCH_BACKENDS,
                    help="candidate execution backend: serial (the oracle), "
                         "threads / processes (pooled (B, P) fan-out), or "
                         "vectorized (stage DPs batched into one stacked "
                         "NumPy evaluation).  Plans are byte-identical "
                         "across backends (default: serial)")
    ap.add_argument("--jobs", type=int, default=0,
                    help="worker count for the threads/processes backends "
                         "(default: one per core)")
    ap.add_argument("--no-prune", dest="prune", action="store_false",
                    help="disable frontier-guided batch-axis pruning "
                         "(pruning skips (B, P) candidates whose certified "
                         "optimistic bound is dominated or over-budget; "
                         "plans are identical either way, it only saves "
                         "search time)")
    ap.add_argument("--parallel", action="store_true",
                    help="fan (B, P) candidates across a thread pool "
                         "(same as --backend threads)")
    ap.add_argument("--workers", type=int, default=0,
                    help="thread-pool size for --parallel (default: auto)")
    ap.add_argument("--variant", default="bmw",
                    help="galvatron_variant search-space preset: dp+tp / "
                         "dp+pp / galvatron / base / 1f1b-biobj / bmw")
    ap.add_argument("--batch-grid", default="",
                    help='comma global-batch sizes to sweep, e.g. "16,32,64" '
                         "(default: the geometric+linear Alg. 1 grid)")
    ap.add_argument("--n-bins", type=int, default=128,
                    help="DP memory-quantization bins (more = finer plans, "
                         "slower search)")
    ap.add_argument("--micro-candidates", type=int, default=3,
                    help="micro-batch counts tried per (B, P), doubling "
                         "from P")
    ap.add_argument("--max-pp", type=int, default=0,
                    help="cap the searched pipeline degree (0 = no cap)")
    ap.add_argument("--sp", action="store_true",
                    help="add ring-attention sequence parallelism to the "
                         "searched paradigms (plan format v4 sp_degree; "
                         "needed for long contexts where no sp=1 plan "
                         "fits the budget — docs/architecture.md §SP)")
    ap.add_argument("--max-sp", type=int, default=0,
                    help="cap the searched sequence-parallel degree "
                         "(0 = no cap; implies nothing without --sp)")
    ap.add_argument("--ep", action="store_true",
                    help="add expert parallelism to the searched paradigms "
                         "(plan format v5 ep_degree; MoE expert weights "
                         "shard over an expert axis with all-to-all "
                         "dispatch/combine — docs/architecture.md §EP)")
    ap.add_argument("--max-ep", type=int, default=0,
                    help="cap the searched expert-parallel degree "
                         "(0 = no cap; implies nothing without --ep)")
    ap.add_argument("--min-samples-per-device", type=float, default=0.0,
                    help="physical per-device batch floor: reject "
                         "strategies whose DP/SDP span leaves fewer "
                         "samples per device (data parallelism cannot "
                         "split one sequence; set 1.0 for long-context "
                         "searches so SP is priced honestly; 0 = the "
                         "paper's unconstrained linear model)")
    ap.add_argument("--schedules", default="",
                    help="comma list of pipeline-schedule candidates the "
                         "search sweeps per (B, P): any of gpipe, 1f1b, "
                         "1f1b-interleaved, zb-h1 "
                         '(e.g. "1f1b,1f1b-interleaved,zb-h1"; '
                         "default: the variant's single schedule)")
    ap.add_argument("--verbose", action="store_true",
                    help="print every improving (B, P, budget) candidate")
    ap.add_argument("--strict", action="store_true",
                    help="fail (exit 2) if an emitted plan carries any "
                         "verifier warnings, not just errors; plan files "
                         "read elsewhere also reject deprecated v0/v1 "
                         "under strict")
    ap.add_argument("--out", default="", help="write frontier/plan JSON here")
    args = ap.parse_args(argv)

    specs, model_name = _specs_for(args)
    cluster = _cluster_for(args)
    opt = build_optimizer(specs, cluster, args)
    if args.quant:
        opt.cfg.quant_bytes = args.quant * GB
    print(f"model={model_name} ({len(specs)} layers)  cluster={cluster.name} "
          f"x{cluster.n_devices}")

    workers = args.jobs or args.workers or None
    if args.slo_sweep:
        import json as _json

        from repro.serving import ServingPlanSearch
        slos = parse_sweep_values(args.slo_sweep)
        search = ServingPlanSearch(specs, cluster, config=opt.cfg)
        points, frontier = search.sweep_slos(
            slos, max_context=args.max_context,
            mean_context=args.mean_context or None,
            ttft_slo_ms=args.ttft_slo,
            backend=args.backend or None, verbose=args.verbose)
        for pt in points:
            if pt.plan is None or pt.plan.serving is None:
                print(f"{pt.slo_ms:8.1f} ms  infeasible "
                      f"({pt.budget_bytes / GB:.1f} GB streamable/step)")
                continue
            sv = pt.plan.serving
            print(f"{pt.slo_ms:8.1f} ms  tp{sv.decode_tp} pp{sv.decode_pp} "
                  f"b={sv.decode_batch} page={sv.page_size} "
                  f"pool={sv.kv_pool_pages}p  "
                  f"est {sv.est_tok_ms:.2f} ms/tok, "
                  f"{sv.est_tok_per_s:.0f} tok/s, "
                  f"ttft {sv.est_ttft_ms:.1f} ms")
        emitted = [pt.plan for pt in points if pt.plan is not None]
        if not emitted:
            print("no SLO point is feasible", file=sys.stderr)
            return 1
        if len(emitted) == 1:
            payload = emitted[0].dumps()     # directly servable plan file
        else:
            payload = _json.dumps(
                {"slo_points": [
                    {"slo_ms": pt.slo_ms, "budget_bytes": pt.budget_bytes,
                     "plan": (pt.plan.to_json() if pt.plan else None)}
                    for pt in points]}, indent=2)
    elif args.budget_sweep:
        budgets = parse_budget_sweep(args.budget_sweep)
        frontier = opt.sweep_budgets(
            budgets, parallel=args.parallel, max_workers=workers,
            backend=args.backend or None, verbose=args.verbose)
        print(frontier.summary())
        knees = frontier.knee_points()
        print(f"{len(frontier.feasible_points())}/{len(frontier.points)} "
              f"budgets feasible, {len(knees)} knee points; "
              f"search {opt.stats['search_seconds']:.2f}s "
              f"({opt.stats['stage_cache_hits']:.0f} cache hits / "
              f"{opt.stats['stage_cache_misses']:.0f} misses; "
              f"{opt.stats['bp_pruned_infeasible']:.0f} candidates pruned "
              f"over-budget + {opt.stats['bp_pruned_dominated']:.0f} "
              f"dominated of {opt.stats['bp_candidates']:.0f}, "
              f"{opt.stats['bp_forced']:.0f} forced)")
        emitted = [p.plan for p in frontier.feasible_points()]
        payload = frontier.dumps()
    else:
        # a 1-point sweep is byte-identical to optimize() and honours the
        # --backend / --parallel (B, P) fan-out
        budget = args.budget * GB if args.budget else cluster.budget()
        plan = opt.sweep_budgets(
            [budget], parallel=args.parallel, max_workers=workers,
            backend=args.backend or None,
            verbose=args.verbose).points[0].plan
        if plan is None:
            print(f"no feasible plan under {budget / GB:.1f} GB", file=sys.stderr)
            return 1
        print(f"{budget / GB:7.1f} GB  {plan.est_throughput:10.2f} samples/s  "
              f"{plan.summary()}")
        emitted = [plan]
        payload = plan.dumps()

    # the verifier gates serialization: an uncertified plan is never
    # written (docs/analysis.md)
    if not certify_plans(emitted, strict=args.strict,
                         log=lambda s: print(s, file=sys.stderr)):
        print(f"verification failed for {len(emitted)} emitted plan(s); "
              "not writing output", file=sys.stderr)
        return 2

    if args.out:
        pathlib.Path(args.out).write_text(payload + "\n")
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
