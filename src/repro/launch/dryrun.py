"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and emit roofline rows.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]

The XLA_FLAGS assignment below MUST run before any other jax-importing
module — jax locks the device count at first init.  Only this entry point
does it; tests and benchmarks see the real (1-device) platform.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import get_config, list_archs
from repro.configs.specs import layerspecs_for
from repro.core.layerspec import LayerSpec
from repro.launch.inputs import config_for_shape, decode_dims, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.common import INPUT_SHAPES, ModelConfig
from repro.roofline import model_flops, roofline_report
from repro.runtime import (ShardPolicy, make_prefill_step, make_serve_step,
                           make_train_step)

ASSIGNED = ["qwen2-72b", "qwen2.5-14b", "internvl2-26b", "kimi-k2-1t-a32b",
            "qwen3-4b", "zamba2-1.2b", "whisper-medium", "mamba2-370m",
            "arctic-480b", "qwen3-8b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def default_policy(cfg: ModelConfig, mode: str,
                   overrides: Optional[Dict[str, Any]] = None) -> ShardPolicy:
    """Paper-faithful baseline mapping: the Galvatron plan for the
    production cluster resolves to SDP x TP with CKPT for training
    (see EXPERIMENTS.md §Dry-run); serving uses TP only."""
    kw: Dict[str, Any] = {}
    if mode == "train":
        n_seg = 2 if (cfg.n_experts > 1 and cfg.first_k_dense) else 1
        kw = dict(tp=True, zero=True, remat_segments=(True,) * n_seg)
    else:
        kw = dict(tp=True, zero=False)
    kw.update(overrides or {})
    return ShardPolicy(**kw)


def depth_scaled(cfg: ModelConfig, n: int) -> ModelConfig:
    """Same architecture at reduced depth (scan-linear probe point)."""
    kw: Dict[str, Any] = {"n_layers": n}
    if cfg.is_encoder_decoder:
        kw["n_enc_layers"] = n
    return cfg.with_(**kw)


def probe_depths(cfg: ModelConfig):
    """Two shallow depths whose linear extrapolation reproduces the full
    model's per-device HLO cost (scan bodies are depth-homogeneous)."""
    if cfg.arch_type == "hybrid" and cfg.attn_every:
        return cfg.attn_every, 2 * cfg.attn_every
    if cfg.n_experts > 1 and cfg.first_k_dense:
        return cfg.first_k_dense + 1, cfg.first_k_dense + 2
    return 2, 4


def _model_flops_global(cfg: ModelConfig, shape, train: bool) -> float:
    specs = layerspecs_for(config_for_shape(cfg, shape), shape.seq_len)
    n = sum(s.param_count for s in specs)
    n_active = sum(s.active_param_count() for s in specs)
    toks = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    return model_flops(n, toks, active_params=n_active, train=train)


def _compile_step(cfg: ModelConfig, shape, mesh,
                  policy_overrides: Optional[Dict[str, Any]] = None):
    if shape.mode == "train":
        pol = default_policy(cfg, "train", policy_overrides)
        built = make_train_step(cfg, mesh, pol, input_specs(cfg, shape))
    elif shape.mode == "prefill":
        pol = default_policy(cfg, "serve", policy_overrides)
        built = make_prefill_step(cfg, mesh, pol, input_specs(cfg, shape))
    else:  # decode
        pol = default_policy(cfg, "serve", policy_overrides)
        B, ctx = decode_dims(cfg, shape)
        built = make_serve_step(cfg, mesh, pol, batch=B, context=ctx)
    return built.fn.lower(*built.abstract_args).compile()


def _per_device_costs(compiled) -> Dict[str, float]:
    from repro.roofline import collective_bytes_from_hlo
    cost = compiled.cost_analysis()
    # jax <= 0.4.x returns [{...}] (one dict per partition), newer a dict
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    colls = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(colls.values())),
        "colls": colls,
    }


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            policy_overrides: Optional[Dict[str, Any]] = None,
            config_overrides: Optional[Dict[str, Any]] = None,
            variant: str = "baseline",
            verbose: bool = True) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    cfg = config_for_shape(get_config(arch), shape)
    if config_overrides:
        cfg = cfg.with_(**config_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 2 * 256 if multi_pod else 256
    t0 = time.time()

    with mesh:
        # (1) full-depth compile: proves lowering succeeds and memory fits
        compiled = _compile_step(cfg, shape, mesh, policy_overrides)
        # (2) two shallow probes: XLA cost_analysis counts a scan body once
        # regardless of trip count, so we linearly extrapolate per-device
        # FLOPs/bytes/collective-bytes from two depths (exact for
        # homogeneous scan stacks).
        from repro.models.flags import force_unroll
        d1, d2 = probe_depths(cfg)
        with force_unroll():
            c1 = _per_device_costs(_compile_step(depth_scaled(cfg, d1), shape,
                                                 mesh, policy_overrides))
            c2 = _per_device_costs(_compile_step(depth_scaled(cfg, d2), shape,
                                                 mesh, policy_overrides))

    alpha = (cfg.n_layers - d1) / (d2 - d1)
    ext = {k: c1[k] + alpha * (c2[k] - c1[k]) for k in ("flops", "bytes", "coll")}
    colls = {k: c1["colls"][k] + alpha * (c2["colls"][k] - c1["colls"][k])
             for k in c1["colls"]}

    mem = compiled.memory_analysis()
    rep = roofline_report(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        cost_analysis={"flops": ext["flops"], "bytes accessed": ext["bytes"]},
        hlo_text="", model_flops_global=_model_flops_global(
            cfg, shape, shape.mode == "train"))
    # overwrite collective numbers with the extrapolated parse
    rep.collective_bytes = ext["coll"] * chips
    rep.per_op_collectives = colls
    rep.t_collective = rep.collective_bytes / (chips * 50e9)

    # modeled (fusion-aware) HBM traffic + residency; keep the raw unfused
    # XLA:CPU number alongside as an upper bound.
    from repro.roofline.analysis import modeled_memory
    specs = layerspecs_for(cfg, shape.seq_len)
    cache_total = 0.0
    if shape.mode == "decode":
        if cfg.arch_type in ("ssm", "hybrid"):
            n_ssm = cfg.n_layers
            cache_total += n_ssm * shape.global_batch * cfg.ssm_heads \
                * cfg.ssm_head_dim * cfg.ssm_state * 4.0
        if cfg.arch_type != "ssm" and cfg.n_kv_heads:
            span = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
            n_attn = (cfg.n_layers if cfg.arch_type != "hybrid"
                      else max(1, cfg.n_layers // (cfg.attn_every or 6)))
            cache_total += n_attn * shape.global_batch * span \
                * cfg.n_kv_heads * cfg.dh * 2 * 2.0
    data_shards = 16 * (2 if multi_pod else 1)
    seq_shard = 16 if (policy_overrides or {}).get("seq_shard") else 1
    mm = modeled_memory(
        specs, mode=shape.mode, chips=chips, tp=16, data_shards=data_shards,
        remat=shape.mode == "train", batch=shape.global_batch,
        cache_bytes_total=cache_total, seq_shard=seq_shard)
    rep.t_memory, raw_t_memory = mm.t_memory(), rep.t_memory

    def _mem_attr(name):
        v = getattr(mem, name, None)
        return float(v) if v is not None else None

    row = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mode": shape.mode, "chips": chips, "variant": variant,
        "compile_seconds": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": _mem_attr("argument_size_in_bytes"),
            "output_bytes": _mem_attr("output_size_in_bytes"),
            "temp_bytes": _mem_attr("temp_size_in_bytes"),
            "generated_code_bytes": _mem_attr("generated_code_size_in_bytes"),
        },
        "t_memory_unfused_s": raw_t_memory,
        "modeled_resident_bytes_per_device": mm.resident_bytes_per_device,
        "modeled_fits_16g": mm.fits,
        **rep.row(),
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"compile={row['compile_seconds']}s "
              f"bottleneck={rep.bottleneck} "
              f"t=(c{rep.t_compute:.4f} m{rep.t_memory:.4f} "
              f"x{rep.t_collective:.4f})s "
              f"useful={rep.useful_flops_ratio:.2f}")
        print("  memory_analysis:", row["memory"])
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default=None)
    ap.add_argument("--shape", choices=SHAPES, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--isolate", action="store_true",
                    help="run each combo in its own subprocess")
    args = ap.parse_args(argv)

    combos = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                for mp in meshes:
                    combos.append((a, s, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape, mp) for mp in meshes]

    out_path = pathlib.Path(args.out) if args.out else None
    if out_path:
        out_path.parent.mkdir(parents=True, exist_ok=True)

    def emit(row):
        if out_path:
            with out_path.open("a") as f:
                f.write(json.dumps(row) + "\n")

    n_ok, failures = 0, []
    if args.isolate:
        # one subprocess per combo: an OOM-killed compile only loses that
        # combo, and each compile's RSS is returned to the OS afterwards.
        import subprocess
        done = set()
        if out_path and out_path.exists():
            for line in out_path.read_text().splitlines():
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass
        for a, s, mp in combos:
            key = (a, s, "2x16x16" if mp else "16x16")
            if key in done:
                print(f"[skip cached] {key}")
                n_ok += 1
                continue
            cmd = [sys.executable, "-u", "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s]
            if mp:
                cmd.append("--multi-pod")
            if args.out:
                cmd += ["--out", str(out_path)]
            res = subprocess.run(cmd, timeout=3600)
            if res.returncode == 0:
                n_ok += 1
            else:
                failures.append((a, s, mp, f"rc={res.returncode}"))
    else:
        for a, s, mp in combos:
            try:
                emit(run_one(a, s, multi_pod=mp))
                n_ok += 1
            except Exception as e:  # noqa: BLE001 — report all failures
                traceback.print_exc()
                failures.append((a, s, mp, repr(e)))
    print(f"\ndry-run: {n_ok} ok, {len(failures)} failed", flush=True)
    for f_ in failures:
        print("  FAIL", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
