"""Serving driver: batched autoregressive decoding with a simple
continuous-batching scheduler (finished sequences are replaced by queued
requests in place, so the decode batch stays full).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \\
        --requests 16 --batch 4 --max-new 32
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_local_mesh
from repro.models import init_decode_state, init_lm
from repro.runtime import ShardPolicy, make_serve_step


class Request:
    def __init__(self, rid: int, prompt: List[int], max_new: int):
        self.rid = rid
        self.prompt = prompt
        self.max_new = max_new
        self.generated: List[int] = []
        self.done = False


def serve(cfg, requests: List[Request], batch: int, context: int,
          *, eos_id: Optional[int] = None, greedy: bool = True,
          seed: int = 0, verbose: bool = True):
    """Continuous batching: one shared KV state, slot-per-lane."""
    mesh = make_local_mesh()
    policy = ShardPolicy(tp=False, zero=False)
    key = jax.random.PRNGKey(seed)
    with mesh:
        step = make_serve_step(cfg, mesh, policy, batch=batch, context=context)
        params = jax.jit(lambda k: init_lm(k, cfg),
                         out_shardings=step.in_shardings[0])(key)
        state = jax.jit(lambda: init_decode_state(cfg, batch, context),
                        out_shardings=step.in_shardings[1])()

        queue = list(requests)
        lanes: List[Optional[Request]] = [None] * batch
        lane_pending: List[List[int]] = [[] for _ in range(batch)]
        tok = np.zeros((batch,), np.int32)
        n_steps = 0
        t0 = time.time()
        while queue or any(l is not None for l in lanes):
            for i in range(batch):
                if lanes[i] is None and queue:
                    r = queue.pop(0)
                    lanes[i] = r
                    lane_pending[i] = list(r.prompt)
                    tok[i] = lane_pending[i].pop(0)
            logits, state = step.fn(params, state, jnp.asarray(tok))
            n_steps += 1
            nxt = np.asarray(jnp.argmax(logits, -1))
            for i in range(batch):
                r = lanes[i]
                if r is None:
                    continue
                if lane_pending[i]:                   # still feeding prompt
                    tok[i] = lane_pending[i].pop(0)
                    continue
                t = int(nxt[i])
                r.generated.append(t)
                tok[i] = t
                if (eos_id is not None and t == eos_id) or \
                        len(r.generated) >= r.max_new:
                    r.done = True
                    lanes[i] = None
        dt = time.time() - t0
        total_new = sum(len(r.generated) for r in requests)
        if verbose:
            print(f"served {len(requests)} requests, {total_new} tokens in "
                  f"{dt:.2f}s ({total_new/dt:.1f} tok/s, {n_steps} steps)")
    return requests


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--context", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=4).tolist(),
                    args.max_new) for i in range(args.requests)]
    serve(cfg, reqs, args.batch, args.context)
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt={r.prompt} -> {r.generated[:8]}...")


if __name__ == "__main__":
    main()
