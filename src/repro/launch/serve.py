"""Serving driver.

Two engines behind one CLI:

  * ``--engine paged`` (default) — the continuous-batching engine over the
    paged KV cache (``repro.serving``): batched chunked prefill
    disaggregated from decode, slot recycling, shared page pools.
  * ``--engine dense`` — the reference dense-cache path: one KV ring
    buffer per lane at full ``--context``, prompts fed one token per
    decode step.  Kept as the greedy-token oracle the paged engine is
    differentially tested against, and as the memory baseline
    ``benchmarks/bench_serve.py`` compares page occupancy to.

``--plan plan.json`` drives the paged engine from a searched v3 plan's
``serving`` section (page size, pool size, decode batch, prefill chunk) —
the file goes through the verified loading path (``repro.analysis``), so a
malformed or SLO-inconsistent plan is a structured diagnostic, not a
crash mid-serve.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \\
        --requests 16 --batch 4 --max-new 32
"""
from __future__ import annotations

import argparse
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_local_mesh
from repro.models import init_decode_state, init_lm
from repro.runtime import ShardPolicy, make_serve_step


class Request:
    def __init__(self, rid: int, prompt: List[int], max_new: int):
        self.rid = rid
        self.prompt = prompt
        self.max_new = max_new
        self.generated: List[int] = []
        self.done = False


def serve(cfg, requests: List[Request], batch: int, context: int,
          *, eos_id: Optional[int] = None, greedy: bool = True,
          seed: int = 0, verbose: bool = True):
    """Dense-cache reference: one shared KV state, slot-per-lane.

    Each lane carries its *own* cache index (per-lane positions), so a
    recycled slot restarts at position 0 and the ring-cache validity mask
    hides the previous request's K/V — recycling never leaks context
    across requests.  Prompts are fed one token per step (the paged
    engine's chunked prefill replaces this; kept here as the oracle)."""
    mesh = make_local_mesh()
    policy = ShardPolicy(tp=False, zero=False)
    key = jax.random.PRNGKey(seed)
    with mesh:
        step = make_serve_step(cfg, mesh, policy, batch=batch, context=context)
        params = jax.jit(lambda k: init_lm(k, cfg),
                         out_shardings=step.in_shardings[0])(key)
        state = jax.jit(lambda: init_decode_state(cfg, batch, context),
                        out_shardings=step.in_shardings[1])()
        # scalar shared index -> per-lane positions
        state["index"] = jnp.zeros((batch,), jnp.int32)

        queue = deque(requests)
        lanes: List[Optional[Request]] = [None] * batch
        cursor = [0] * batch                  # next prompt position per lane
        tok = np.zeros((batch,), np.int32)
        n_steps = 0
        t0 = time.time()
        while queue or any(l is not None for l in lanes):
            for i in range(batch):
                if lanes[i] is None and queue:
                    r = queue.popleft()
                    lanes[i] = r
                    cursor[i] = 1
                    tok[i] = r.prompt[0]
                    # recycled slot starts over at position 0; stale ring
                    # slots are masked by the per-lane validity window
                    state["index"] = state["index"].at[i].set(0)
            logits, state = step.fn(params, state, jnp.asarray(tok))
            n_steps += 1
            if greedy:
                nxt = np.asarray(jnp.argmax(logits, -1))
            else:
                key, sub = jax.random.split(key)
                nxt = np.asarray(jax.random.categorical(sub, logits, -1))
            for i in range(batch):
                r = lanes[i]
                if r is None:
                    continue
                if cursor[i] < len(r.prompt):     # still feeding prompt
                    tok[i] = r.prompt[cursor[i]]
                    cursor[i] += 1
                    continue
                t = int(nxt[i])
                r.generated.append(t)
                tok[i] = t
                if (eos_id is not None and t == eos_id) or \
                        len(r.generated) >= r.max_new:
                    r.done = True
                    lanes[i] = None
        dt = time.time() - t0
        total_new = sum(len(r.generated) for r in requests)
        if verbose:
            print(f"served {len(requests)} requests, {total_new} tokens in "
                  f"{dt:.2f}s ({total_new/dt:.1f} tok/s, {n_steps} steps)")
    return requests


def serve_paged(cfg, requests: List[Request], ecfg, *,
                seed: int = 0, verbose: bool = True):
    """Continuous-batching serve over the paged KV cache.

    Returns the engine's :class:`~repro.serving.ServeMetrics`; generated
    tokens are written back into each :class:`Request`."""
    from repro.serving import ServeRequest, ServingEngine

    mesh = make_local_mesh()
    key = jax.random.PRNGKey(seed)
    params = jax.jit(lambda k: init_lm(k, cfg))(key)
    engine = ServingEngine(cfg, params, mesh, ecfg)
    sreqs = [ServeRequest(rid=str(r.rid), prompt=list(r.prompt),
                          max_new=r.max_new) for r in requests]
    metrics = engine.run(sreqs, verbose=False)
    for r, s in zip(requests, sreqs):
        r.generated = list(s.tokens)
        r.done = s.done
    if verbose:
        summ = metrics.summary()
        print(f"served {summ['completed']} requests, {summ['new_tokens']} "
              f"tokens in {summ['wall_s']:.2f}s "
              f"({summ['tok_per_s']:.1f} tok/s, "
              f"{summ['decode_steps']} decode steps, "
              f"{summ['prefill_chunks']} prefill chunks, "
              f"peak page occupancy {summ['page_occupancy_max']:.2f})")
    return metrics


def engine_config_from_args(args, cfg):
    """Resolve the paged-engine geometry: ``--plan``'s serving section when
    given, CLI flags otherwise (flags override plan fields when set)."""
    from repro.serving import EngineConfig

    page_size, n_pages = args.page_size, args.pages
    batch, context = args.batch, args.context
    prefill_chunk, eos = args.prefill_chunk, args.eos_id
    if args.plan:
        from repro.analysis import load_plan_file
        plan, _ = load_plan_file(args.plan)
        sv = plan.serving
        if sv is None:
            raise SystemExit(
                f"{args.plan}: plan has no serving section (a v3 serving "
                "plan comes from `search --slo-sweep`)")
        page_size = sv.page_size
        context = min(sv.max_context, context) if context else sv.max_context
        batch = min(sv.decode_batch, batch) if batch else sv.decode_batch
        prefill_chunk = prefill_chunk or sv.prefill_chunk
        n_pages = n_pages or sv.kv_pool_pages
    context = context or 128
    batch = batch or 4
    page_size = page_size or 16
    context = -(-context // page_size) * page_size   # round up to pages
    n_pages = n_pages or (batch * (context // page_size))
    return EngineConfig(
        page_size=page_size, n_pages=n_pages, decode_slots=batch,
        max_context=context,
        prefill_batch=min(4, batch),
        prefill_chunk=prefill_chunk or min(32, context),
        eos_id=eos)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="serve.py",
        description="Serve synthetic requests with the paged "
                    "continuous-batching engine or the dense reference.")
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-4b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="shrink the model for local runs "
                         "(--no-reduced serves the full config)")
    ap.add_argument("--engine", choices=("paged", "dense"), default="paged")
    ap.add_argument("--plan", default=None, metavar="PLAN.json",
                    help="drive the paged engine from a searched v3 plan's "
                         "serving section (verified load)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=0,
                    help="decode lanes (0 = from plan, default 4)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--context", type=int, default=0,
                    help="per-lane context cap (0 = from plan, default 128)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged engine: tokens per KV page")
    ap.add_argument("--pages", type=int, default=0,
                    help="paged engine: shared pool pages per layer")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="paged engine: prompt tokens per prefill call")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=4).tolist(),
                    args.max_new) for i in range(args.requests)]
    if args.engine == "paged":
        ecfg = engine_config_from_args(args, cfg)
        serve_paged(cfg, reqs, ecfg, seed=args.seed)
    else:
        serve(cfg, reqs, args.batch or 4, args.context or 128,
              eos_id=args.eos_id, seed=args.seed)
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt={r.prompt} -> {r.generated[:8]}...")


if __name__ == "__main__":
    main()
