"""Kimi K2 1T-A32B [arXiv:2501.kimi2] — trillion-parameter MoE: 61 layers,
384 experts, top-8 routing, d_ff(expert)=2048, one shared expert, first
layer dense (DeepSeek-V3-style layout)."""
from repro.configs import register
from repro.models.common import ModelConfig

KIMI_K2 = register(ModelConfig(
    name="kimi-k2-1t-a32b", arch_type="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    n_experts=384, top_k=8, shared_expert_ff=2048, first_k_dense=1,
    rope_theta=1e6, norm_eps=1e-6,
))
