"""Whisper-medium [arXiv:2212.04356] — encoder-decoder, 24+24 layers,
d=1024, 16 heads (MHA), GELU MLP d_ff=4096.  Conv/mel frontend is a stub:
the encoder consumes precomputed frame embeddings (1500 frames)."""
from repro.configs import register
from repro.models.common import ModelConfig

WHISPER_MEDIUM = register(ModelConfig(
    name="whisper-medium", arch_type="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    is_encoder_decoder=True, n_enc_layers=24, encoder_seq=1500,
    norm_eps=1e-5, tie_embeddings=True,
))
