"""Architecture configs: the 10 assigned architectures + the paper's models.

Each assigned arch lives in ``configs/<id>.py`` (exact dims from the
assignment, source cited) and registers itself here.  ``get_config(name)``
is the single lookup used by the launcher (``--arch <id>``).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

_ARCH_MODULES = [
    "qwen2_72b", "qwen2_5_14b", "internvl2_26b", "kimi_k2_1t_a32b",
    "qwen3_4b", "zamba2_1_2b", "whisper_medium", "mamba2_370m",
    "arctic_480b", "qwen3_8b",
]

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    key = name.replace("_", "-")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    importlib.import_module("repro.configs.paper_models")
