"""The paper's evaluation models (Table I) as cost-estimator workloads.

These drive the reproduction benchmarks (Tables II–VI, Fig. 5).  Parameter
counts are validated against Table I in tests.  ``store_attn_matrix=True``
reflects the paper's 2022/23 PyTorch implementations (no flash attention —
attention probabilities are stashed for backward).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.layerspec import (LayerSpec, cross_attn_extra, dense_layer,
                                  embed_layer, head_layer, merge)
from repro.configs import register
from repro.models.common import ModelConfig


def bert(n_layers: int, d: int, seq: int = 512, vocab: int = 30522,
         name: str = "bert") -> List[LayerSpec]:
    heads = d // 64
    specs = [embed_layer("embed", seq, d, vocab)]
    for i in range(n_layers):
        specs.append(dense_layer(f"enc{i}", seq, d, heads, heads, 4 * d,
                                 causal=False, gated=False, qkv_bias=True,
                                 store_attn_matrix=True))
    return specs


def vit(n_layers: int, d: int, n_patches: int = 197,
        n_classes: int = 1000) -> List[LayerSpec]:
    heads = d // 64
    specs = [embed_layer("patch_embed", n_patches, d, 768)]  # 16x16x3 proj
    for i in range(n_layers):
        specs.append(dense_layer(f"enc{i}", n_patches, d, heads, heads, 4 * d,
                                 causal=False, gated=False, qkv_bias=True,
                                 store_attn_matrix=True))
    return specs


def t5(n_enc: int, n_dec: int, d: int, enc_seq: int, dec_seq: int,
       vocab: int = 32128) -> List[LayerSpec]:
    heads = d // 64
    specs = [embed_layer("embed", enc_seq, d, vocab)]
    for i in range(n_enc):
        specs.append(dense_layer(f"enc{i}", enc_seq, d, heads, heads, 4 * d,
                                 causal=False, gated=False,
                                 store_attn_matrix=True))
    for i in range(n_dec):
        base = dense_layer(f"dec{i}", dec_seq, d, heads, heads, 4 * d,
                           causal=True, gated=False, store_attn_matrix=True)
        cross = cross_attn_extra(dec_seq, enc_seq, d, heads, heads,
                                 store_attn_matrix=True)
        specs.append(merge(f"dec{i}", base, cross))
    return specs


def swin(depths: Tuple[int, ...], dims: Tuple[int, ...],
         img_tokens: int = 3136, window: int = 49,
         n_classes: int = 1000) -> List[LayerSpec]:
    """Swin: hierarchical stages, window attention, patch merging between
    stages (tokens /4, dim x2).  Uneven per-layer workloads — the paper's
    showcase for layer-wise strategy search (Fig. 6 case B)."""
    specs = [embed_layer("patch_embed", img_tokens, dims[0], 48)]
    tokens = img_tokens
    for si, (depth, d) in enumerate(zip(depths, dims)):
        heads = max(1, d // 32)
        for li in range(depth):
            specs.append(dense_layer(
                f"s{si}l{li}", tokens, d, heads, heads, 4 * d,
                causal=False, gated=False, qkv_bias=True,
                store_attn_matrix=True, window=window))
        if si + 1 < len(dims):
            tokens //= 4
    return specs


def gpt3(n_layers: int, d: int, seq: int = 2048,
         vocab: int = 50257) -> List[LayerSpec]:
    heads = d // 128
    specs = [embed_layer("embed", seq, d, vocab)]
    for i in range(n_layers):
        specs.append(dense_layer(f"dec{i}", seq, d, heads, heads, 4 * d,
                                 causal=True, gated=False, qkv_bias=True,
                                 store_attn_matrix=True))
    specs.append(head_layer("head", seq, d, vocab))
    return specs


PAPER_MODELS: Dict[str, List[LayerSpec]] = {}


def paper_model_specs(name: str) -> List[LayerSpec]:
    if not PAPER_MODELS:
        PAPER_MODELS.update({
            "bert-huge-32": bert(32, 1280),
            "bert-huge-48": bert(48, 1280),
            "bert-xhuge": bert(128, 2560),
            "vit-huge-32": vit(32, 1280),
            "vit-huge-48": vit(48, 1280),
            "vit-xhuge": vit(128, 2560),
            "t5-large-32": t5(16, 16, 1024, 512, 512),
            "t5-large-48": t5(24, 24, 1024, 512, 512),
            "t5-512/4-32": t5(16, 16, 1024, 512, 4),
            "t5-512/4-48": t5(24, 24, 1024, 512, 4),
            "swin-huge-32": swin((2, 2, 26, 2), (320, 640, 1280, 2560)),
            "swin-huge-48": swin((2, 2, 42, 2), (320, 640, 1280, 2560)),
            "gpt3-15b": gpt3(48, 5120),
            "gpt3-39b": gpt3(48, 8192),
            "gpt3-65b": gpt3(80, 8192),
        })
    return PAPER_MODELS[name]


# A runnable GPT-3-15B-shaped dense config (usable end to end in the
# runtime, beyond the cost-model tables).
GPT3_15B_RUNTIME = register(ModelConfig(
    name="gpt3-15b", arch_type="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=20480, vocab_size=50257,
    rope_theta=10_000.0, norm_eps=1e-5,
))
