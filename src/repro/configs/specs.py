"""LayerSpec workloads for the assigned architectures — feeds the
Galvatron-BMW search when planning on the TPU clusters.  Unlike the paper
models, these assume flash attention (no stashed probability matrices)."""
from __future__ import annotations

from typing import List, Optional

from repro.core.layerspec import (LayerSpec, dense_layer, embed_layer,
                                  head_layer, moe_layer, ssm_layer)
from repro.models.common import ModelConfig


def layerspecs_for(cfg: ModelConfig, seq_len: int, *,
                   window: Optional[int] = None) -> List[LayerSpec]:
    win = window if window is not None else cfg.sliding_window
    specs: List[LayerSpec] = [
        embed_layer("embed", seq_len, cfg.d_model, cfg.vocab_size)]

    if cfg.arch_type in ("dense", "vlm"):
        seq = seq_len + (cfg.vision_tokens if cfg.arch_type == "vlm" else 0)
        for i in range(cfg.n_layers):
            specs.append(dense_layer(
                f"layer{i}", seq, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, qkv_bias=cfg.qkv_bias, window=win))
    elif cfg.arch_type == "moe":
        for i in range(cfg.n_layers):
            if cfg.is_moe_layer(i):
                specs.append(moe_layer(
                    f"layer{i}", seq_len, cfg.d_model, cfg.n_heads,
                    cfg.n_kv_heads, cfg.d_ff, cfg.n_experts, cfg.top_k,
                    d_ff_shared=cfg.shared_expert_ff,
                    dense_residual_ff=cfg.dense_residual_ff, window=win,
                    capacity_factor=cfg.capacity_factor))
            else:
                specs.append(dense_layer(
                    f"layer{i}", seq_len, cfg.d_model, cfg.n_heads,
                    cfg.n_kv_heads, cfg.d_ff * cfg.top_k, window=win))
    elif cfg.arch_type == "ssm":
        for i in range(cfg.n_layers):
            specs.append(ssm_layer(f"layer{i}", seq_len, cfg.d_model,
                                   d_state=cfg.ssm_state,
                                   expand=cfg.ssm_expand))
    elif cfg.arch_type == "hybrid":
        for i in range(cfg.n_layers):
            specs.append(ssm_layer(f"layer{i}", seq_len, cfg.d_model,
                                   d_state=cfg.ssm_state,
                                   expand=cfg.ssm_expand))
            if cfg.is_attn_layer(i):
                specs.append(dense_layer(
                    f"shared_attn{i}", seq_len, cfg.d_model, cfg.n_heads,
                    cfg.n_kv_heads, cfg.d_ff, window=win))
    elif cfg.arch_type == "audio":
        enc_seq = cfg.encoder_seq or 1500
        n_enc = cfg.n_enc_layers or cfg.n_layers
        for i in range(n_enc):
            specs.append(dense_layer(f"enc{i}", enc_seq, cfg.d_model,
                                     cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
                                     causal=False, gated=False))
        for i in range(cfg.n_layers):
            specs.append(dense_layer(f"dec{i}", seq_len, cfg.d_model,
                                     cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
                                     gated=False))
    else:
        raise ValueError(cfg.arch_type)

    specs.append(head_layer("head", seq_len, cfg.d_model, cfg.vocab_size))
    return specs
