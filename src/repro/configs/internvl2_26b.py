"""InternVL2-26B [arXiv:2404.16821] — InternViT (stub frontend) + InternLM2
language backbone.  We implement the 48L/6144/48H(GQA kv=8) LM; the vision
encoder provides precomputed patch embeddings per the modality carve-out."""
from repro.configs import register
from repro.models.common import ModelConfig

INTERNVL2_26B = register(ModelConfig(
    name="internvl2-26b", arch_type="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92553,
    rope_theta=1e6, norm_eps=1e-5,
    vision_tokens=256, d_vision=3200,     # InternViT-6B hidden size
))
