"""Qwen3-8B [hf:Qwen/Qwen3-8B] — dense, GQA, QK-norm."""
from repro.configs import register
from repro.models.common import ModelConfig

QWEN3_8B = register(ModelConfig(
    name="qwen3-8b", arch_type="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab_size=151936,
    qk_norm=True, head_dim=128, rope_theta=1e6, norm_eps=1e-6,
))
