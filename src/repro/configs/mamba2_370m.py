"""Mamba2-370m [arXiv:2405.21060] — attention-free SSD (state-space
duality): 48 layers, d=1024, ssm_state=128."""
from repro.configs import register
from repro.models.common import ModelConfig

MAMBA2_370M = register(ModelConfig(
    name="mamba2-370m", arch_type="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    norm_eps=1e-5, tie_embeddings=True,
))
