"""Qwen2-72B [arXiv:2407.10671] — dense, GQA (8 KV heads), QKV bias."""
from repro.configs import register
from repro.models.common import ModelConfig

QWEN2_72B = register(ModelConfig(
    name="qwen2-72b", arch_type="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6, norm_eps=1e-6,
))
