"""Zamba2-1.2B [arXiv:2411.15242] — hybrid: Mamba2 backbone with a
weight-SHARED full-attention block interleaved (here: every 6 SSM layers),
MHA (kv=32), ssm_state=64."""
from repro.configs import register
from repro.models.common import ModelConfig

ZAMBA2_1_2B = register(ModelConfig(
    name="zamba2-1.2b", arch_type="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    attn_every=6, shared_attention=True,
    rope_theta=10_000.0, norm_eps=1e-5, tie_embeddings=True,
))
