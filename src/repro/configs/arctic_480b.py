"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] — dense-MoE
hybrid: 35 layers, 128 experts top-2 (d_ff=4864 per expert) with an
always-on dense residual branch."""
from repro.configs import register
from repro.models.common import ModelConfig

ARCTIC_480B = register(ModelConfig(
    name="arctic-480b", arch_type="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    n_experts=128, top_k=2, dense_residual_ff=4864,
    rope_theta=1e6, norm_eps=1e-6,
))
