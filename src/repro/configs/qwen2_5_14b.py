"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B family card] — dense, GQA, QKV bias."""
from repro.configs import register
from repro.models.common import ModelConfig

QWEN2_5_14B = register(ModelConfig(
    name="qwen2.5-14b", arch_type="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6, norm_eps=1e-6,
))
