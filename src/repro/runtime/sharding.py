"""Plan → GSPMD sharding rules.

The production meshes are ``(data=16, model=16)`` per pod and
``(pod=2, data=16, model=16)`` across pods.  A searched plan maps onto them
as follows (DESIGN.md §3):

  * TP level  -> parameters sharded along the ``model`` axis
                 (column/row-parallel per Megatron; expert dim for MoE),
  * SDP level -> parameters *additionally* sharded along ``data`` (+``pod``)
                 — GSPMD inserts the ZeRO-3 all-gathers,
  * DP level  -> batch dims sharded along ``data`` (+``pod``), params
                 replicated across it,
  * CKPT      -> jax.checkpoint per layer-stack segment,
  * PP        -> the shard_map pipeline runtime (runtime/pipeline.py),
  * SP        -> batch token dims sharded along a ``seq`` axis; attention
                 runs the ring kernel (kernels/ring_attention.py) via
                 runtime/sequence.py,
  * EP        -> expert weights sharded along an ``expert`` axis (plan
                 format v5 ``ep_degree``); the batch dim co-shards over it
                 and MoE dispatch runs the all-to-all path
                 (models/moe.py::_moe_ep).

Every rule checks divisibility and falls back to replication, so any
(architecture x shape x mesh) combination lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardPolicy:
    """How a plan's dominant strategy maps to the fixed mesh."""
    tp: bool = True            # use the "model" axis for parameter sharding
    zero: bool = True          # SDP: shard params over the batch axes too
    remat_segments: Optional[Tuple[bool, ...]] = None
    # beyond-paper knobs (perf iteration):
    shard_cache_seq: bool = True   # decode KV cache: shard context over "model"
    expert_axis: str = "model"     # mesh axis carrying the expert dimension
    seq_shard: bool = False        # Megatron-style sequence parallelism on
                                   # the residual stream (stash /16)
    sp_degree: int = 1             # ring-attention sequence parallelism: the
                                   # searched plan.sp_degree — batch seq dims
                                   # shard over the mesh's "seq" axis and
                                   # attention runs the ring kernel
                                   # (kernels/ring_attention.py)
    ep_degree: int = 1             # expert parallelism: the searched
                                   # plan.ep_degree (format v5) — expert
                                   # weights shard over the mesh's "expert"
                                   # axis, the batch co-shards over it, and
                                   # MoE dispatch runs the all-to-all path

    @staticmethod
    def from_strategy(strategy, remat_segments=None) -> "ShardPolicy":
        ep = getattr(strategy, "ep", 1)
        return ShardPolicy(tp=strategy.tp > 1, zero=strategy.sdp > 1,
                           remat_segments=tuple(remat_segments or ()) or None,
                           sp_degree=getattr(strategy, "sp", 1),
                           ep_degree=ep,
                           expert_axis="expert" if ep > 1 else "model")


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(mesh: Mesh, dim: int, axes) -> bool:
    s = _axis_size(mesh, axes)
    return s > 1 and dim % s == 0


# parameter-name classes
_COLUMN = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "w_fc", "w1"}
_ROW = {"wo", "w_down", "out_proj", "w_proj", "w2"}
_EMBED = {"embed"}
_HEAD = {"head"}
_REPLICATED_HINT = {"router"}


def _leaf_spec(path, leaf, mesh: Mesh, pol: ShardPolicy) -> P:
    names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    names = [n for n in names if isinstance(n, str)]
    name = names[-1] if names else ""
    shape = leaf.shape
    nd = len(shape)
    bt = batch_axes(mesh)
    model = "model" if ("model" in mesh.axis_names and pol.tp) else None
    zero = bt if (pol.zero and bt) else None

    def spec(*entries):
        # pad to ndim with None
        entries = list(entries) + [None] * (nd - len(entries))
        return P(*entries[:nd])

    if name in _REPLICATED_HINT or nd <= 1:
        return P()

    if name in _EMBED and nd == 2:
        a0 = model if _fits(mesh, shape[0], model) else None
        a1 = zero if _fits(mesh, shape[1], zero) else None
        return P(a0, a1)
    if name in _HEAD and nd == 2:
        a1 = model if _fits(mesh, shape[1], model) else None
        a0 = zero if _fits(mesh, shape[0], zero) else None
        return P(a0, a1)
    if name in ("enc_pos", "dec_pos"):
        return P()

    # MoE stacked experts: (L, E, d, f) / (L, E, f, d)
    if name in (_COLUMN | _ROW) and nd == 4:
        e_ax = pol.expert_axis if pol.tp or pol.expert_axis != "model" else None
        e_ax = e_ax if _fits(mesh, shape[1], e_ax) else None
        z_ax = zero if _fits(mesh, shape[2], zero) else None
        return P(None, e_ax, z_ax, None)

    if name in _COLUMN:
        # (..., d_in, d_out): column parallel
        a_out = model if _fits(mesh, shape[-1], model) else None
        a_in = zero if _fits(mesh, shape[-2], zero) else None
        return spec(*([None] * (nd - 2) + [a_in, a_out]))
    if name in _ROW:
        a_in = model if _fits(mesh, shape[-2], model) else None
        a_out = zero if _fits(mesh, shape[-1], zero) else None
        return spec(*([None] * (nd - 2) + [a_in, a_out]))

    # default: try ZeRO-sharding the largest dim (skipping stacked L at 0)
    if pol.zero and nd >= 2:
        dims = list(range(1, nd)) or [0]
        big = max(dims, key=lambda i: shape[i])
        if _fits(mesh, shape[big], zero):
            entries = [None] * nd
            entries[big] = zero
            return P(*entries)
    return P()


def param_shardings(abstract_params, mesh: Mesh, pol: ShardPolicy):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, _leaf_spec(path, leaf, mesh, pol)),
        abstract_params)


def opt_shardings(abstract_opt, mesh: Mesh, pol: ShardPolicy):
    """Optimizer state mirrors the parameter shardings; step is replicated."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh,
            P() if _path_has(path, "step") else _leaf_spec(path[1:], leaf, mesh, pol)),
        abstract_opt)


def _path_has(path, key: str) -> bool:
    for k in path:
        if getattr(k, "key", None) == key:
            return True
    return False


def batch_shardings(abstract_batch, mesh: Mesh,
                    pol: Optional[ShardPolicy] = None):
    """Shard every leading batch dimension over the batch axes.

    When the mesh carries a ``seq`` axis and the policy prescribes
    ring-attention sequence parallelism (``pol.sp_degree > 1``), dim 1 —
    the token dimension of ``(B, S, ...)`` batches — additionally shards
    over ``seq``, so each device materialises only its ``S / sp`` token
    panel (the plan's activation-memory ÷ sp_degree claim).

    With ``pol.ep_degree > 1`` and an ``expert`` mesh axis, the batch dim
    additionally co-shards over ``expert`` — expert parallelism acts as
    data parallelism for the non-expert compute, matching the x_spec the
    MoE all-to-all path (models/moe.py::_moe_ep) shard_maps with."""
    bt = batch_axes(mesh)
    if (pol is not None and pol.ep_degree > 1
            and "expert" in mesh.axis_names):
        bt = bt + ("expert",)
    seq = ("seq" if (pol is not None and pol.sp_degree > 1
                     and "seq" in mesh.axis_names) else None)

    def leaf(path, x):
        entries = [None] * x.ndim
        if x.ndim >= 1 and bt and x.shape[0] % _axis_size(mesh, bt) == 0:
            entries[0] = bt
        if seq and x.ndim >= 2 and x.shape[1] % _axis_size(mesh, seq) == 0:
            entries[1] = seq
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(leaf, abstract_batch)


def paged_state_shardings(abstract_pools, mesh: Mesh, pol: ShardPolicy):
    """KV page pools (L, N, psz, KV, dh): TP shards the KV-head dim over
    ``model`` (head-parallel decode).  The page dimension stays replicated
    across the batch axes — pages are shared by every lane, so any data
    shard must be able to gather any pool row."""
    model = "model" if ("model" in mesh.axis_names and pol.tp) else None

    def leaf(path, x):
        names = [getattr(k, "key", None) for k in path
                 if getattr(k, "key", None)]
        name = names[-1] if names else ""
        nd = x.ndim
        if name in ("k", "v") and nd >= 4:
            entries = [None] * nd
            kv_dim = nd - 2
            if model and x.shape[kv_dim] % _axis_size(mesh, model) == 0:
                entries[kv_dim] = model
            return NamedSharding(mesh, P(*entries))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf, abstract_pools)


def decode_state_shardings(abstract_state, mesh: Mesh, pol: ShardPolicy):
    """KV caches: batch over data axes; context (or SSM heads) over model."""
    bt = batch_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None

    def leaf(path, x):
        names = [getattr(k, "key", None) for k in path if getattr(k, "key", None)]
        name = names[-1] if names else ""
        nd = x.ndim
        if name in ("k", "v") and nd >= 4:
            # (L, B, C, KV, dh) stacked or (B, C, KV, dh) single
            off = nd - 4
            entries = [None] * nd
            if bt and x.shape[off] % _axis_size(mesh, bt) == 0:
                entries[off] = bt
            if (pol.shard_cache_seq and model
                    and x.shape[off + 1] % _axis_size(mesh, model) == 0):
                entries[off + 1] = model
            elif model and x.shape[off + 2] % _axis_size(mesh, model) == 0:
                entries[off + 2] = model
            return NamedSharding(mesh, P(*entries))
        if name == "ssm" and nd >= 4:
            off = nd - 4
            entries = [None] * nd
            if bt and x.shape[off] % _axis_size(mesh, bt) == 0:
                entries[off] = bt
            if model and x.shape[off + 1] % _axis_size(mesh, model) == 0:
                entries[off + 1] = model
            return NamedSharding(mesh, P(*entries))
        if name == "conv" and nd >= 3:
            off = nd - 3
            entries = [None] * nd
            if bt and x.shape[off] % _axis_size(mesh, bt) == 0:
                entries[off] = bt
            return NamedSharding(mesh, P(*entries))
        if name == "cross_kv" or (nd >= 2 and name not in ("index",)):
            entries = [None] * nd
            off = 1 if nd >= 2 and x.shape[0] < 256 else 0   # stacked-L heuristic
            if bt and nd > off and x.shape[off] % _axis_size(mesh, bt) == 0:
                entries[off] = bt
            return NamedSharding(mesh, P(*entries))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf, abstract_state)
