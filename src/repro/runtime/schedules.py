"""Pipeline-schedule subsystem (DESIGN.md §5).

A *schedule* is compiled ahead of time into a per-tick **program table**:
for every tick ``t`` and pipeline stage ``i`` the table says which
micro-batch to process, which local virtual chunk of layers to run, whether
the slot is real work or a bubble, and whether the tick finishes the last
virtual stage (head + loss).  The runtime (``runtime/pipeline.py``) then
executes *one* generic ``lax.scan`` tick loop for every schedule — the
schedules differ only in data, not in code.

Supported schedules:

  * ``gpipe``             — all ``m`` micro-batches stream through ``P``
    stages; every tick's activations are stashed (GPipe memory, Eq. 5).
  * ``1f1b``              — same tick order (a flush schedule's forward
    order is GPipe's), but the tick body is rematerialized so only the
    per-tick boundary carries are stashed — the 1F1B-flush *memory*
    profile (``P - i`` in-flight sets on stage ``i``, Eq. 9).
  * ``1f1b-interleaved``  — each device owns ``V`` *virtual chunks*;
    global virtual stage ``s = v·P + i`` lives on device ``i`` as chunk
    ``v``.  Micro-batches advance in groups of ``P``, shrinking the
    pipeline bubble from ``(P-1)/m`` to ``(P-1)/(m·V)`` at the price of
    ``V×`` hand-off traffic and deeper warm-up queues.

Tick mapping (one formula covers all three; ``V = 1`` recovers GPipe/1F1B):
virtual stage ``s = v·P + i`` processes micro-batch ``mb = g·P + r``
(group ``g = mb // P``, offset ``r = mb % P``) at tick

    t = i + r + P·(g·V + v)

Consecutive virtual stages always sit one ring hop and one tick apart —
``s → s+1`` is either device ``i → i+1`` (same chunk) or the wrap link
``P-1 → 0`` (chunk ``v → v+1``) — so a single ``ppermute`` over the full
ring moves every in-flight activation between ticks.  Inverting the
mapping per (tick, device): ``k = t - i``, ``r = k mod P``,
``v = (k div P) mod V``, ``g = k div (P·V)`` — unique, so a device never
has two chunks scheduled on the same tick.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

SCHEDULE_NAMES: Tuple[str, ...] = ("gpipe", "1f1b", "1f1b-interleaved")


@dataclasses.dataclass(frozen=True)
class ScheduleProgram:
    """A compiled schedule: per-tick program tables, all shaped (T, P)."""

    name: str
    n_stages: int            # P — pipeline stages (devices on the pipe axis)
    n_chunks: int            # V — virtual chunks per stage (1 unless interleaved)
    n_micro: int             # m — micro-batches per iteration
    n_ticks: int             # T — scan length
    remat: bool              # rematerialize the tick body (1F1B memory profile)
    mb_index: np.ndarray     # (T, P) int32, clipped to [0, m) — micro-batch
    chunk_index: np.ndarray  # (T, P) int32 in [0, V) — local virtual chunk
    valid: np.ndarray        # (T, P) bool — real work (False = bubble slot)
    loss_valid: np.ndarray   # (T, P) bool — tick finishes virtual stage P·V-1

    @property
    def bubble_ticks(self) -> int:
        """Fill+drain ticks beyond the ideal ``m·V``.

        ``P - 1`` for single-chunk schedules and for interleaved programs
        with full micro-batch groups (``m % P == 0``).  A ragged last
        group (``m % P != 0``) leaves extra idle slots, so the optimizer
        only proposes interleaving when ``m`` divides evenly (the analytic
        ``(P-1)/(m·V)`` bubble would otherwise understate this program)."""
        return self.n_ticks - self.n_micro * self.n_chunks

    def __post_init__(self):
        for f in ("mb_index", "chunk_index", "valid", "loss_valid"):
            assert getattr(self, f).shape == (self.n_ticks, self.n_stages), f


def compile_schedule(name: str, n_stages: int, n_micro: int,
                     n_chunks: Optional[int] = None) -> ScheduleProgram:
    """Compile ``name`` into a :class:`ScheduleProgram`.

    ``n_chunks`` (V) is only meaningful for ``1f1b-interleaved`` (default 2
    there); ``gpipe``/``1f1b`` are single-chunk schedules and reject V > 1.
    """
    if name not in SCHEDULE_NAMES:
        raise ValueError(f"unknown schedule {name!r}; "
                         f"expected one of {SCHEDULE_NAMES}")
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    if n_micro < 1:
        raise ValueError(f"n_micro must be >= 1, got {n_micro}")
    if name == "1f1b-interleaved":
        V = 2 if n_chunks is None else int(n_chunks)
        if V < 2:
            raise ValueError(
                f"1f1b-interleaved needs n_chunks >= 2, got {V} "
                "(V=1 is plain 1f1b)")
    else:
        V = 1 if n_chunks is None else int(n_chunks)
        if V != 1:
            raise ValueError(f"schedule {name!r} is single-chunk; "
                             f"got n_chunks={V}")

    P, m = int(n_stages), int(n_micro)
    # last slot: micro-batch m-1 (g = (m-1)//P, r = (m-1)%P) finishing the
    # last virtual stage (i = P-1, v = V-1)
    T = (P - 1) + ((m - 1) % P) + P * (((m - 1) // P) * V + (V - 1)) + 1

    t = np.arange(T, dtype=np.int64)[:, None]          # (T, 1)
    i = np.arange(P, dtype=np.int64)[None, :]          # (1, P)
    k = t - i
    nonneg = k >= 0
    kc = np.maximum(k, 0)
    r = kc % P
    q = kc // P
    v = q % V
    g = q // V
    mb = g * P + r
    valid = nonneg & (mb < m)
    loss_valid = valid & (i == P - 1) & (v == V - 1)
    return ScheduleProgram(
        name=name, n_stages=P, n_chunks=V, n_micro=m, n_ticks=T,
        remat=(name != "gpipe"),
        mb_index=np.clip(mb, 0, m - 1).astype(np.int32),
        chunk_index=v.astype(np.int32),
        valid=valid,
        loss_valid=loss_valid,
    )
