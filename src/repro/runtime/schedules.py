"""Pipeline-schedule subsystem (DESIGN.md §5).

A *schedule* is compiled ahead of time into a per-tick **program table**:
for every tick ``t`` and pipeline stage ``i`` the table says which
micro-batch to process, which local virtual chunk of layers to run, whether
the slot is real work or a bubble, and whether the tick finishes the last
virtual stage (head + loss).  The runtime (``runtime/pipeline.py``) then
executes *one* generic ``lax.scan`` tick loop for every schedule — the
schedules differ only in data, not in code.

Supported schedules:

  * ``gpipe``             — all ``m`` micro-batches stream through ``P``
    stages; every tick's activations are stashed (GPipe memory, Eq. 5).
  * ``1f1b``              — same tick order (a flush schedule's forward
    order is GPipe's), but the tick body is rematerialized so only the
    per-tick boundary carries are stashed — the 1F1B-flush *memory*
    profile (``P - i`` in-flight sets on stage ``i``, Eq. 9).
  * ``1f1b-interleaved``  — each device owns ``V`` *virtual chunks*;
    global virtual stage ``s = v·P + i`` lives on device ``i`` as chunk
    ``v``.  Micro-batches advance in groups of ``P``, shrinking the
    pipeline bubble from ``(P-1)/m`` to ``(P-1)/(m·V)`` at the price of
    ``V×`` hand-off traffic and deeper warm-up queues.
  * ``zb-h1``             — zero-bubble (handcrafted schedule 1, after
    Qi et al., "Zero Bubble Pipeline Parallelism"): the backward pass is
    split into a **B** tick (activation gradient, on the critical path to
    the upstream stage) and a **W** tick (weight gradient, no inter-stage
    dependency).  W ticks are *deferred* and spent filling what would be
    the 1F1B bubble, shrinking it from ``3(P-1)`` to exactly ``P-1``
    unit ticks (the unavoidable warm-up fill) at the price of the
    deferred weight-gradient activation stash — up to
    ``max(1, m - P + 1 + i)`` pending W sets on stage ``i``
    (``docs/schedules.md``).  Compiled as a genuine three-phase table by
    a greedy event simulation (:func:`_compile_zb_h1`); the runtime
    executes its *forward projection*
    (:meth:`ScheduleProgram.forward_program`) — the B ticks are realized
    by autodiff of the rematerialized scan, the W ticks by the
    weight-gradient work XLA schedules in the backward.

Tick mapping (one formula covers all three; ``V = 1`` recovers GPipe/1F1B):
virtual stage ``s = v·P + i`` processes micro-batch ``mb = g·P + r``
(group ``g = mb // P``, offset ``r = mb % P``) at tick

    t = i + r + P·(g·V + v)

Consecutive virtual stages always sit one ring hop and one tick apart —
``s → s+1`` is either device ``i → i+1`` (same chunk) or the wrap link
``P-1 → 0`` (chunk ``v → v+1``) — so a single ``ppermute`` over the full
ring moves every in-flight activation between ticks.  Inverting the
mapping per (tick, device): ``k = t - i``, ``r = k mod P``,
``v = (k div P) mod V``, ``g = k div (P·V)`` — unique, so a device never
has two chunks scheduled on the same tick.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

# single source of truth for the ZB-H1 deferred-W depth: the cost model
# prices it and the greedy compiler below realizes it (re-exported here
# because schedule consumers are runtime-side)
from repro.core.pipeline_balance import zb_w_pending_max  # noqa: F401

SCHEDULE_NAMES: Tuple[str, ...] = ("gpipe", "1f1b", "1f1b-interleaved",
                                   "zb-h1")

# phase codes for three-phase (zero-bubble) program tables
PHASE_F, PHASE_B, PHASE_W = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class ScheduleProgram:
    """A compiled schedule: per-tick program tables, all shaped (T, P)."""

    name: str
    n_stages: int            # P — pipeline stages (devices on the pipe axis)
    n_chunks: int            # V — virtual chunks per stage (1 unless interleaved)
    n_micro: int             # m — micro-batches per iteration
    n_ticks: int             # T — scan length
    remat: bool              # rematerialize the tick body (1F1B memory profile)
    mb_index: np.ndarray     # (T, P) int32, clipped to [0, m) — micro-batch
    chunk_index: np.ndarray  # (T, P) int32 in [0, V) — local virtual chunk
    valid: np.ndarray        # (T, P) bool — real work (False = bubble slot)
    loss_valid: np.ndarray   # (T, P) bool — tick finishes virtual stage P·V-1
    # (T, P) int8 ∈ {PHASE_F, PHASE_B, PHASE_W}, meaningful where ``valid``.
    # Single-phase schedules (gpipe / 1f1b / interleaved) are all-F; only
    # ``zb-h1`` compiles genuine B/W ticks.  ``None`` normalizes to all-F.
    phase: Optional[np.ndarray] = None

    @property
    def is_three_phase(self) -> bool:
        """True when the table carries split-backward (B/W) ticks."""
        return bool((self.phase > PHASE_F).any())

    @property
    def work_ticks_per_stage(self) -> int:
        """Busy ticks a fully-loaded stage runs: ``m·V`` chunk ticks for
        single-phase schedules, ``3·m·V`` (one F, one B, one W per
        micro-batch chunk) for three-phase tables."""
        return self.n_micro * self.n_chunks * (3 if self.is_three_phase else 1)

    @property
    def bubble_ticks(self) -> int:
        """Fill+drain ticks beyond the ideal :attr:`work_ticks_per_stage`.

        ``P - 1`` for single-chunk single-phase schedules and for
        interleaved programs with full micro-batch groups (``m % P == 0``).
        A ragged last group (``m % P != 0``) leaves extra idle slots, so
        the optimizer only proposes interleaving when ``m`` divides evenly
        (the analytic ``(P-1)/(m·V)`` bubble would otherwise understate
        this program).  For ``zb-h1`` the deferred W ticks refill most of
        the drain: the compiled bubble sits near ``P - 1`` three-phase
        unit ticks versus 1F1B's ``3(P-1)`` equivalent."""
        return self.n_ticks - self.work_ticks_per_stage

    @property
    def f_valid(self) -> np.ndarray:
        """(T, P) bool — slots that run the *forward* stage body."""
        return self.valid & (self.phase == PHASE_F)

    def forward_program(self) -> "ScheduleProgram":
        """The forward projection executed by ``runtime/pipeline.py``.

        Single-phase programs are their own forward projection.  For
        three-phase tables, every stage's F slots process micro-batches
        ``0..m-1`` in order (asserted), so the densest forward execution
        is the classic flush diagonal — the same table ``1f1b`` compiles,
        under which every hand-off producer sits exactly one tick and one
        ring hop upstream of its consumer (the single-carry ``ppermute``
        invariant).  The B ticks are realized by autodiff of the
        rematerialized scan and the W ticks by the weight-gradient
        computations XLA places in the backward; their *timing* (what the
        deferred W slots buy on real parallel hardware) is exactly what
        the three-phase table models for the cost model.

        Returns:
          A single-phase :class:`ScheduleProgram` with this program's
          name, ``remat`` and (P, V, m), safe for the generic tick loop.
        """
        if not self.is_three_phase:
            return self
        for i in range(self.n_stages):
            mbs = self.mb_index[self.f_valid[:, i], i]
            assert (mbs == np.arange(self.n_micro)).all(), (
                "three-phase program's F slots are not in flush order; "
                "no dense forward projection exists")
        diag = compile_schedule("1f1b", self.n_stages, self.n_micro)
        return dataclasses.replace(diag, name=self.name, remat=self.remat)

    def __post_init__(self):
        if self.phase is None:
            object.__setattr__(
                self, "phase",
                np.zeros((self.n_ticks, self.n_stages), np.int8))
        for f in ("mb_index", "chunk_index", "valid", "loss_valid", "phase"):
            assert getattr(self, f).shape == (self.n_ticks, self.n_stages), f


def _validate_program(prog: ScheduleProgram) -> ScheduleProgram:
    """Run the static schedule verifier (``repro.analysis``) on a freshly
    compiled table; raise its structured ``DiagnosticError`` on any
    error-severity finding.  Local import: analysis imports this module,
    and validation is opt-in on the hot path."""
    from repro.analysis.schedule_lint import certify_program
    certify_program(prog).raise_if_errors(
        context=f"compile_schedule({prog.name!r}, P={prog.n_stages}, "
                f"m={prog.n_micro}, V={prog.n_chunks})")
    return prog


def compile_schedule(name: str, n_stages: int, n_micro: int,
                     n_chunks: Optional[int] = None, *,
                     validate: bool = False) -> ScheduleProgram:
    """Compile ``name`` into a :class:`ScheduleProgram`.

    Args:
      name: one of :data:`SCHEDULE_NAMES` (``gpipe`` / ``1f1b`` /
        ``1f1b-interleaved`` / ``zb-h1``).
      n_stages: ``P`` — pipeline stages (size of the mesh ``pipe`` axis).
      n_micro: ``m`` — micro-batches per iteration.
      n_chunks: ``V`` — virtual chunks per stage.  Only meaningful for
        ``1f1b-interleaved`` (default 2 there, must be >= 2); every other
        schedule is single-chunk and rejects V > 1.
      validate: run the static schedule verifier on the compiled table
        (happens-before edges, loss coverage, certified liveness vs the
        cost model, bubble pin — the invariants documented in
        ``docs/analysis.md``) and raise
        :class:`repro.analysis.DiagnosticError` on any error finding.
        Off by default: the searcher compiles thousands of tables whose
        shape-level legality the optimizer already guarantees.

    Returns:
      The compiled :class:`ScheduleProgram` — per-tick ``(T, P)`` tables
      the generic ``runtime/pipeline.py`` scan loop replays.

    Raises:
      ValueError: unknown ``name``, non-positive ``n_stages`` /
        ``n_micro``, or an ``n_chunks`` the schedule cannot use; with
        ``validate=True`` also any certification failure (the raised
        ``DiagnosticError`` is a ``ValueError`` carrying the structured
        diagnostics).
    """
    if name not in SCHEDULE_NAMES:
        raise ValueError(f"unknown schedule {name!r}; "
                         f"expected one of {SCHEDULE_NAMES}")
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    if n_micro < 1:
        raise ValueError(f"n_micro must be >= 1, got {n_micro}")
    if name == "zb-h1":
        if n_chunks is not None and int(n_chunks) != 1:
            raise ValueError(f"schedule 'zb-h1' is single-chunk; "
                             f"got n_chunks={n_chunks}")
        prog = _compile_zb_h1(int(n_stages), int(n_micro))
        return _validate_program(prog) if validate else prog
    if name == "1f1b-interleaved":
        V = 2 if n_chunks is None else int(n_chunks)
        if V < 2:
            raise ValueError(
                f"1f1b-interleaved needs n_chunks >= 2, got {V} "
                "(V=1 is plain 1f1b)")
    else:
        V = 1 if n_chunks is None else int(n_chunks)
        if V != 1:
            raise ValueError(f"schedule {name!r} is single-chunk; "
                             f"got n_chunks={V}")

    P, m = int(n_stages), int(n_micro)
    # last slot: micro-batch m-1 (g = (m-1)//P, r = (m-1)%P) finishing the
    # last virtual stage (i = P-1, v = V-1)
    T = (P - 1) + ((m - 1) % P) + P * (((m - 1) // P) * V + (V - 1)) + 1

    t = np.arange(T, dtype=np.int64)[:, None]          # (T, 1)
    i = np.arange(P, dtype=np.int64)[None, :]          # (1, P)
    k = t - i
    nonneg = k >= 0
    kc = np.maximum(k, 0)
    r = kc % P
    q = kc // P
    v = q % V
    g = q // V
    mb = g * P + r
    valid = nonneg & (mb < m)
    loss_valid = valid & (i == P - 1) & (v == V - 1)
    prog = ScheduleProgram(
        name=name, n_stages=P, n_chunks=V, n_micro=m, n_ticks=T,
        remat=(name != "gpipe"),
        mb_index=np.clip(mb, 0, m - 1).astype(np.int32),
        chunk_index=v.astype(np.int32),
        valid=valid,
        loss_valid=loss_valid,
    )
    return _validate_program(prog) if validate else prog


def _compile_zb_h1(P: int, m: int) -> ScheduleProgram:
    """Greedy event simulation of the ZB-H1 zero-bubble schedule.

    Unit-tick model (``T_F = T_B = T_W``, the handcrafted-schedule
    assumption): each stage picks one action per tick —

      1. the oldest *ready* B (activation gradient; its F is done and the
         downstream stage's B for the same micro-batch has arrived),
      2. else the oldest ready F, subject to the 1F1B in-flight cap
         ``min(P - i, m)`` (the forward-activation stash never exceeds
         the 1F1B-flush profile),
      3. else a deferred W (weight gradient — always runnable once its B
         is done, never on the inter-stage critical path),
      4. else bubble.

    All stages decide simultaneously from the previous ticks' state, so
    every dependency is satisfied strictly earlier than its consumer.
    Deferring W maximally lets the banked W ticks fill every drain stall,
    so the compiled program runs in ``3m + P - 1`` unit ticks for
    ``m >= P`` — bubble exactly ``P - 1``, a third of 1F1B's ``3(P-1)``
    equivalent — at the price of :func:`zb_w_pending_max` deferred
    weight-gradient sets per stage.
    """
    NONE = -1
    f_tick = np.full((P, m), NONE, np.int64)
    b_tick = np.full((P, m), NONE, np.int64)
    w_tick = np.full((P, m), NONE, np.int64)
    f_done = [0] * P
    b_done = [0] * P
    w_done = [0] * P
    rows = []                                   # per tick: [(phase, mb)|None]
    limit = 4 * m + 4 * P + 8                   # safety stop (never hit)
    t = 0
    while min(w_done) < m and t < limit:
        acts = []
        for i in range(P):
            act = None
            j = b_done[i]
            b_ready = (j < m and 0 <= f_tick[i, j] < t
                       and (i == P - 1 or 0 <= b_tick[i + 1, j] < t))
            k = f_done[i]
            f_ready = (k < m
                       and (i == 0 or 0 <= f_tick[i - 1, k] < t)
                       # 1F1B warm-up / in-flight cap
                       and f_done[i] - b_done[i] < min(P - i, m))
            if b_ready:
                act = (PHASE_B, j)
            elif f_ready:
                act = (PHASE_F, k)
            elif b_done[i] - w_done[i] > 0:
                act = (PHASE_W, w_done[i])
            acts.append(act)
        for i, act in enumerate(acts):          # commit simultaneously
            if act is None:
                continue
            phase, mb = act
            (f_tick, b_tick, w_tick)[phase][i, mb] = t
            if phase == PHASE_F:
                f_done[i] += 1
            elif phase == PHASE_B:
                b_done[i] += 1
            else:
                w_done[i] += 1
        rows.append(acts)
        t += 1
    assert min(w_done) == m, "zb-h1 simulation did not converge"

    T = len(rows)
    mb_index = np.zeros((T, P), np.int32)
    chunk_index = np.zeros((T, P), np.int32)
    valid = np.zeros((T, P), bool)
    phase = np.zeros((T, P), np.int8)
    for tt, acts in enumerate(rows):
        for i, act in enumerate(acts):
            if act is None:
                continue
            phase[tt, i] = act[0]
            mb_index[tt, i] = act[1]
            valid[tt, i] = True
    # the executed forward finishes a micro-batch (head + loss) at its
    # last-stage F tick; the B tick on that slot is the loss backward
    loss_valid = valid & (phase == PHASE_F)
    loss_valid[:, :P - 1] = False
    return ScheduleProgram(
        name="zb-h1", n_stages=P, n_chunks=1, n_micro=m, n_ticks=T,
        remat=True, mb_index=mb_index, chunk_index=chunk_index,
        valid=valid, loss_valid=loss_valid, phase=phase)
