"""Plan → executor bridge: derive a concrete mesh execution policy from a
Galvatron-searched ``ParallelPlan``.

The search is layer-granular; the GSPMD executor applies policies per
layer-stack *segment* (scan-over-layers keeps segments homogeneous), so the
bridge reduces each segment's strategies to their dominant choice:

  * TP on the `model` axis iff any layer's plan has tp > 1,
  * ZeRO (SDP) on the batch axes iff the majority of layers use sdp > 1,
  * remat per segment iff the majority of the segment's layers have CKPT,
  * sequence parallelism iff the modeled stash exceeds the HBM budget
    (the §Perf policy rule),
  * ring-attention SP degree copied verbatim from ``plan.sp_degree``
    (the searched axis, format v4) — the executor shards token dims over
    the mesh's ``seq`` axis and runs the ring kernel via
    runtime/sequence.py,
  * expert-parallel degree copied verbatim from ``plan.ep_degree``
    (the searched axis, format v5) — expert weights shard over the mesh's
    ``expert`` axis and MoE dispatch runs the all-to-all path
    (models/moe.py::_moe_ep).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.layerspec import LayerSpec
from repro.core.plan import ParallelPlan
from repro.models.common import ModelConfig
from repro.models.transformer import build_stacks
from repro.roofline.analysis import modeled_memory
from repro.runtime.schedules import ScheduleProgram, compile_schedule
from repro.runtime.sharding import ShardPolicy


def _segment_bounds(cfg: ModelConfig) -> List[int]:
    sizes = [n for _, n in build_stacks(cfg)]
    return sizes


def policy_from_plan(cfg: ModelConfig, plan: ParallelPlan, *,
                     specs: Optional[Sequence[LayerSpec]] = None,
                     seq_len: int = 4096, chips: int = 256,
                     hbm_capacity: float = 16e9) -> ShardPolicy:
    strategies = plan.strategies
    # body layers only (embed/head specs may pad the plan at either end)
    n_body = cfg.n_layers
    if len(strategies) > n_body:
        off = (len(strategies) - n_body) // 2
        strategies = strategies[off:off + n_body]

    tp = any(s.tp > 1 for s in strategies)
    zero = sum(s.sdp > 1 for s in strategies) * 2 >= len(strategies)

    remat: List[bool] = []
    i = 0
    for seg in _segment_bounds(cfg):
        seg_s = strategies[i:i + seg] or strategies[-1:]
        remat.append(sum(s.ckpt for s in seg_s) * 2 >= len(seg_s))
        i += seg

    seq_shard = False
    if specs is not None:
        mm = modeled_memory(
            list(specs), mode="train", chips=chips, tp=16, data_shards=16,
            remat=any(remat), batch=plan.global_batch,
            hbm_capacity=hbm_capacity)
        seq_shard = not mm.fits      # §Perf rule: only when stash overflows
    ep = plan.ep_degree
    return ShardPolicy(tp=tp, zero=zero, remat_segments=tuple(remat),
                       seq_shard=seq_shard, sp_degree=plan.sp_degree,
                       ep_degree=ep,
                       expert_axis="expert" if ep > 1 else "model")


def schedule_program_from_plan(plan: ParallelPlan, *,
                               validate: bool = False) -> ScheduleProgram:
    """Compile the plan's searched (schedule, pp_degree, n_micro,
    vpp_degree) into the tick program the pipeline runtime executes.

    Three-phase plans (``schedule="zb-h1"``) compile to the full F/B/W
    table; the executor runs its forward projection (see
    ``runtime/pipeline.py::make_pipeline_loss_from_program``).

    An uncompilable (schedule, P, m, V) combo raises a structured
    :class:`repro.analysis.DiagnosticError` naming the offending plan
    field (rule ``PLN004``) instead of leaking ``compile_schedule``'s
    bare ``ValueError``; ``validate=True`` additionally runs the full
    schedule verifier on the compiled table."""
    from repro.analysis.diagnostics import DiagnosticError, error
    try:
        return compile_schedule(plan.schedule, plan.pp_degree, plan.n_micro,
                                plan.vpp_degree, validate=validate)
    except DiagnosticError:
        raise
    except ValueError as e:
        raise DiagnosticError([error(
            "PLN004", "plan.schedule",
            f"plan prescribes an uncompilable schedule combo "
            f"(schedule={plan.schedule!r}, pp_degree={plan.pp_degree}, "
            f"n_micro={plan.n_micro}, vpp_degree={plan.vpp_degree}): {e}",
            "run `python -m repro.analysis --plan <file>` for the full "
            "verdict")], context="schedule_program_from_plan") from e


def pipeline_loss_from_plan(cfg: ModelConfig, mesh, plan: ParallelPlan):
    """shard_map pipeline loss executing the plan's searched schedule.

    The mesh's ``pipe`` axis size must equal ``plan.pp_degree`` (the
    program tables are compiled for exactly that stage count); a mismatch
    raises a structured diagnostic (rule ``PLN006``) up front rather than
    a shape error from deep inside ``shard_map``."""
    from repro.runtime.pipeline import make_pipeline_loss_from_program
    n_pipe = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pipe", 1)
    if n_pipe != plan.pp_degree:
        from repro.analysis.diagnostics import DiagnosticError, error
        raise DiagnosticError([error(
            "PLN006", "plan.pp_degree",
            f"plan was searched for pp_degree={plan.pp_degree} but the "
            f"mesh's 'pipe' axis has {n_pipe} device(s)",
            "build the mesh with make_pipeline_mesh(n_stages="
            f"{plan.pp_degree}, ...) or re-search for this cluster")],
            context="pipeline_loss_from_plan")
    prog = schedule_program_from_plan(plan)
    return make_pipeline_loss_from_program(cfg, mesh, prog)
