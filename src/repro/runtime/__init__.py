from .executor import (BuiltStep, abstract_decode_state, abstract_opt_state,
                       abstract_paged_state, abstract_params,
                       init_train_state, make_paged_decode_step,
                       make_paged_prefill_step, make_prefill_step,
                       make_serve_step, make_train_step)
from .pipeline import (make_pipeline_loss, make_pipeline_loss_from_program,
                       stage_split_params)
from .schedules import (PHASE_B, PHASE_F, PHASE_W, SCHEDULE_NAMES,
                        ScheduleProgram, compile_schedule, zb_w_pending_max)
from .sequence import ring_attention_on_mesh, seq_axis_size
from .sharding import (ShardPolicy, batch_shardings, decode_state_shardings,
                       opt_shardings, paged_state_shardings, param_shardings)

__all__ = ["BuiltStep", "PHASE_B", "PHASE_F", "PHASE_W", "SCHEDULE_NAMES",
           "ScheduleProgram", "ShardPolicy", "zb_w_pending_max",
           "abstract_decode_state", "abstract_opt_state",
           "abstract_paged_state", "abstract_params",
           "batch_shardings", "compile_schedule", "decode_state_shardings",
           "init_train_state", "make_paged_decode_step",
           "make_paged_prefill_step", "make_pipeline_loss",
           "make_pipeline_loss_from_program", "make_prefill_step",
           "make_serve_step", "make_train_step", "opt_shardings",
           "paged_state_shardings", "param_shardings",
           "ring_attention_on_mesh", "seq_axis_size", "stage_split_params"]
