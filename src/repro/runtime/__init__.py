from .executor import (BuiltStep, abstract_decode_state, abstract_opt_state,
                       abstract_params, init_train_state, make_prefill_step,
                       make_serve_step, make_train_step)
from .sharding import (ShardPolicy, batch_shardings, decode_state_shardings,
                       opt_shardings, param_shardings)

__all__ = ["BuiltStep", "ShardPolicy", "abstract_decode_state",
           "abstract_opt_state", "abstract_params", "batch_shardings",
           "decode_state_shardings", "init_train_state", "make_prefill_step",
           "make_serve_step", "make_train_step", "opt_shardings",
           "param_shardings"]
