"""Plan execution: build sharded, jit-compiled train / prefill / decode
steps for any architecture on any mesh.

``make_train_step`` / ``make_serve_step`` return (fn, in_shardings,
abstract_args) so callers can either run them (examples, tests) or
``.lower().compile()`` them against ShapeDtypeStructs (the multi-pod
dry-run).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import (decode_step, encdec_loss, init_decode_state,
                          init_encdec, init_encdec_decode_state, init_lm,
                          init_paged_state, lm_loss, paged_decode_step,
                          paged_prefill_step)
from repro.models.common import ModelConfig
from repro.models.flags import batch_sharding
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime.sharding import (ShardPolicy, batch_shardings,
                                    decode_state_shardings, opt_shardings,
                                    paged_state_shardings, param_shardings)


# --------------------------------------------------------------------------
# abstract state builders (no allocation — safe at any scale)
# --------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    if cfg.is_encoder_decoder:
        return jax.eval_shape(lambda k: init_encdec(k, cfg), key)
    return jax.eval_shape(lambda k: init_lm(k, cfg), key)


def abstract_opt_state(aparams, opt_cfg: Optional[AdamWConfig] = None):
    return jax.eval_shape(lambda p: adamw_init(p, opt_cfg), aparams)


def abstract_decode_state(cfg: ModelConfig, batch: int, context: int,
                          aparams=None):
    if cfg.is_encoder_decoder:
        frames = jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model),
                                      jnp.float32)
        return jax.eval_shape(
            lambda p, f: init_encdec_decode_state(p, f, cfg, context),
            aparams, frames)
    return jax.eval_shape(lambda: init_decode_state(cfg, batch, context))


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------

@dataclasses.dataclass
class BuiltStep:
    fn: Callable                        # jit-wrapped
    abstract_args: Tuple[Any, ...]      # ShapeDtypeStructs for lowering
    in_shardings: Tuple[Any, ...]


def loss_fn_for(cfg: ModelConfig, policy: ShardPolicy):
    remat = list(policy.remat_segments) if policy.remat_segments else None
    if cfg.is_encoder_decoder:
        return functools.partial(encdec_loss, cfg=cfg,
                                 remat=bool(remat and remat[0]))
    return functools.partial(lm_loss, cfg=cfg, remat_segments=remat)


def make_train_step(cfg: ModelConfig, mesh: Mesh, policy: ShardPolicy,
                    batch_abstract: Dict[str, jax.ShapeDtypeStruct],
                    opt_cfg: Optional[AdamWConfig] = None) -> BuiltStep:
    opt_cfg = opt_cfg or AdamWConfig()
    aparams = abstract_params(cfg)
    aopt = abstract_opt_state(aparams, opt_cfg)
    loss_fn = loss_fn_for(cfg, policy)

    from repro.runtime.sharding import batch_axes as _bt

    seq_ax = ("model" if (policy.seq_shard and "model" in mesh.axis_names)
              else None)
    seq_sz = mesh.shape.get("model", 1) if seq_ax else 1

    def train_step(params, opt_state, batch):
        with batch_sharding(_bt(mesh), seq_axis=seq_ax, seq_axis_size=seq_sz,
                            mesh=mesh):
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch))(params)
        new_params, new_opt, metrics = adamw_update(params, grads, opt_state,
                                                    opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    ps = param_shardings(aparams, mesh, policy)
    os_ = opt_shardings(aopt, mesh, policy)
    bs = batch_shardings(batch_abstract, mesh)
    rep = NamedSharding(mesh, P())
    fn = jax.jit(train_step,
                 in_shardings=(ps, os_, bs),
                 out_shardings=(ps, os_, rep),
                 donate_argnums=(0, 1))
    return BuiltStep(fn=fn, abstract_args=(aparams, aopt, batch_abstract),
                     in_shardings=(ps, os_, bs))


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, policy: ShardPolicy,
                      batch_abstract) -> BuiltStep:
    """Inference forward (loss-free) over a long prompt."""
    aparams = abstract_params(cfg)

    if cfg.is_encoder_decoder:
        from repro.models import encode
        from repro.models.encdec import decode_train

        def prefill(params, batch):
            from repro.runtime.sharding import batch_axes as _bt
            with batch_sharding(_bt(mesh), mesh=mesh):
                enc = encode(params, batch["frames"], cfg)
                return decode_train(params, batch["tokens"], enc, cfg)
    else:
        from repro.models import lm_forward

        def prefill(params, batch):
            from repro.runtime.sharding import batch_axes as _bt
            with batch_sharding(_bt(mesh), mesh=mesh):
                logits, _ = lm_forward(params, batch["tokens"], cfg,
                                       patches=batch.get("patches"))
            return logits

    ps = param_shardings(aparams, mesh, policy)
    bs = batch_shardings(batch_abstract, mesh)
    fn = jax.jit(prefill, in_shardings=(ps, bs))
    return BuiltStep(fn=fn, abstract_args=(aparams, batch_abstract),
                     in_shardings=(ps, bs))


def make_serve_step(cfg: ModelConfig, mesh: Mesh, policy: ShardPolicy,
                    batch: int, context: int) -> BuiltStep:
    """One-token decode step with KV/SSM state."""
    aparams = abstract_params(cfg)
    astate = abstract_decode_state(cfg, batch, context, aparams)
    atoken = jax.ShapeDtypeStruct((batch,), jnp.int32)

    if cfg.is_encoder_decoder:
        from repro.models import encdec_decode_step as _step
    else:
        _step = functools.partial(decode_step)

    def serve_step(params, state, token):
        return _step(params, state, token, cfg)

    ps = param_shardings(aparams, mesh, policy)
    ss = decode_state_shardings(astate, mesh, policy)
    bt = batch_shardings({"t": atoken}, mesh)["t"]
    fn = jax.jit(serve_step, in_shardings=(ps, ss, bt),
                 donate_argnums=(1,))
    return BuiltStep(fn=fn, abstract_args=(aparams, astate, atoken),
                     in_shardings=(ps, ss, bt))


def abstract_paged_state(cfg: ModelConfig, n_pages: int, page_size: int):
    return jax.eval_shape(lambda: init_paged_state(cfg, n_pages, page_size))


def make_paged_decode_step(cfg: ModelConfig, mesh: Mesh, policy: ShardPolicy,
                           n_slots: int, n_pages: int, page_size: int,
                           pages_per_slot: int) -> BuiltStep:
    """One-token decode over the shared KV page pools (serving engine).

    Signature of the built fn:
    ``(params, pools, token (B,), page_rows (B,P), lengths (B,))``
    -> ``(logits (B,V), new_pools)`` with the pools donated."""
    aparams = abstract_params(cfg)
    apools = abstract_paged_state(cfg, n_pages, page_size)
    atoken = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    arows = jax.ShapeDtypeStruct((n_slots, pages_per_slot), jnp.int32)
    alens = jax.ShapeDtypeStruct((n_slots,), jnp.int32)

    def step(params, pools, token, page_rows, lengths):
        return paged_decode_step(params, pools, token, page_rows, lengths,
                                 cfg)

    ps = param_shardings(aparams, mesh, policy)
    pls = paged_state_shardings(apools, mesh, policy)
    rep = NamedSharding(mesh, P())
    fn = jax.jit(step, in_shardings=(ps, pls, rep, rep, rep),
                 donate_argnums=(1,))
    return BuiltStep(fn=fn,
                     abstract_args=(aparams, apools, atoken, arows, alens),
                     in_shardings=(ps, pls, rep, rep, rep))


def make_paged_prefill_step(cfg: ModelConfig, mesh: Mesh, policy: ShardPolicy,
                            prefill_batch: int, prefill_chunk: int,
                            n_pages: int, page_size: int,
                            pages_per_slot: int) -> BuiltStep:
    """Chunked prefill filling the KV page pools (serving engine).

    Signature of the built fn:
    ``(params, pools, tokens (PB,S), page_rows (PB,P), base, prompt_len (PB,))``
    -> ``(last-prompt-position logits (PB,V), new_pools)``; ``base`` is a
    traced scalar so the whole chunk loop reuses one compilation."""
    aparams = abstract_params(cfg)
    apools = abstract_paged_state(cfg, n_pages, page_size)
    atokens = jax.ShapeDtypeStruct((prefill_batch, prefill_chunk), jnp.int32)
    arows = jax.ShapeDtypeStruct((prefill_batch, pages_per_slot), jnp.int32)
    abase = jax.ShapeDtypeStruct((), jnp.int32)
    alens = jax.ShapeDtypeStruct((prefill_batch,), jnp.int32)

    def step(params, pools, tokens, page_rows, base, prompt_len):
        return paged_prefill_step(params, pools, tokens, page_rows, base,
                                  prompt_len, cfg)

    ps = param_shardings(aparams, mesh, policy)
    pls = paged_state_shardings(apools, mesh, policy)
    rep = NamedSharding(mesh, P())
    fn = jax.jit(step, in_shardings=(ps, pls, rep, rep, rep, rep),
                 donate_argnums=(1,))
    return BuiltStep(
        fn=fn,
        abstract_args=(aparams, apools, atokens, arows, abase, alens),
        in_shardings=(ps, pls, rep, rep, rep, rep))


# --------------------------------------------------------------------------
# convenience: fully materialized training state (examples / tests)
# --------------------------------------------------------------------------

def init_train_state(cfg: ModelConfig, mesh: Mesh, policy: ShardPolicy,
                     seed: int = 0):
    key = jax.random.PRNGKey(seed)
    init = (init_encdec if cfg.is_encoder_decoder else init_lm)
    aparams = abstract_params(cfg)
    ps = param_shardings(aparams, mesh, policy)
    params = jax.jit(lambda k: init(k, cfg), out_shardings=ps)(key)
    aopt = jax.eval_shape(adamw_init, aparams)
    os_ = opt_shardings(aopt, mesh, policy)
    opt_state = jax.jit(adamw_init, out_shardings=os_)(params)
    return params, opt_state
