"""Sequence-parallel (ring attention) mesh execution.

Bridges the searched ``sp_degree`` to actual devices: a mesh with a
``seq`` axis shards the token dimension of ``(B, S, H, dh)`` activations,
and :func:`ring_attention_on_mesh` wraps the ring kernel
(``kernels/ring_attention.py``) in ``shard_map`` so K/V panels rotate
around the axis while queries stay resident.  Per-device activation
memory drops by ``sp_degree`` — the axis the long-context search trades
against TP/PP/DP (docs/architecture.md §SP).

The wrapper takes and returns GLOBAL arrays; ``shard_map`` splits them
over ``seq`` and the kernel reconstructs global token positions from
``jax.lax.axis_index``.  Output is token-identical to the single-device
flash kernel (differential-tested in tests/test_ring_attention.py).
"""
from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime.pipeline import shard_map


def seq_axis_size(mesh: Mesh) -> int:
    """Size of the mesh's ``seq`` axis (1 when absent)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("seq", 1)


def ring_attention_on_mesh(mesh: Mesh, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128):
    """Build ``fn(q, k, v) -> out`` running ring attention over ``mesh``.

    ``q``/``k``/``v`` are global ``(B, S, H|KV, dh)`` arrays; S must be
    divisible by the ``seq`` axis size (lint rule PLN011 enforces the
    matching plan-level constraint).  With no ``seq`` axis (or size 1)
    this degrades to the single-device flash kernel.
    """
    from repro.kernels.ops import flash_attention, ring_flash_attention

    sp = seq_axis_size(mesh)
    if sp <= 1:
        def dense(q, k, v):
            return flash_attention(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k)
        return dense

    def local(q, k, v):
        return ring_flash_attention(
            q, k, v, axis_name="seq", axis_size=sp, causal=causal,
            window=window, block_q=block_q, block_k=block_k)

    spec = P(None, "seq", None, None)
    return shard_map(local, mesh, in_specs=(spec, spec, spec),
                     out_specs=spec)
