"""Pipeline-parallel runtime: micro-batch pipelining as a ``shard_map``
over a ``pipe`` mesh axis with ``lax.ppermute`` stage hand-off, composable
with data parallelism on a ``data`` axis.

Takeaway #1 maps this axis onto the slowest interconnect — across pods in
the production mesh.

The *schedule* is pluggable (DESIGN.md §5, docs/schedules.md):
``runtime/schedules.py`` compiles a named schedule (``gpipe`` / ``1f1b``
/ ``1f1b-interleaved`` / ``zb-h1``) into per-tick program tables —
(micro-batch, virtual chunk, validity, loss, phase) per (tick, stage) —
and this module executes whatever program it is handed with one generic
``lax.scan`` tick loop (three-phase zero-bubble tables run through their
forward projection; see ``make_pipeline_loss_from_program``).  Params are
split into ``P × V`` virtual chunks (``stage_split_params``); the
interleaved schedule walks each device through its ``V`` chunks per
micro-batch group.

Hand-off / compute overlap: each tick *first* issues the ring ``ppermute``
on the previous tick's output, *then* runs the stage body — the two have
no data dependency, so XLA schedules the send/recv concurrently with the
compute (the permute of tick ``t`` rides under the compute of tick
``t+1``'s body in the unrolled trace).

Differentiating straight through the pipelined scan gives GPipe autodiff
semantics; the ``1f1b`` family rematerializes the tick body so only the
boundary carries are stashed (the 1F1B-flush memory profile — the cost
model accounts the schedules' time/memory split analytically, Eq. 5/9).
The stage computation runs *locally* per device (pure jnp inside
shard_map), so this runtime composes PP x DP; TP/SDP within a stage are
served by the GSPMD executor path.  Heterogeneous multi-stack models
(zamba2 / whisper) use the executor path only — see DESIGN.md §3.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig
from repro.models.embedding import embed
from repro.models.layers import cross_entropy_loss, rms_norm
from repro.models.transformer import _BLOCK_APPLY, build_stacks
from repro.runtime.schedules import ScheduleProgram, compile_schedule

try:  # JAX >= 0.6
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except (ImportError, TypeError):  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def stage_split_params(params, n_stages: int, n_chunks: int = 1):
    """Reshape every stacked (L, ...) leaf to (P, V, L/(P·V), ...).

    dim0 shards over the pipe axis so each device holds exactly its V
    virtual chunks.  Chunk ``v`` on device ``i`` carries the layers of
    global virtual stage ``v·P + i`` (the interleaved round-robin layer
    placement); with V = 1 this is the plain contiguous stage split.
    """
    stacks = params["stacks"]
    assert len(stacks) == 1, "pipeline runtime requires one homogeneous stack"
    PV = n_stages * n_chunks

    def resh(v):
        L = v.shape[0]
        assert L % PV == 0, (f"{L} layers not divisible by "
                             f"{n_stages} stages x {n_chunks} chunks")
        # (L, ...) -> (V, P, Lc, ...) [virtual stage s = v*P + i -> (v, i)]
        # -> (P, V, Lc, ...) so dim0 is the device (pipe) dim
        out = v.reshape(n_chunks, n_stages, L // PV, *v.shape[1:])
        return out.swapaxes(0, 1)

    out = dict(params)
    out["stacks"] = [jax.tree.map(resh, stacks[0])]
    return out


def pipeline_specs(params_split, mesh: Mesh):
    """Pipe-sharded specs for split params: stage dim over 'pipe'."""
    def leaf_spec(path, v):
        names = [getattr(k, "key", None) for k in path]
        if "stacks" in names:
            return NamedSharding(mesh, P("pipe", *([None] * (v.ndim - 1))))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(leaf_spec, params_split)


def make_pipeline_loss(cfg: ModelConfig, mesh: Mesh, n_micro: int,
                       schedule: str = "gpipe",
                       n_chunks: Optional[int] = None):
    """Returns loss(params_split, batch) running the compiled schedule.

    batch: tokens/labels (m, B_m, S) — micro dim leading, batch dim sharded
    over 'data', replicated over 'pipe'.  ``params_split`` must come from
    ``stage_split_params(params, P, V)`` with the matching (P, V).

    The schedule name selects a :class:`ScheduleProgram` (see
    ``runtime/schedules.py``); the tick loop below is schedule-agnostic —
    it just replays the program tables.
    """
    n_stages = mesh.shape["pipe"]
    prog = compile_schedule(schedule, n_stages, n_micro, n_chunks)
    return make_pipeline_loss_from_program(cfg, mesh, prog)


def make_pipeline_loss_from_program(cfg: ModelConfig, mesh: Mesh,
                                    prog: ScheduleProgram):
    """Generic tick-loop executor for any compiled :class:`ScheduleProgram`.

    Three-phase (zero-bubble) programs are executed through their
    :meth:`~repro.runtime.schedules.ScheduleProgram.forward_program`: the
    scan replays the F ticks on the dense flush diagonal, autodiff of the
    rematerialized tick body realizes the B ticks, and XLA's backward
    placement realizes the deferred W ticks.  The three-phase table's
    tick *timing* is the analytic object the cost model prices
    (``docs/schedules.md``).
    """
    prog = prog.forward_program()
    n_stages = mesh.shape["pipe"]
    assert prog.n_stages == n_stages, (prog.n_stages, n_stages)
    m, V, T = prog.n_micro, prog.n_chunks, prog.n_ticks
    (kind, _), = build_stacks(cfg)
    block = _BLOCK_APPLY[kind]

    def stage_fn(chunk_params, x, positions):
        def body(carry, lp):
            h, _ = block(lp, carry, positions, cfg, window=cfg.sliding_window)
            return h, None
        x, _ = jax.lax.scan(body, x, chunk_params)
        return x

    def local_step(params, tokens, labels):
        # tokens/labels: (m, B_loc, S) local shards
        stage = jax.lax.axis_index("pipe")
        _, B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        stack = jax.tree.map(lambda v: v[0], params["stacks"][0])  # (V, Lc, ...)
        d = cfg.d_model
        # i -> i+1 carries the same-chunk hand-off; the P-1 -> 0 wrap link
        # carries the chunk v -> v+1 hand-off and is only needed when V > 1
        # (with V = 1 stage 0 always starts from the embedding, so a full
        # ring would ship the last stage's output back just to discard it)
        if V > 1:
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        else:
            perm = [(i, i + 1) for i in range(n_stages - 1)]
        mb_tab = jnp.asarray(prog.mb_index)        # (T, P)
        ch_tab = jnp.asarray(prog.chunk_index)     # (T, P)
        loss_tab = jnp.asarray(prog.loss_valid)    # (T, P)

        def tick(carry, t):
            y_prev, acc = carry
            # hand-off overlap: issue the permute on the PREVIOUS tick's
            # output before this tick's stage body — no data dependency, so
            # the collective runs under the compute
            x_recv = jax.lax.ppermute(y_prev, "pipe", perm)
            mb_idx = mb_tab[t, stage]
            chunk = ch_tab[t, stage]
            mb = jax.lax.dynamic_index_in_dim(tokens, mb_idx, 0, False)
            x_emb = embed(params["embed"], mb).astype(cfg.dtype)
            # virtual stage 0 (device 0, chunk 0) starts from the embedding;
            # everyone else consumes the ring hand-off
            first = (stage == 0) & (chunk == 0)
            x_in = jnp.where(first, x_emb, x_recv)
            chunk_stack = jax.tree.map(
                lambda v: jax.lax.dynamic_index_in_dim(v, chunk, 0, False),
                stack)
            y = stage_fn(chunk_stack, x_in, positions)
            # last virtual stage: head + loss for the just-finished mb;
            # bubble slots compute too but their loss is masked out (their
            # outputs are never consumed — every valid slot's producer one
            # tick earlier is itself valid)
            lb = jax.lax.dynamic_index_in_dim(labels, mb_idx, 0, False)
            h = rms_norm(y, params["final_norm"], cfg.norm_eps)
            logits = h @ (params["head"] if "head" in params
                          else params["embed"].T)
            loss_t = cross_entropy_loss(logits, lb)
            acc = acc + jnp.where(loss_tab[t, stage], loss_t, 0.0)
            return (y, acc), None

        y0 = jnp.zeros((B, S, d), cfg.dtype)
        tick_fn = (jax.checkpoint(tick, prevent_cse=False)
                   if prog.remat else tick)
        (_, acc), _ = jax.lax.scan(tick_fn, (y0, jnp.zeros((), jnp.float32)),
                                   jnp.arange(T))
        # NOTE: no collective here — the loss lives on the last stage only.
        # Summing across stages inside the differentiated objective would
        # multiply every gradient by P (the VJP of psum is a psum of the
        # all-ones cotangents); the caller psums the *value* after autodiff.
        return acc / m

    def loss_and_grads(params_split, batch):
        def inner(params, tokens, labels):
            loss_local, grads = jax.value_and_grad(
                lambda p: local_step(p, tokens, labels))(params)
            loss = jax.lax.psum(loss_local, "pipe")   # value: last stage only
            # pipe-replicated params (embed/head/final_norm) get gradient
            # contributions from different stages -> sum them; stack grads
            # stay local to their stage.
            grads = {k: (v if k == "stacks"
                         else jax.lax.psum(v, "pipe"))
                     for k, v in grads.items()}
            # DP gradient sync
            if "data" in mesh.axis_names:
                grads = jax.lax.pmean(grads, "data")
                loss = jax.lax.pmean(loss, "data")
            return loss, grads

        pspecs = pipeline_specs(params_split, mesh)
        pspec_tree = jax.tree.map(lambda s: s.spec, pspecs)
        data_axes = tuple(a for a in ("data",) if a in mesh.axis_names)
        tok_spec = P(None, data_axes if data_axes else None, None)
        fn = shard_map(inner, mesh,
                       in_specs=(pspec_tree, tok_spec, tok_spec),
                       out_specs=(P(), pspec_tree))
        return fn(params_split, batch["tokens"], batch["labels"])

    return loss_and_grads
