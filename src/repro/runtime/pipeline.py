"""Pipeline-parallel runtime: GPipe-style micro-batch pipelining as a
``shard_map`` over a ``pipe`` mesh axis with ``lax.ppermute`` stage
hand-off, composable with data parallelism on a ``data`` axis.

Takeaway #1 maps this axis onto the slowest interconnect — across pods in
the production mesh.  Differentiating straight through the pipelined scan
gives GPipe semantics (all in-flight activations stashed); the cost model
accounts 1F1B separately (§IV-B).

The stage computation runs *locally* per device (pure jnp inside
shard_map), so this runtime composes PP x DP; TP/SDP within a stage are
served by the GSPMD executor path.  Heterogeneous multi-stack models
(zamba2 / whisper) use the executor path only — see DESIGN.md.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig
from repro.models.embedding import embed
from repro.models.layers import cross_entropy_loss, rms_norm
from repro.models.transformer import _BLOCK_APPLY, build_stacks

try:  # JAX >= 0.6
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except (ImportError, TypeError):  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def stage_split_params(params, n_stages: int):
    """Reshape every stacked (L, ...) leaf to (P, L/P, ...): dim0 shards
    over the pipe axis so each device holds exactly its stage's layers."""
    stacks = params["stacks"]
    assert len(stacks) == 1, "pipeline runtime requires one homogeneous stack"

    def resh(v):
        L = v.shape[0]
        assert L % n_stages == 0, f"{L} layers not divisible by {n_stages} stages"
        return v.reshape(n_stages, L // n_stages, *v.shape[1:])

    out = dict(params)
    out["stacks"] = [jax.tree.map(resh, stacks[0])]
    return out


def pipeline_specs(params_split, mesh: Mesh):
    """Pipe-sharded specs for split params: stage dim over 'pipe'."""
    def leaf_spec(path, v):
        names = [getattr(k, "key", None) for k in path]
        if "stacks" in names:
            return NamedSharding(mesh, P("pipe", *([None] * (v.ndim - 1))))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(leaf_spec, params_split)


def make_pipeline_loss(cfg: ModelConfig, mesh: Mesh, n_micro: int,
                       schedule: str = "gpipe"):
    """Returns loss(params_split, batch) running the pipelined schedule.

    batch: tokens/labels (m, B_m, S) — micro dim leading, batch dim sharded
    over 'data', replicated over 'pipe'.

    ``schedule="gpipe"`` stashes every tick's activations (GPipe memory);
    ``schedule="1f1b"`` rematerializes the tick body, so only the per-tick
    boundary carries are stashed — the 1F1B-flush *memory* profile (stash
    ∝ boundary × ticks instead of full layer activations × ticks).  The
    compute result is identical either way; the cost model accounts the
    schedules' time/memory difference analytically (Eq. 5/9).
    """
    n_stages = mesh.shape["pipe"]
    (kind, _), = build_stacks(cfg)
    block = _BLOCK_APPLY[kind]

    def stage_fn(stack_params, x, positions):
        def body(carry, lp):
            h, _ = block(lp, carry, positions, cfg, window=cfg.sliding_window)
            return h, None
        x, _ = jax.lax.scan(body, x, stack_params)
        return x

    def local_step(params, tokens, labels):
        # tokens/labels: (m, B_loc, S) local shards
        stage = jax.lax.axis_index("pipe")
        m, B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        stack = jax.tree.map(lambda v: v[0], params["stacks"][0])  # (Lp, ...)
        d = cfg.d_model
        T = m + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            y_prev, acc = carry
            x_recv = jax.lax.ppermute(y_prev, "pipe", perm)
            mb_idx = jnp.clip(t, 0, m - 1)
            mb = jax.lax.dynamic_index_in_dim(tokens, mb_idx, 0, False)
            x_emb = embed(params["embed"], mb).astype(cfg.dtype)
            x_in = jnp.where(stage == 0, x_emb, x_recv)
            y = stage_fn(stack, x_in, positions)
            # final stage: head + loss for micro-batch t - (P-1)
            lb_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            lb = jax.lax.dynamic_index_in_dim(labels, lb_idx, 0, False)
            h = rms_norm(y, params["final_norm"], cfg.norm_eps)
            logits = h @ (params["head"] if "head" in params
                          else params["embed"].T)
            loss_t = cross_entropy_loss(logits, lb)
            is_last = stage == n_stages - 1
            valid = (t >= n_stages - 1) & is_last
            acc = acc + jnp.where(valid, loss_t, 0.0)
            return (y, acc), None

        y0 = jnp.zeros((B, S, d), cfg.dtype)
        tick_fn = (jax.checkpoint(tick, prevent_cse=False)
                   if schedule == "1f1b" else tick)
        (_, acc), _ = jax.lax.scan(tick_fn, (y0, jnp.zeros((), jnp.float32)),
                                   jnp.arange(T))
        # NOTE: no collective here — the loss lives on the last stage only.
        # Summing across stages inside the differentiated objective would
        # multiply every gradient by P (the VJP of psum is a psum of the
        # all-ones cotangents); the caller psums the *value* after autodiff.
        return acc / m

    def loss_and_grads(params_split, batch):
        def inner(params, tokens, labels):
            loss_local, grads = jax.value_and_grad(
                lambda p: local_step(p, tokens, labels))(params)
            loss = jax.lax.psum(loss_local, "pipe")   # value: last stage only
            # pipe-replicated params (embed/head/final_norm) get gradient
            # contributions from different stages -> sum them; stack grads
            # stay local to their stage.
            grads = {k: (v if k == "stacks"
                         else jax.lax.psum(v, "pipe"))
                     for k, v in grads.items()}
            # DP gradient sync
            if "data" in mesh.axis_names:
                grads = jax.lax.pmean(grads, "data")
                loss = jax.lax.pmean(loss, "data")
            return loss, grads

        pspecs = pipeline_specs(params_split, mesh)
        pspec_tree = jax.tree.map(lambda s: s.spec, pspecs)
        data_axes = tuple(a for a in ("data",) if a in mesh.axis_names)
        tok_spec = P(None, data_axes if data_axes else None, None)
        fn = shard_map(inner, mesh,
                       in_specs=(pspec_tree, tok_spec, tok_spec),
                       out_specs=(P(), pspec_tree))
        return fn(params_split, batch["tokens"], batch["labels"])

    return loss_and_grads
