from .analysis import (RooflineReport, collective_bytes_from_hlo,
                       model_flops, roofline_report)

__all__ = ["RooflineReport", "collective_bytes_from_hlo", "model_flops",
           "roofline_report"]
