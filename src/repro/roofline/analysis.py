"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs    / (chips x peak_FLOP/s)
    memory term     = HLO_bytes    / (chips x HBM_bw)
    collective term = coll_bytes   / (chips x link_bw)

``cost_analysis()`` of a GSPMD-partitioned module reports *per-device*
numbers; we rescale to global (x chips) so the formulas above apply as
written.  Collective bytes are not in cost_analysis — we parse the
optimized HLO and sum the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e constants (task spec)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (HW has multiple links;
                             # we charge one link's worth — conservative)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.3 = bf16[16,512,128]{...} all-gather(...)
_SHAPE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\b(" +
    "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n * b)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-opcode result bytes of collectives in the (per-device) module."""
    out: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for m in _SHAPE_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        # "-done" ops repeat the "-start" shape; count each pair once
        if "-done(" in m.group(0):
            continue
        out[op] += _shape_bytes(dtype, dims)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # global quantities
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    per_op_collectives: Dict[str, float]
    model_flops: float
    # terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "per_op_collectives": self.per_op_collectives,
        }


def model_flops(param_count: float, tokens: float, *, active_params:
                Optional[float] = None, train: bool = True) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); 2*N*D for inference."""
    n = active_params if active_params is not None else param_count
    return (6.0 if train else 2.0) * n * tokens


def roofline_report(*, arch: str, shape: str, mesh_name: str, chips: int,
                    cost_analysis: Dict[str, float], hlo_text: str,
                    model_flops_global: float) -> RooflineReport:
    per_dev_flops = float(cost_analysis.get("flops", 0.0))
    per_dev_bytes = float(cost_analysis.get("bytes accessed", 0.0))
    colls = collective_bytes_from_hlo(hlo_text)
    per_dev_coll = sum(colls.values())

    g_flops = per_dev_flops * chips
    g_bytes = per_dev_bytes * chips
    g_coll = per_dev_coll * chips
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=g_flops, hlo_bytes=g_bytes, collective_bytes=g_coll,
        per_op_collectives=colls, model_flops=model_flops_global,
        t_compute=g_flops / (chips * PEAK_FLOPS),
        t_memory=g_bytes / (chips * HBM_BW),
        t_collective=g_coll / (chips * LINK_BW),
    )


# ---------------------------------------------------------------------------
# modeled HBM traffic + residency (TPU-fused estimate)
# ---------------------------------------------------------------------------
# XLA:CPU's "bytes accessed" counts every unfused op's operands, a gross
# upper bound on TPU HBM traffic after fusion.  The dry-run therefore also
# reports a MODELED memory term from the same analytic layer workloads the
# paper's estimator uses: weights touched per pass, optimizer state traffic,
# and activation stash/reload.  Both numbers appear in EXPERIMENTS.md; the
# bottleneck verdict uses the modeled one.

@dataclasses.dataclass
class MemoryModel:
    traffic_bytes_per_device: float     # HBM bytes moved per step per chip
    resident_bytes_per_device: float    # persistent + peak stash per chip
    fits: bool

    def t_memory(self) -> float:
        return self.traffic_bytes_per_device / HBM_BW


def modeled_memory(specs, *, mode: str, chips: int, tp: int,
                   data_shards: int, remat: bool,
                   batch: int, cache_bytes_total: float = 0.0,
                   hbm_capacity: float = 16e9,
                   seq_shard: int = 1) -> MemoryModel:
    """specs: LayerSpec list (full model).  batch: global batch (sequences);
    cache_bytes_total: global KV/SSM cache bytes (decode modes);
    seq_shard: sequence-parallel factor on the stashed activations
    (Megatron-style; 1 = paper-faithful baseline)."""
    n_params = sum(s.param_count for s in specs)
    n_active = sum(s.active_param_count() for s in specs)
    b_dev = batch / data_shards
    act_dev = sum((s.bnd_bytes_per_sample + s.int_bytes_per_sample)
                  for s in specs) * b_dev / seq_shard
    bnd_dev = sum(s.bnd_bytes_per_sample for s in specs) * b_dev / seq_shard

    w_pass = 2.0 * n_params / tp          # bf16 weights touched, TP-sharded
    opt_dev = 16.0 * n_params / chips     # mixed-precision Adam states
    cache_dev = cache_bytes_total / chips

    if mode == "train":
        # fwd read + bwd (dx, dw) reads + recompute read; opt read+write;
        # activation stash write+read (+ recompute rewrite under remat)
        traffic = 4.0 * w_pass + 2.0 * opt_dev
        traffic += (3.0 * bnd_dev + 2.0 * act_dev) if remat else 2.0 * act_dev
        resident = 2.0 * n_params / chips + opt_dev \
            + (bnd_dev if remat else act_dev)
    elif mode == "prefill":
        traffic = 2.0 * n_active / tp + 2.0 * act_dev
        resident = 2.0 * n_params / tp + act_dev / len(specs)  # one layer live
    else:  # decode
        traffic = 2.0 * n_active / tp + 2.0 * cache_dev
        resident = 2.0 * n_params / tp + cache_dev
    return MemoryModel(
        traffic_bytes_per_device=traffic,
        resident_bytes_per_device=resident,
        fits=resident <= hbm_capacity,
    )
