"""Grouped-query attention with RoPE, optional QK-norm / QKV-bias /
sliding-window masking, KV-cache decode, and a pluggable inner kernel
(pure-jnp reference here; Pallas flash kernel in repro.kernels)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .flags import scan_unroll
from .layers import apply_rope, init_dense, rms_norm

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, *, d_model: Optional[int] = None,
                   cross: bool = False) -> Dict[str, Any]:
    d = d_model or cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, cfg.q_dim, cfg.dtype),
        "wk": init_dense(ks[1], d, cfg.kv_dim, cfg.dtype),
        "wv": init_dense(ks[2], d, cfg.kv_dim, cfg.dtype),
        "wo": init_dense(ks[3], cfg.q_dim, d, cfg.dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.q_dim,), cfg.dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), cfg.dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), cfg.dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.dh,), cfg.dtype)
        p["k_norm"] = jnp.ones((cfg.dh,), cfg.dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions, *, rope: bool = True):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.dh)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.dh)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def sdpa_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
             causal: bool, window: Optional[int] = None,
             q_offset: Any = 0,
             kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Reference grouped-query attention.

    q (B,S,H,dh); k/v (B,T,KV,dh).  ``q_offset`` is the absolute position of
    q[0] (for decode: cache length).  ``kv_len`` masks cache positions >= it.
    """
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, dh)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(S)              # (S,)
    kpos = jnp.arange(T)                         # (T,)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    mask_bt = mask[None, None, None]
    if kv_len is not None:
        valid = kpos[None, :] < jnp.asarray(kv_len).reshape(-1, 1)   # (B,T)
        mask_bt = mask_bt & valid[:, None, None, None, :]
    scores = jnp.where(mask_bt, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, dh).astype(q.dtype)


def sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool, window: Optional[int] = None,
                 block_q: int = 512) -> jax.Array:
    """Memory-efficient attention: q is processed in blocks (scan +
    rematerialized block body), so peak score memory is
    (B, H, block_q, T) instead of (B, H, S, T).  This is the pure-jnp
    analogue of the Pallas flash kernel, used on non-TPU backends and in
    the 512-device dry-runs."""
    B, S, H, dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    while S % bq:
        bq //= 2
    nq = S // bq
    qb = jnp.moveaxis(q.reshape(B, nq, bq, H, dh), 1, 0)     # (nq,B,bq,H,dh)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    # Under sequence parallelism, pin K/V to seq-replicated (batch-sharded
    # only): GSPMD would otherwise re-all-gather them for EVERY q chunk of
    # the rematerialized scan body (64x per layer-pass); one explicit gather
    # is tiny thanks to GQA (kv_dim << q_dim).  Without seq sharding the
    # pin is left off — it perturbs GSPMD's (cheaper) baseline layout.
    from .flags import constrain_batch_only, seq_sharding_active
    if seq_sharding_active():
        kf = constrain_batch_only(k.astype(jnp.float32))
        vf = constrain_batch_only(v.astype(jnp.float32))
    else:
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
    kpos = jnp.arange(T)

    def block(carry, inp):
        i, qc = inp                                          # qc (B,bq,H,dh)
        qg = qc.reshape(B, bq, KV, G, dh).astype(jnp.float32)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kf) * scale
        qpos = i * bq + jnp.arange(bq)
        mask = jnp.ones((bq, T), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgst,btkd->bskgd", pr, vf)
        return carry, o.reshape(B, bq, H, dh).astype(q.dtype)

    _, ob = jax.lax.scan(jax.checkpoint(block, prevent_cse=False),
                         0, (jnp.arange(nq), qb), unroll=scan_unroll(nq))
    return jnp.moveaxis(ob, 0, 1).reshape(B, S, H, dh)


def attention(p, x: jax.Array, positions: jax.Array, cfg: ModelConfig, *,
              causal: bool = True,
              window: Optional[int] = None,
              impl: str = "auto",
              sp_axis: str = "seq", sp_size: int = 1) -> jax.Array:
    """Full-sequence (train / prefill) self-attention.

    ``impl="ring"`` runs sequence-parallel ring attention: x/positions are
    this shard's slice of a sequence split over the ``sp_axis`` mesh axis
    (size ``sp_size``), and the call must sit inside ``shard_map``
    (``runtime/sequence.py``).  ``positions`` must be the shard's absolute
    token positions so RoPE agrees with the single-device kernel.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    if impl == "auto":
        impl = "chunked" if S >= 1024 else "ref"
    if impl == "ring":
        from repro.kernels.ops import ring_flash_attention as _ring
        out = _ring(q, k, v, causal=causal, window=window,
                    axis_name=sp_axis, axis_size=sp_size)
    elif impl == "flash":
        from repro.kernels.ops import flash_attention as _flash
        out = _flash(q, k, v, causal=causal, window=window)
    elif impl == "chunked":
        out = sdpa_chunked(q, k, v, causal=causal, window=window)
    else:
        out = sdpa_ref(q, k, v, causal=causal, window=window)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"]


def attention_decode(p, x: jax.Array, cache: Dict[str, jax.Array],
                     cache_index: jax.Array, cfg: ModelConfig, *,
                     window: Optional[int] = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode with a ring or linear KV cache.

    x (B,1,d).  cache["k"/"v"]: (B, C, KV, dh) with C = max context (full) or
    the sliding window span.  ``cache_index`` — number of tokens already in
    context (absolute position of the new token); a scalar shared by every
    lane, or per-lane ``(B,)`` when lanes sit at different positions (the
    continuous-batching serve path after slot recycling).
    """
    B, S, _ = x.shape
    assert S == 1
    C = cache["k"].shape[1]
    idx = jnp.broadcast_to(jnp.asarray(cache_index, jnp.int32), (B,))
    q, k, v = _project_qkv(p, x, cfg, idx.reshape(B, 1))
    slot = (idx % C).astype(jnp.int32)                      # (B,)
    lane = jnp.arange(B)
    new_k = cache["k"].at[lane, slot].set(k[:, 0].astype(cache["k"].dtype))
    new_v = cache["v"].at[lane, slot].set(v[:, 0].astype(cache["v"].dtype))

    # position stored in each ring slot: the latest p with p % C == slot
    # and p <= cache_index
    kpos = jnp.arange(C)
    idx_c = idx[:, None]                                    # (B,1)
    abs_pos = idx_c - ((idx_c - kpos[None, :]) % C)         # (B,C)
    valid = (abs_pos >= 0) & (abs_pos <= idx_c)   # >=0: slot written
    if window is not None:
        valid &= abs_pos > idx_c - window
    scale = 1.0 / jnp.sqrt(cfg.dh).astype(jnp.float32)
    KV = cfg.n_kv_heads
    G = cfg.n_heads // KV
    qg = q.reshape(B, 1, KV, G, cfg.dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        new_k.astype(jnp.float32)) * scale
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, new_v.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.q_dim).astype(x.dtype) @ p["wo"]
    return out, {"k": new_k, "v": new_v}


def attention_decode_paged(p, x: jax.Array, pool: Dict[str, jax.Array],
                           page_rows: jax.Array, lengths: jax.Array,
                           cfg: ModelConfig, *,
                           window: Optional[int] = None
                           ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode against a paged KV cache.

    x (B,1,d).  pool["k"/"v"]: shared page pools (N, psz, KV, dh) — every
    lane's K/V lives in pool pages, so memory scales with tokens actually
    cached rather than lanes * max-context.  ``page_rows`` (B, P) int32 maps
    each lane's logical page p to a pool row (-1 = unassigned);
    ``lengths`` (B,) is each lane's current context length (the write
    position for the new token).  Inactive lanes signal with a negative
    length: their write is routed out of bounds and dropped.

    The gathered per-lane view is a *linear* cache (position t at row
    t // psz, offset t % psz), so with identical inputs the output matches
    :func:`attention_decode` on a ring cache of span P * psz exactly —
    the paged/dense differential tests rely on this.
    """
    B, S, _ = x.shape
    assert S == 1
    N, psz, KV, dh = pool["k"].shape
    P = page_rows.shape[1]
    L = lengths.astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, jnp.maximum(L, 0).reshape(B, 1))
    # scatter the new token at (page_rows[lane, L // psz], L % psz);
    # unassigned pages / inactive lanes route to row N (out of bounds)
    # and the write is dropped
    pi = jnp.clip(L // psz, 0, P - 1)
    page = jnp.take_along_axis(page_rows, pi[:, None], axis=1)[:, 0]  # (B,)
    page = jnp.where((page < 0) | (L < 0) | (L // psz >= P), N, page)
    off = jnp.clip(L % psz, 0, psz - 1)
    new_k = pool["k"].at[page, off].set(
        k[:, 0].astype(pool["k"].dtype), mode="drop")
    new_v = pool["v"].at[page, off].set(
        v[:, 0].astype(pool["v"].dtype), mode="drop")
    # gather each lane's pages into a linear (B, P*psz, KV, dh) view;
    # unassigned rows gather page 0 (garbage) and are masked below
    rows = jnp.where(page_rows < 0, 0, page_rows)
    gk = new_k[rows].reshape(B, P * psz, KV, dh)
    gv = new_v[rows].reshape(B, P * psz, KV, dh)
    kpos = jnp.arange(P * psz)
    valid = kpos[None, :] <= L[:, None]                     # (B, C)
    if window is not None:
        valid &= kpos[None, :] > L[:, None] - window
    scale = 1.0 / jnp.sqrt(cfg.dh).astype(jnp.float32)
    G = cfg.n_heads // KV
    qg = q.reshape(B, 1, KV, G, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        gk.astype(jnp.float32)) * scale
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, gv.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.q_dim).astype(x.dtype) @ p["wo"]
    return out, {"k": new_k, "v": new_v}


def attention_prefill_paged(p, x: jax.Array, pool: Dict[str, jax.Array],
                            page_rows: jax.Array, base: jax.Array,
                            prompt_len: jax.Array, cfg: ModelConfig, *,
                            window: Optional[int] = None
                            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked-prefill attention that captures K/V into the page pools.

    x (B,S,d): one prompt chunk covering absolute positions
    [base, base + S) for every lane (``base`` may be a traced scalar, so
    one compilation serves the whole chunk loop).  ``prompt_len`` (B,)
    clips per-lane writes and masks shorter prompts; padding lanes use
    ``prompt_len = 0``.  Writes the chunk's K/V into the pools *first*,
    then attends over the gathered pool view, so earlier chunks of the
    same prompt are visible.
    """
    B, S, _ = x.shape
    N, psz, KV, dh = pool["k"].shape
    P = page_rows.shape[1]
    base = jnp.asarray(base, jnp.int32)
    ap = base + jnp.arange(S, dtype=jnp.int32)              # (S,) abs pos
    q, k, v = _project_qkv(p, x, cfg, jnp.broadcast_to(ap, (B, S)))
    pi = jnp.clip(ap // psz, 0, P - 1)                      # (S,)
    page = page_rows[:, pi]                                 # (B,S)
    in_prompt = ap[None, :] < prompt_len[:, None]           # (B,S)
    page = jnp.where((page < 0) | ~in_prompt
                     | (ap[None, :] // psz >= P), N, page)
    off = jnp.broadcast_to(ap % psz, (B, S))
    new_k = pool["k"].at[page, off].set(
        k.astype(pool["k"].dtype), mode="drop")
    new_v = pool["v"].at[page, off].set(
        v.astype(pool["v"].dtype), mode="drop")
    rows = jnp.where(page_rows < 0, 0, page_rows)
    gk = new_k[rows].reshape(B, P * psz, KV, dh)
    gv = new_v[rows].reshape(B, P * psz, KV, dh)
    kv_len = jnp.minimum(prompt_len, base + S)
    out = sdpa_ref(q, gk, gv, causal=True, window=window,
                   q_offset=base, kv_len=kv_len)
    out = out.reshape(B, S, cfg.q_dim) @ p["wo"]
    return out, {"k": new_k, "v": new_v}


def cross_attention(p, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                    cfg: ModelConfig) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (no RoPE)."""
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.dh)
    k, v = enc_kv
    out = sdpa_ref(q, k, v, causal=False)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"]


def precompute_cross_kv(p, enc_out: jax.Array, cfg: ModelConfig):
    B, T, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.dh)
    v = (enc_out @ p["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.dh)
    return k, v


def init_kv_cache(cfg: ModelConfig, batch: int, context: int,
                  *, dtype=None) -> Dict[str, jax.Array]:
    """Cache for one layer; ``context`` = full context or window span."""
    span = context if cfg.sliding_window is None else min(context, cfg.sliding_window)
    dt = dtype or cfg.dtype
    shape = (batch, span, cfg.n_kv_heads, cfg.dh)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def init_page_pool(cfg: ModelConfig, n_pages: int, page_size: int,
                   *, dtype=None) -> Dict[str, jax.Array]:
    """Shared K/V page pool for one layer: (n_pages, page_size, KV, dh)."""
    dt = dtype or cfg.dtype
    shape = (n_pages, page_size, cfg.n_kv_heads, cfg.dh)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
