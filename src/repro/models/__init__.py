"""Pure-JAX model zoo: dense / MoE / SSM / hybrid / encoder-decoder / VLM."""
from .common import INPUT_SHAPES, InputShape, ModelConfig
from .transformer import (decode_step, init_decode_state, init_lm, lm_forward,
                          lm_loss, init_paged_state, paged_decode_step,
                          paged_prefill_step, supports_paged_decode)
from .encdec import (encdec_decode_step, encdec_loss, encode,
                     init_encdec, init_encdec_decode_state)

__all__ = ["INPUT_SHAPES", "InputShape", "ModelConfig", "decode_step",
           "init_decode_state", "init_lm", "lm_forward", "lm_loss",
           "encdec_decode_step", "encdec_loss", "encode", "init_encdec",
           "init_encdec_decode_state", "init_paged_state",
           "paged_decode_step", "paged_prefill_step",
           "supports_paged_decode"]
