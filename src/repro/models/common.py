"""Shared model configuration for every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    # attention variant: None = full causal; int = sliding window span
    sliding_window: Optional[int] = None

    # ---- MoE -------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    shared_expert_ff: int = 0        # always-on shared expert width (Kimi K2)
    dense_residual_ff: int = 0       # dense residual branch width (Arctic)
    first_k_dense: int = 0           # leading dense layers before MoE layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # "sort" (per-group vmap scatter; paper-faithful baseline mapping) or
    # "grouped" (batched dispatch with a data-sharded, expert-replicated
    # buffer — kills the cross-shard buffer all-reduce; see §Perf)
    moe_dispatch: str = "sort"

    # ---- SSM / hybrid ------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_conv: int = 4
    # hybrid: attention block shared weights inserted every k SSM layers
    attn_every: int = 0              # 0 = no interleaved attention
    shared_attention: bool = False

    # ---- encoder-decoder (audio) -------------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    encoder_seq: int = 0             # e.g. 1500 whisper frames
    encoder_causal: bool = False

    # ---- VLM ----------------------------------------------------------------
    vision_tokens: int = 0           # stub patch embeddings prepended
    d_vision: int = 0                # frontend embedding width

    dtype: jnp.dtype = jnp.bfloat16

    # ------------------------------------------------------------------
    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.dh

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.dh

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 1 and i >= self.first_k_dense

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid models: which layer indices run (shared) attention."""
        if self.arch_type not in ("hybrid",):
            return self.arch_type != "ssm"
        return self.attn_every > 0 and (i + 1) % self.attn_every == 0

    def reduced(self, *, n_layers: int = 2, d_model: int = 256,
                n_experts: Optional[int] = None) -> "ModelConfig":
        """Smoke-test variant of the same family (<=2 layers, d<=512, <=4 experts)."""
        d = min(d_model, self.d_model)
        n_heads = max(2, min(self.n_heads, d // 64))
        dh = d // n_heads
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        ne = self.n_experts
        if ne:
            ne = min(n_experts if n_experts is not None else 4, ne)
        changes = dict(
            n_layers=n_layers,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=dh,
            d_ff=min(self.d_ff, 2 * d) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            n_experts=ne,
            top_k=min(self.top_k, max(1, ne // 2)) if ne else 0,
            shared_expert_ff=min(self.shared_expert_ff, d) if self.shared_expert_ff else 0,
            dense_residual_ff=min(self.dense_residual_ff, d) if self.dense_residual_ff else 0,
            first_k_dense=min(self.first_k_dense, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=min(self.ssm_head_dim, dh),
            ssm_chunk=16,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2) if self.n_enc_layers else 0,
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            vision_tokens=min(self.vision_tokens, 16) if self.vision_tokens else 0,
            d_vision=min(self.d_vision, d) if self.d_vision else 0,
            sliding_window=(min(self.sliding_window, 64)
                            if self.sliding_window else None),
        )
        return dataclasses.replace(self, **changes)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
