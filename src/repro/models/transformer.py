"""Decoder-only language models: dense, MoE, SSM, hybrid — built from the
component blocks, stacked with ``lax.scan`` (scan-over-layers keeps the HLO
O(1) in depth, which matters for 512-device GSPMD compiles).

Parameter layout::

  params = {
    "embed":  (V, d),
    "stacks": [ {"params": <stacked block pytree with leading L_i>,
                 "kind": "dense"|"moe"|"ssm", "n": L_i}, ... ],
    "shared_attn": {...}?          # zamba2-style shared block
    "projector": {...}?            # VLM frontend projector
    "final_norm": (d,),
    "head": (d, V)?                # absent when tied
  }

Remat (CKPT) is applied per stack segment when the plan asks for it.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attention, attention_decode, attention_decode_paged,
                        attention_prefill_paged, init_attention,
                        init_kv_cache, init_page_pool)
from .common import ModelConfig
from .flags import constrain_batch, constrain_batch_only, scan_unroll
from .embedding import embed, init_embedding, init_projector, project
from .layers import cross_entropy_loss, init_dense, rms_norm
from .mlp import init_swiglu, swiglu_mlp
from .moe import init_moe, moe_ffn
from .ssm import (init_ssm, init_ssm_state, ssm_block, ssm_block_decode)


# --------------------------------------------------------------------------
# single blocks
# --------------------------------------------------------------------------

def init_dense_block(key, cfg: ModelConfig) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
        "attn": init_attention(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
        "mlp": init_swiglu(k2, cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def dense_block(p, x, positions, cfg: ModelConfig, *,
                window: Optional[int] = None, causal: bool = True):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + attention(p["attn"], h, positions, cfg, causal=causal,
                      window=window)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + swiglu_mlp(p["mlp"], h)
    return x, jnp.zeros((), jnp.float32)


def init_moe_block(key, cfg: ModelConfig) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
        "attn": init_attention(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
        "moe": init_moe(k2, cfg),
    }


def moe_block(p, x, positions, cfg: ModelConfig, *,
              window: Optional[int] = None, causal: bool = True):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + attention(p["attn"], h, positions, cfg, causal=causal,
                      window=window)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, aux = moe_ffn(p["moe"], h, cfg)
    return x + y, aux


def init_ssm_block_p(key, cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
        "ssm": init_ssm(key, cfg),
    }


def ssm_block_outer(p, x, positions, cfg: ModelConfig, **_):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + ssm_block(p["ssm"], h, cfg)
    return x, jnp.zeros((), jnp.float32)


_BLOCK_INIT = {"dense": init_dense_block, "moe": init_moe_block,
               "ssm": init_ssm_block_p}
_BLOCK_APPLY = {"dense": dense_block, "moe": moe_block,
                "ssm": ssm_block_outer}


# --------------------------------------------------------------------------
# stacking
# --------------------------------------------------------------------------

def init_stack(key, cfg: ModelConfig, kind: str, n: int) -> Dict[str, Any]:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _BLOCK_INIT[kind](k, cfg))(keys)


def apply_stack(stack_params, kind, x, positions, cfg: ModelConfig, *,
                remat: bool = False, window: Optional[int] = None,
                causal: bool = True):
    fn = _BLOCK_APPLY[kind]

    def body(carry, layer_params):
        h, aux = carry
        # Sequence parallelism, stash-only: the scan carry (= the remat
        # stash) stays seq-sharded (constrain_batch adds the seq axis when
        # the policy enables it); compute runs on the gathered tensor so
        # GSPMD keeps the baseline head-parallel attention layout.
        h = constrain_batch_only(h)
        h, a = fn(layer_params, h, positions, cfg, window=window,
                  causal=causal)
        return (constrain_batch(h), aux + a), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    n_layers = jax.tree_util.tree_leaves(stack_params)[0].shape[0]
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               stack_params, unroll=scan_unroll(n_layers))
    return x, aux


# --------------------------------------------------------------------------
# whole LM
# --------------------------------------------------------------------------

def build_stacks(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """Sequence of (kind, n_layers) segments for the architecture."""
    if cfg.arch_type == "ssm":
        return [("ssm", cfg.n_layers)]
    if cfg.arch_type == "hybrid":
        # handled layer-by-layer (shared attention interleave)
        return [("ssm", cfg.n_layers)]
    if cfg.n_experts > 1:
        segs = []
        if cfg.first_k_dense:
            segs.append(("dense", cfg.first_k_dense))
        segs.append(("moe", cfg.n_layers - cfg.first_k_dense))
        return segs
    return [("dense", cfg.n_layers)]


def init_lm(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    stacks = []
    for i, (kind, n) in enumerate(build_stacks(cfg)):
        stacks.append(init_stack(ks[1 + i], cfg, kind, n))
    params["stacks"] = stacks
    if cfg.arch_type == "hybrid" and cfg.attn_every:
        params["shared_attn"] = {
            "ln": jnp.ones((cfg.d_model,), cfg.dtype),
            "attn": init_attention(ks[5], cfg),
        }
    if cfg.arch_type == "vlm":
        params["projector"] = init_projector(ks[6], cfg.d_vision, cfg.d_model,
                                             cfg.dtype)
    if not cfg.tie_embeddings:
        params["head"] = init_dense(ks[7], cfg.d_model, cfg.vocab_size,
                                    cfg.dtype)
    return params


def _logits(params, x, cfg: ModelConfig) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if "head" in params:
        return x @ params["head"]
    return x @ params["embed"].T


def _hybrid_forward(params, x, positions, cfg: ModelConfig, *,
                    remat_segments: Optional[List[bool]] = None):
    """Zamba2-style: SSM stack with a weight-shared attention block applied
    every ``attn_every`` layers.  Executed as scans over equal segments."""
    stack_params = params["stacks"][0]
    n = cfg.n_layers
    k = cfg.attn_every or (n + 1)
    aux = jnp.zeros((), jnp.float32)
    sa = params.get("shared_attn")

    def seg_slice(tree, a, b):
        return jax.tree.map(lambda v: v[a:b], tree)

    i = 0
    si = 0
    while i < n:
        j = min(n, i + k)
        seg = seg_slice(stack_params, i, j)
        # remat_segments may be shorter than the segment count (e.g. a
        # single-element policy meaning "all segments"): clamp the index.
        remat = (bool(remat_segments[min(si, len(remat_segments) - 1)])
                 if remat_segments else False)
        x, a = apply_stack(seg, "ssm", x, positions, cfg, remat=remat)
        aux = aux + a
        if sa is not None and (j % k == 0):
            h = rms_norm(x, sa["ln"], cfg.norm_eps)
            x = x + attention(sa["attn"], h, positions, cfg, causal=True,
                              window=cfg.sliding_window)
        i = j
        si += 1
    return x, aux


def lm_forward(params, tokens: jax.Array, cfg: ModelConfig, *,
               patches: Optional[jax.Array] = None,
               remat_segments: Optional[List[bool]] = None,
               window: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """tokens (B,S) -> logits (B,S,V), aux loss.  For VLM, ``patches``
    (B, n_vis, d_vision) are projected and prepended."""
    x = constrain_batch(embed(params["embed"], tokens))
    if cfg.arch_type == "vlm" and patches is not None:
        vis = project(params["projector"], patches.astype(cfg.dtype))
        x = jnp.concatenate([vis, x], axis=1)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    win = window if window is not None else cfg.sliding_window

    if cfg.arch_type == "hybrid":
        x, aux = _hybrid_forward(params, x, positions, cfg,
                                 remat_segments=remat_segments)
    else:
        aux = jnp.zeros((), jnp.float32)
        for si, (kind, _) in enumerate(build_stacks(cfg)):
            remat = (bool(remat_segments[min(si, len(remat_segments) - 1)])
                     if remat_segments else False)
            x, a = apply_stack(params["stacks"][si], kind, x, positions, cfg,
                               remat=remat, window=win)
            aux = aux + a
    if cfg.arch_type == "vlm" and patches is not None:
        x = x[:, patches.shape[1]:]
    return _logits(params, x, cfg), aux


def lm_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig, *,
            remat_segments: Optional[List[bool]] = None) -> jax.Array:
    logits, aux = lm_forward(params, batch["tokens"], cfg,
                             patches=batch.get("patches"),
                             remat_segments=remat_segments)
    loss = cross_entropy_loss(logits, batch["labels"])
    return loss + cfg.router_aux_coef * aux


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, context: int) -> Dict[str, Any]:
    """Per-layer caches, stacked to match the scan layout."""
    state: Dict[str, Any] = {}
    stacks = []
    for kind, n in build_stacks(cfg):
        if kind == "ssm":
            one = init_ssm_state(cfg, batch)
        else:
            one = init_kv_cache(cfg, batch, context)
        stacks.append(jax.tree.map(
            lambda v: jnp.broadcast_to(v, (n,) + v.shape), one))
    state["stacks"] = stacks
    if cfg.arch_type == "hybrid" and cfg.attn_every:
        n_attn = cfg.n_layers // cfg.attn_every
        one = init_kv_cache(cfg, batch, context)
        state["shared_attn"] = [one for _ in range(n_attn)]
    state["index"] = jnp.zeros((), jnp.int32)
    return state


def _decode_block(kind: str):
    def dense_step(p, x, cache, index, cfg, window):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        a, new_cache = attention_decode(p["attn"], h, cache, index, cfg,
                                        window=window)
        x = x + a
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if "mlp" in p:
            x = x + swiglu_mlp(p["mlp"], h)
        else:
            y, _ = moe_ffn(p["moe"], h, cfg)
            x = x + y
        return x, new_cache

    def ssm_step_(p, x, cache, index, cfg, window):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, new_cache = ssm_block_decode(p["ssm"], h, cache, cfg)
        return x + y, new_cache

    return ssm_step_ if kind == "ssm" else dense_step


def decode_step(params, state, token: jax.Array, cfg: ModelConfig, *,
                window: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    """One decode step. token (B,) -> logits (B, V) + new state."""
    x = embed(params["embed"], token)[:, None, :]
    index = state["index"]
    win = window if window is not None else cfg.sliding_window
    new_state = {"index": index + 1, "stacks": []}

    if cfg.arch_type == "hybrid":
        # layer-by-layer python loop with shared-attention interleave
        stack_params = params["stacks"][0]
        cache = state["stacks"][0]
        new_cache = jax.tree.map(lambda v: v, cache)
        sa = params.get("shared_attn")
        sa_caches = list(state.get("shared_attn", []))
        k = cfg.attn_every or (cfg.n_layers + 1)
        ai = 0
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda v: v[i], stack_params)
            lc = jax.tree.map(lambda v: v[i], cache)
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, lc2 = ssm_block_decode(lp["ssm"], h, lc, cfg)
            x = x + y
            new_cache = jax.tree.map(
                lambda full, upd, ii=i: full.at[ii].set(upd), new_cache, lc2)
            if sa is not None and (i + 1) % k == 0 and ai < len(sa_caches):
                h = rms_norm(x, sa["ln"], cfg.norm_eps)
                a, sc = attention_decode(sa["attn"], h, sa_caches[ai], index,
                                         cfg, window=win)
                x = x + a
                sa_caches[ai] = sc
                ai += 1
        new_state["stacks"] = [new_cache]
        new_state["shared_attn"] = sa_caches
    else:
        for (kind, _), stack_params, cstack in zip(
                build_stacks(cfg), params["stacks"], state["stacks"]):
            step = _decode_block(kind)

            def body(carry, inp):
                h = carry
                lp, lc = inp
                h, lc2 = step(lp, h, lc, index, cfg, win)
                return h, lc2

            n_l = jax.tree_util.tree_leaves(stack_params)[0].shape[0]
            x, new_cache = jax.lax.scan(body, x, (stack_params, cstack),
                                        unroll=scan_unroll(n_l))
            new_state["stacks"].append(new_cache)

    logits = _logits(params, x, cfg)[:, 0]
    return logits, new_state


# --------------------------------------------------------------------------
# paged decode (serving engine)
# --------------------------------------------------------------------------

def supports_paged_decode(cfg: ModelConfig) -> bool:
    """Paged serving covers pure-attention stacks (dense / MoE decoders);
    SSM/hybrid state is not paged and enc-dec needs cross-attention."""
    return (not cfg.is_encoder_decoder
            and all(kind != "ssm" for kind, _ in build_stacks(cfg))
            and cfg.arch_type not in ("ssm", "hybrid"))


def init_paged_state(cfg: ModelConfig, n_pages: int, page_size: int,
                     *, dtype=None) -> Dict[str, Any]:
    """Per-layer K/V page pools, stacked to match the scan layout.

    Unlike :func:`init_decode_state` the pools are shared across lanes:
    total KV memory is n_pages * page_size tokens per layer regardless of
    how many lanes are configured."""
    if not supports_paged_decode(cfg):
        raise NotImplementedError(
            f"paged decode does not support arch_type={cfg.arch_type!r}")
    stacks = []
    for _, n in build_stacks(cfg):
        one = init_page_pool(cfg, n_pages, page_size, dtype=dtype)
        stacks.append(jax.tree.map(
            lambda v: jnp.broadcast_to(v, (n,) + v.shape), one))
    return {"stacks": stacks}


def _paged_scan(params, pools, x, cfg, attn_fn):
    """Scan ``attn_fn`` + FFN over each stack; returns (x, new pools)."""
    new_stacks = []
    for (kind, _), stack_params, pstack in zip(
            build_stacks(cfg), params["stacks"], pools["stacks"]):

        def body(carry, inp):
            h = carry
            lp, lpool = inp
            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            a, new_pool = attn_fn(lp["attn"], hn, lpool)
            h = h + a
            hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
            if "mlp" in lp:
                h = h + swiglu_mlp(lp["mlp"], hn)
            else:
                y, _ = moe_ffn(lp["moe"], hn, cfg)
                h = h + y
            return h, new_pool

        n_l = jax.tree_util.tree_leaves(stack_params)[0].shape[0]
        x, new_pool = jax.lax.scan(body, x, (stack_params, pstack),
                                   unroll=scan_unroll(n_l))
        new_stacks.append(new_pool)
    return x, {"stacks": new_stacks}


def paged_decode_step(params, pools, token: jax.Array,
                      page_rows: jax.Array, lengths: jax.Array,
                      cfg: ModelConfig, *,
                      window: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    """One decode step on the paged KV cache.

    token (B,) -> logits (B, V) + new pools.  ``page_rows`` (B, P) /
    ``lengths`` (B,) come from the serving engine's page table (same table
    for every layer; each layer owns its own pool rows)."""
    x = embed(params["embed"], token)[:, None, :]
    win = window if window is not None else cfg.sliding_window
    x, new_pools = _paged_scan(
        params, pools, x, cfg,
        lambda p, h, lpool: attention_decode_paged(
            p, h, lpool, page_rows, lengths, cfg, window=win))
    logits = _logits(params, x, cfg)[:, 0]
    return logits, new_pools


def paged_prefill_step(params, pools, tokens: jax.Array,
                       page_rows: jax.Array, base: jax.Array,
                       prompt_len: jax.Array, cfg: ModelConfig, *,
                       window: Optional[int] = None
                       ) -> Tuple[jax.Array, Dict]:
    """One chunked-prefill step: process prompt chunk ``tokens`` (B, S)
    covering absolute positions [base, base + S), writing K/V into the
    page pools.  Returns logits (B, V) taken at each lane's *last prompt
    position* (meaningful only for lanes whose prompt ends inside this
    chunk) plus the updated pools."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    win = window if window is not None else cfg.sliding_window
    x, new_pools = _paged_scan(
        params, pools, x, cfg,
        lambda p, h, lpool: attention_prefill_paged(
            p, h, lpool, page_rows, base, prompt_len, cfg, window=win))
    last = jnp.clip(prompt_len - 1 - base, 0, S - 1)        # (B,)
    xl = x[jnp.arange(B), last][:, None, :]                 # (B,1,d)
    logits = _logits(params, xl, cfg)[:, 0]
    return logits, new_pools
