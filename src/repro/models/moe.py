"""Mixture-of-experts FFN with top-k routing.

Two dispatch implementations:

  * ``sort``   — production path: flat (token, choice) pairs are sorted by
    expert id, ranked within each expert, and scattered into a dense
    (E, capacity, d) buffer.  FLOP cost is just the expert matmuls (honest
    roofline); shards under GSPMD with the expert axis on the mesh.
  * ``einsum`` — GShard-style one-hot dispatch, O(T·E·C·d) extra FLOPs;
    kept as a small-scale cross-check oracle for the sort path.

Arctic's dense residual branch and Kimi-K2-style shared experts are computed
alongside the routed experts.  A switch-style load-balance auxiliary loss is
returned so the trainer can add it.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .layers import init_dense, swiglu
from .mlp import init_swiglu, swiglu_mlp


def init_moe(key, cfg: ModelConfig, dtype=None) -> Dict[str, Any]:
    dt = dtype or cfg.dtype
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {
        "router": init_dense(ks[0], d, E, jnp.float32),
        # stacked experts: (E, d, f) / (E, f, d)
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32)
                   / math.sqrt(d)).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32)
                 / math.sqrt(d)).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
                   / math.sqrt(f)).astype(dt),
    }
    if cfg.shared_expert_ff:
        p["shared"] = init_swiglu(ks[4], d, cfg.shared_expert_ff, dt)
    if cfg.dense_residual_ff:
        p["dense_residual"] = init_swiglu(ks[5], d, cfg.dense_residual_ff, dt)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(cfg.top_k, c)


def _route(p, xf: jax.Array, cfg: ModelConfig):
    """xf (T, d) -> (topv, topi, aux_loss)."""
    logits = xf.astype(jnp.float32) @ p["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)           # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance loss
    E = cfg.n_experts
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(1), axis=0)  # (E,)
    frac_probs = probs.mean(0)
    aux = E * jnp.sum(frac_tokens * frac_probs) / cfg.top_k
    return topv, topi, aux


def _experts(p, h: jax.Array) -> jax.Array:
    """h (E, C, d) -> (E, C, d) through each expert's SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", swiglu(g, u), p["w_down"])


def _moe_sort(p, xf: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    T, d = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    topv, topi, aux = _route(p, xf, cfg)

    flat_e = topi.reshape(-1)                                 # (T*k,)
    flat_w = topv.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k) - starts[se]
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)              # E*C = drop bin

    buf = jnp.zeros((E * C + 1, d), xf.dtype).at[slot].set(xf[st])
    y = _experts(p, buf[: E * C].reshape(E, C, d)).reshape(E * C, d)
    contrib = jnp.where(keep[:, None],
                        y[jnp.where(keep, slot, 0)], 0.0) * sw[:, None].astype(xf.dtype)
    out = jnp.zeros((T, d), xf.dtype).at[st].add(contrib)
    return out, aux


def _moe_einsum(p, xf: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """GShard one-hot dispatch (oracle for small shapes)."""
    T, d = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    topv, topi, aux = _route(p, xf, cfg)

    # position of each (t, choice) within its expert, in (t, choice) order —
    # identical ordering to the stable sort of the sort path.
    choice_e = jax.nn.one_hot(topi, E, dtype=jnp.int32)       # (T, k, E)
    flat = choice_e.reshape(T * k, E)
    rank = jnp.cumsum(flat, axis=0) - flat                    # (T*k, E)
    rank = (rank * flat).sum(-1).reshape(T, k)
    keep = rank < C
    disp = (jax.nn.one_hot(topi, E, dtype=xf.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, rank, C), C + 1,
                             dtype=xf.dtype)[:, :, None, :])  # (T,k,E,C+1)
    disp = disp[..., :C]
    h = jnp.einsum("tkec,td->ecd", disp, xf)
    y = _experts(p, h)
    comb = (disp * topv[:, :, None, None].astype(xf.dtype))
    out = jnp.einsum("tkec,ecd->td", comb, y)
    return out, aux


def _moe_grouped(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Batched grouped dispatch — the §Perf-optimized path.

    Key difference vs the vmap'd sort path: the dispatch buffer carries an
    explicit leading group dim and stays **data-sharded, expert-replicated**
    (anchored with a sharding constraint), so the scatter of group-local
    tokens is entirely local — GSPMD never emits the (G,E,C,d) buffer
    all-reduce across the model axis that dominates the baseline's
    collective roofline term.  The expert einsum then contracts against
    expert-sharded weights, which slices the replicated buffer locally.
    """
    G, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)

    xf32 = x.reshape(G * T, d)
    topv, topi, aux = _route(p, xf32, cfg)
    topv = topv.reshape(G, T, k)
    topi = topi.reshape(G, T, k)

    flat_e = topi.reshape(G, T * k)
    flat_w = topv.reshape(G, T * k)
    flat_t = jnp.broadcast_to(jnp.repeat(jnp.arange(T), k)[None], (G, T * k))
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, 1)
    st = jnp.take_along_axis(flat_t, order, 1)
    sw = jnp.take_along_axis(flat_w, order, 1)
    # rank within expert: position minus the expert's start offset
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(se)
    rank = jnp.arange(T * k)[None] - jnp.take_along_axis(starts, se, 1)
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, 0)           # dropped -> slot 0,
    gathered = jnp.take_along_axis(x, st[..., None], 1)  # (G, T*k, d)
    vals = jnp.where(keep[..., None], gathered, 0.0)   # ... with zero value

    from .flags import constrain_batch_only
    buf = jnp.zeros((G, E * C, d), x.dtype)
    buf = buf.at[jnp.arange(G)[:, None], slot].add(vals)
    buf = constrain_batch_only(buf)                    # data-sharded only
    y = jax.vmap(lambda h: _experts(p, h.reshape(E, C, d)))(buf)
    y = constrain_batch_only(y.reshape(G, E * C, d))

    picked = jnp.take_along_axis(y, slot[..., None], 1)
    contrib = jnp.where(keep[..., None], picked, 0.0) * sw[..., None].astype(x.dtype)
    out = jnp.zeros((G, T, d), x.dtype).at[
        jnp.arange(G)[:, None], st].add(contrib)
    return out, aux


def _moe_shmap(p, x: jax.Array, cfg: ModelConfig, mesh,
               bt_axes) -> Tuple[jax.Array, jax.Array]:
    """Explicit expert-parallel MoE under shard_map — the §Perf winner.

    Every device holds E/model_size experts and its data-shard of token
    groups.  Routing, dispatch scatter, expert matmuls and the combine
    scatter are all LOCAL; the only collective is one psum of the (G,T,d)
    partial outputs over the model axis — volume ~= tokens x d, a factor
    k x capacity_factor smaller than the dispatch-buffer all-reduce GSPMD
    derives for the baseline mapping.
    """
    import jax.experimental.shard_map  # noqa: F401  (older-alias safety)
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map as _sm

        def _shard_map(f, in_specs, out_specs):
            return _sm(f, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    except (ImportError, TypeError):  # pragma: no cover
        from jax.experimental.shard_map import shard_map as _sm_old

        def _shard_map(f, in_specs, out_specs):
            return _sm_old(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)

    G, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    n_model = mesh.shape["model"]
    E_loc = E // n_model

    def local(p_loc, x_loc):
        g_loc = x_loc.shape[0]
        xf = x_loc.reshape(g_loc * T, d)
        topv, topi, aux = _route(p_loc, xf, cfg)
        aux = jax.lax.pmean(aux, bt_axes) if bt_axes else aux
        topv = topv.reshape(g_loc, T, k)
        topi = topi.reshape(g_loc, T, k)

        flat_e = topi.reshape(g_loc, T * k)
        flat_w = topv.reshape(g_loc, T * k)
        flat_t = jnp.broadcast_to(jnp.repeat(jnp.arange(T), k)[None],
                                  (g_loc, T * k))
        order = jnp.argsort(flat_e, axis=1, stable=True)
        se = jnp.take_along_axis(flat_e, order, 1)
        st = jnp.take_along_axis(flat_t, order, 1)
        sw = jnp.take_along_axis(flat_w, order, 1)
        starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(se)
        rank = jnp.arange(T * k)[None] - jnp.take_along_axis(starts, se, 1)

        my = jax.lax.axis_index("model")
        off = my * E_loc
        keep = (rank < C) & (se >= off) & (se < off + E_loc)
        slot = jnp.where(keep, (se - off) * C + rank, 0)
        gathered = jnp.take_along_axis(x_loc, st[..., None], 1)
        vals = jnp.where(keep[..., None], gathered, 0.0)

        buf = jnp.zeros((g_loc, E_loc * C, d), x_loc.dtype)
        buf = buf.at[jnp.arange(g_loc)[:, None], slot].add(vals)
        y = jax.vmap(
            lambda h: _experts(p_loc, h.reshape(E_loc, C, d)))(buf)
        y = y.reshape(g_loc, E_loc * C, d)
        picked = jnp.take_along_axis(y, slot[..., None], 1)
        contrib = jnp.where(keep[..., None], picked,
                            0.0) * sw[..., None].astype(x_loc.dtype)
        out = jnp.zeros((g_loc, T, d), x_loc.dtype).at[
            jnp.arange(g_loc)[:, None], st].add(contrib)
        out = jax.lax.psum(out, "model")
        return out, aux

    x_spec = P(bt_axes if bt_axes else None, None, None)
    # only the routed-expert params enter the shard_map; shared experts /
    # dense residual branches are computed by the caller
    routed = {key: p[key] for key in ("router", "w_gate", "w_up", "w_down")}
    routed_specs = {key: (P("model", None, None)
                          if key != "router" else P()) for key in routed}
    out, aux = _shard_map(local, (routed_specs, x_spec),
                          (x_spec, P()))(routed, x)
    return out, aux


def _shard_map_compat(mesh):
    """Version-compat ``shard_map`` binder (same dance as ``_moe_shmap``)."""
    try:
        from jax import shard_map as _sm

        def _shard_map(f, in_specs, out_specs):
            return _sm(f, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    except (ImportError, TypeError):  # pragma: no cover
        from jax.experimental.shard_map import shard_map as _sm_old

        def _shard_map(f, in_specs, out_specs):
            return _sm_old(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)
    return _shard_map


def _moe_ep(p, x: jax.Array, cfg: ModelConfig, mesh,
            bt_axes) -> Tuple[jax.Array, jax.Array]:
    """Expert parallelism over the searched ``"expert"`` mesh axis — the
    runtime for a plan's ``ep_degree`` (plan format v5).

    Each expert rank owns ``E / ep`` experts (weights sharded on the mesh)
    and a batch shard of token groups.  Tokens route locally against the
    replicated router, the per-group dispatch buffer is built locally in
    global expert order, and one **all-to-all** per direction moves each
    expert's capacity slab to its owner (dispatch) and the expert outputs
    back (combine) — the collective the cost model prices for EP.  Group
    semantics (per-group capacity, stable-sort ranking, drop order) are
    identical to the single-device sort path, so outputs are
    token-identical to ``dispatch="sort"`` (tests/test_moe.py certifies
    this on an 8-fake-device mesh).
    """
    import jax.experimental.shard_map  # noqa: F401  (older-alias safety)
    from jax.sharding import PartitionSpec as P

    G, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    n_ep = mesh.shape["expert"]
    E_loc = E // n_ep
    bt = tuple(a for a in (bt_axes or ()) if a != "expert")
    aux_axes = bt + ("expert",)

    def local(p_loc, x_loc):
        g_loc = x_loc.shape[0]
        # route per group (aux is a per-group mean, like the single-device
        # path: joint routing over g_loc groups would skew the balance loss)
        topv, topi, aux = jax.vmap(
            lambda g: _route(p_loc, g, cfg))(x_loc)       # (g_loc, T, k)
        aux = jax.lax.pmean(aux.mean(), aux_axes)

        # per-group dispatch in GLOBAL expert order (same arithmetic as
        # _moe_grouped: stable sort, searchsorted starts, capacity drop)
        flat_e = topi.reshape(g_loc, T * k)
        flat_w = topv.reshape(g_loc, T * k)
        flat_t = jnp.broadcast_to(jnp.repeat(jnp.arange(T), k)[None],
                                  (g_loc, T * k))
        order = jnp.argsort(flat_e, axis=1, stable=True)
        se = jnp.take_along_axis(flat_e, order, 1)
        st = jnp.take_along_axis(flat_t, order, 1)
        sw = jnp.take_along_axis(flat_w, order, 1)
        starts = jax.vmap(
            lambda row: jnp.searchsorted(row, jnp.arange(E)))(se)
        rank = jnp.arange(T * k)[None] - jnp.take_along_axis(starts, se, 1)
        keep = rank < C
        slot = jnp.where(keep, se * C + rank, 0)
        gathered = jnp.take_along_axis(x_loc, st[..., None], 1)
        vals = jnp.where(keep[..., None], gathered, 0.0)
        buf = jnp.zeros((g_loc, E * C, d), x_loc.dtype)
        buf = buf.at[jnp.arange(g_loc)[:, None], slot].add(vals)

        # dispatch: slab for expert block q travels to rank q; combine
        # reverses the route.  tiled all-to-all keeps ranks' slabs in
        # global rank order, so the reshape below restores e*C + r slots.
        recv = jax.lax.all_to_all(buf, "expert", split_axis=1,
                                  concat_axis=0, tiled=True)
        y = jax.vmap(
            lambda h: _experts(p_loc, h.reshape(E_loc, C, d)))(recv)
        y = y.reshape(n_ep * g_loc, E_loc * C, d)
        y = jax.lax.all_to_all(y, "expert", split_axis=0,
                               concat_axis=1, tiled=True)  # (g_loc, E*C, d)

        picked = jnp.take_along_axis(y, slot[..., None], 1)
        contrib = jnp.where(keep[..., None], picked,
                            0.0) * sw[..., None].astype(x_loc.dtype)
        out = jnp.zeros((g_loc, T, d), x_loc.dtype).at[
            jnp.arange(g_loc)[:, None], st].add(contrib)
        return out, aux

    x_spec = P(bt + ("expert",), None, None)
    routed = {key: p[key] for key in ("router", "w_gate", "w_up", "w_down")}
    routed_specs = {key: (P("expert", None, None)
                          if key != "router" else P()) for key in routed}
    out, aux = _shard_map_compat(mesh)(local, (routed_specs, x_spec),
                                       (x_spec, P()))(routed, x)
    return out, aux


def expert_axis_usable(cfg: ModelConfig, mesh, batch: int,
                       bt_axes) -> bool:
    """Can ``_moe_ep`` run: an ``"expert"`` mesh axis of size > 1 exists,
    it divides the expert count, and the batch shards evenly over the
    data x expert axes."""
    if mesh is None or "expert" not in mesh.axis_names:
        return False
    n_ep = mesh.shape["expert"]
    if n_ep <= 1 or cfg.n_experts % n_ep:
        return False
    span = n_ep
    for a in (bt_axes or ()):
        if a != "expert":
            span *= mesh.shape[a]
    return batch % span == 0


def moe_ffn(p, x: jax.Array, cfg: ModelConfig, *,
            dispatch: str = "sort") -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out, aux_loss).

    Dispatch is *grouped* per batch row (GShard-style groups): tokens only
    compete for expert capacity within their own group, so the dispatch
    buffers carry a leading batch dimension that shards over the data mesh
    axis while the expert dimension shards over the model axis.

    When the ambient mesh carries an ``"expert"`` axis (a plan with
    ``ep_degree > 1``, see launch/mesh.py), the sort dispatch executes
    expert-parallel via :func:`_moe_ep` — sharded expert weights plus
    all-to-all dispatch/combine — regardless of ``cfg.moe_dispatch``.
    """
    B, S, d = x.shape
    from .flags import current_batch_axes, current_mesh
    ep_mesh = current_mesh()
    ep_bt = current_batch_axes()
    if (dispatch in ("sort", "grouped", "shmap")
            and expert_axis_usable(cfg, ep_mesh, B, ep_bt)):
        out, aux = _moe_ep(p, x, cfg, ep_mesh, ep_bt)
        if "shared" in p:
            out = out + swiglu_mlp(p["shared"], x)
        if "dense_residual" in p:
            out = out + swiglu_mlp(p["dense_residual"], x)
        return out, aux
    if dispatch == "sort" and cfg.moe_dispatch in ("grouped", "shmap"):
        dispatch = cfg.moe_dispatch
    if dispatch == "shmap":
        from .flags import current_batch_axes, current_mesh
        mesh = current_mesh()
        bt = current_batch_axes()
        ok = (mesh is not None and "model" in mesh.axis_names
              and cfg.n_experts % mesh.shape["model"] == 0
              and (not bt or B % max(1, __import__("math").prod(
                  mesh.shape[a] for a in bt)) == 0))
        if ok:
            out, aux = _moe_shmap(p, x, cfg, mesh, bt)
        else:   # fall back (no mesh context / indivisible shapes)
            out, aux = _moe_grouped(p, x, cfg)
    elif dispatch == "grouped":
        out, aux = _moe_grouped(p, x, cfg)
    else:
        fn = _moe_sort if dispatch == "sort" else _moe_einsum
        out, aux = jax.vmap(lambda xg: fn(p, xg, cfg))(x)
        aux = aux.mean()
    if "shared" in p:
        out = out + swiglu_mlp(p["shared"], x)
    if "dense_residual" in p:
        out = out + swiglu_mlp(p["dense_residual"], x)
    return out, aux
