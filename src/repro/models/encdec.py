"""Whisper-style encoder-decoder transformer backbone.

Per the modality carve-out, the mel-spectrogram + conv feature extractor is a
STUB: the encoder consumes precomputed frame embeddings (B, T_enc, d) from
``input_specs``.  We implement the transformer itself: non-causal encoder,
causal decoder with cross-attention, learned positional embeddings,
LayerNorm + GELU MLPs (the Whisper recipe), and a one-token decode step with
self-attention KV cache + precomputed cross K/V.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attention, attention_decode, cross_attention,
                        init_attention, init_kv_cache, precompute_cross_kv)
from .common import ModelConfig
from .flags import constrain_batch, scan_unroll
from .embedding import embed, init_embedding, init_learned_pos
from .layers import cross_entropy_loss, layer_norm
from .mlp import gelu_mlp, init_gelu_mlp


def _init_ln(cfg):
    return {"w": jnp.ones((cfg.d_model,), cfg.dtype),
            "b": jnp.zeros((cfg.d_model,), cfg.dtype)}


def init_enc_block(key, cfg: ModelConfig) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": _init_ln(cfg),
        "attn": init_attention(k1, cfg),
        "ln2": _init_ln(cfg),
        "mlp": init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def init_dec_block(key, cfg: ModelConfig) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": _init_ln(cfg),
        "self_attn": init_attention(k1, cfg),
        "ln_x": _init_ln(cfg),
        "cross_attn": init_attention(k2, cfg, cross=True),
        "ln2": _init_ln(cfg),
        "mlp": init_gelu_mlp(k3, cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def init_encdec(key, cfg: ModelConfig, *, max_dec_len: int = 4096) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    n_enc = cfg.n_enc_layers or cfg.n_layers
    return {
        "enc_pos": init_learned_pos(ks[0], cfg.encoder_seq or 1500,
                                    cfg.d_model, cfg.dtype),
        "enc_blocks": jax.vmap(lambda k: init_enc_block(k, cfg))(
            jax.random.split(ks[1], n_enc)),
        "enc_ln": _init_ln(cfg),
        "embed": init_embedding(ks[2], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "dec_pos": init_learned_pos(ks[3], max_dec_len, cfg.d_model, cfg.dtype),
        "dec_blocks": jax.vmap(lambda k: init_dec_block(k, cfg))(
            jax.random.split(ks[4], cfg.n_layers)),
        "dec_ln": _init_ln(cfg),
    }


def _enc_block(p, x, positions, cfg):
    h = layer_norm(x, p["ln1"]["w"], p["ln1"]["b"], cfg.norm_eps)
    x = x + attention(p["attn"], h, positions, cfg, causal=False)
    h = layer_norm(x, p["ln2"]["w"], p["ln2"]["b"], cfg.norm_eps)
    return x + gelu_mlp(p["mlp"], h)


def encode(params, frames: jax.Array, cfg: ModelConfig, *,
           remat: bool = False) -> jax.Array:
    """frames (B, T_enc, d) — stub conv-frontend output."""
    B, T, _ = frames.shape
    x = frames.astype(cfg.dtype) + params["enc_pos"][:T]
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    def body(h, lp):
        return constrain_batch(_enc_block(lp, h, positions, cfg)), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    n_l = params["enc_blocks"]["ln1"]["w"].shape[0]
    x, _ = jax.lax.scan(body, x, params["enc_blocks"], unroll=scan_unroll(n_l))
    return layer_norm(x, params["enc_ln"]["w"], params["enc_ln"]["b"],
                      cfg.norm_eps)


def _dec_block(p, x, positions, enc_out, cfg):
    h = layer_norm(x, p["ln1"]["w"], p["ln1"]["b"], cfg.norm_eps)
    x = x + attention(p["self_attn"], h, positions, cfg, causal=True)
    h = layer_norm(x, p["ln_x"]["w"], p["ln_x"]["b"], cfg.norm_eps)
    kv = precompute_cross_kv(p["cross_attn"], enc_out, cfg)
    x = x + cross_attention(p["cross_attn"], h, kv, cfg)
    h = layer_norm(x, p["ln2"]["w"], p["ln2"]["b"], cfg.norm_eps)
    return x + gelu_mlp(p["mlp"], h)


def decode_train(params, tokens: jax.Array, enc_out: jax.Array,
                 cfg: ModelConfig, *, remat: bool = False) -> jax.Array:
    B, S = tokens.shape
    # wrap positions past the learned table (synthetic long-context stress
    # shapes exceed whisper's real 448-token decoder window; see DESIGN.md)
    P_len = params["dec_pos"].shape[0]
    pos_emb = jnp.take(params["dec_pos"], jnp.arange(S) % P_len, axis=0)
    x = embed(params["embed"], tokens) + pos_emb
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(h, lp):
        return constrain_batch(_dec_block(lp, h, positions, enc_out, cfg)), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    n_l = params["dec_blocks"]["ln1"]["w"].shape[0]
    x, _ = jax.lax.scan(body, x, params["dec_blocks"], unroll=scan_unroll(n_l))
    x = layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"], cfg.norm_eps)
    return x @ params["embed"].T      # whisper ties output projection


def encdec_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig, *,
                remat: bool = False) -> jax.Array:
    enc_out = encode(params, batch["frames"], cfg, remat=remat)
    logits = decode_train(params, batch["tokens"], enc_out, cfg, remat=remat)
    return cross_entropy_loss(logits, batch["labels"])


# --------------------------------------------------------------------------
# decode path
# --------------------------------------------------------------------------

def init_encdec_decode_state(params, frames: jax.Array, cfg: ModelConfig,
                             context: int) -> Dict[str, Any]:
    """Run the encoder once, precompute every layer's cross K/V, and
    allocate self-attention caches."""
    B = frames.shape[0]
    enc_out = encode(params, frames, cfg)
    cross_kv = jax.vmap(
        lambda lp: precompute_cross_kv(lp["cross_attn"], enc_out, cfg),
        in_axes=0)(params["dec_blocks"])
    one = init_kv_cache(cfg, B, context)
    n = cfg.n_layers
    self_cache = jax.tree.map(lambda v: jnp.broadcast_to(v, (n,) + v.shape), one)
    return {"cross_kv": cross_kv, "self_cache": self_cache,
            "index": jnp.zeros((), jnp.int32)}


def encdec_decode_step(params, state, token: jax.Array,
                       cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, Any]]:
    """token (B,) -> logits (B, V)."""
    index = state["index"]
    x = embed(params["embed"], token)[:, None, :]
    pos_emb = jnp.take(params["dec_pos"],
                       index % params["dec_pos"].shape[0], axis=0)
    x = x + pos_emb[None, None, :]

    def body(h, inp):
        lp, cache, ckv = inp
        hh = layer_norm(h, lp["ln1"]["w"], lp["ln1"]["b"], cfg.norm_eps)
        a, cache2 = attention_decode(lp["self_attn"], hh, cache, index, cfg)
        h = h + a
        hh = layer_norm(h, lp["ln_x"]["w"], lp["ln_x"]["b"], cfg.norm_eps)
        h = h + cross_attention(lp["cross_attn"], hh, ckv, cfg)
        hh = layer_norm(h, lp["ln2"]["w"], lp["ln2"]["b"], cfg.norm_eps)
        h = h + gelu_mlp(lp["mlp"], hh)
        return h, cache2

    n_l = params["dec_blocks"]["ln1"]["w"].shape[0]
    x, new_cache = jax.lax.scan(
        body, x, (params["dec_blocks"], state["self_cache"],
                  state["cross_kv"]), unroll=scan_unroll(n_l))
    x = layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"], cfg.norm_eps)
    logits = (x @ params["embed"].T)[:, 0]
    return logits, {"cross_kv": state["cross_kv"], "self_cache": new_cache,
                    "index": index + 1}
