"""Token / positional / stub-modality embeddings."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .layers import init_dense


def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def init_learned_pos(key, max_len: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (max_len, d), jnp.float32) * 0.01).astype(dtype)


def init_projector(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """2-layer MLP projector (VLM frontend stub -> LM width)."""
    k1, k2 = jax.random.split(key)
    return {
        "w1": init_dense(k1, d_in, d_out, dtype),
        "b1": jnp.zeros((d_out,), dtype),
        "w2": init_dense(k2, d_out, d_out, dtype),
        "b2": jnp.zeros((d_out,), dtype),
    }


def project(p, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu((x @ p["w1"] + p["b1"]).astype(jnp.float32))
    return (h.astype(x.dtype) @ p["w2"]) + p["b2"]
