"""Global lowering flags.

``force_unroll`` makes every ``lax.scan`` in the model fully unroll.  XLA's
``cost_analysis`` counts a while-loop body ONCE regardless of trip count,
so the dry-run's shallow roofline probes compile with unrolled scans to get
true per-device FLOP/byte/collective counts; production lowering keeps the
rolled scans (small HLO, fast compiles).
"""
from __future__ import annotations

import contextlib

_UNROLL = False


def unroll_scans() -> bool:
    return _UNROLL


def scan_unroll(length: int) -> int:
    """`unroll=` argument for lax.scan."""
    return length if _UNROLL else 1


@contextlib.contextmanager
def force_unroll(on: bool = True):
    global _UNROLL
    old = _UNROLL
    _UNROLL = on
    try:
        yield
    finally:
        _UNROLL = old


# ---------------------------------------------------------------------------
# activation batch-sharding anchor
# ---------------------------------------------------------------------------
_BATCH_AXES = None
_SEQ_AXIS = None          # (axis_name, axis_size) for sequence parallelism
_MESH = None              # ambient mesh for shard_map-based layers


def current_mesh():
    return _MESH


def current_batch_axes():
    return _BATCH_AXES


@contextlib.contextmanager
def batch_sharding(axes, seq_axis=None, seq_axis_size=1, mesh=None):
    """While tracing under this context, ``constrain_batch`` pins the leading
    (batch) dim of activations to the given mesh axes — anchors GSPMD so the
    batch dimension never silently degrades to replicated.

    ``seq_axis`` additionally shards dim 1 (the sequence) of rank>=3
    activations — Megatron-style sequence parallelism for the residual
    stream, our beyond-paper memory optimization (EXPERIMENTS.md §Perf)."""
    global _BATCH_AXES, _SEQ_AXIS, _MESH
    old, olds, oldm = _BATCH_AXES, _SEQ_AXIS, _MESH
    _BATCH_AXES = tuple(axes) if axes else None
    _SEQ_AXIS = (seq_axis, seq_axis_size) if seq_axis else None
    _MESH = mesh
    try:
        yield
    finally:
        _BATCH_AXES, _SEQ_AXIS, _MESH = old, olds, oldm


def constrain_batch(x):
    if _BATCH_AXES is None:
        return x
    import jax
    from jax.sharding import PartitionSpec as P
    rest = [None] * (x.ndim - 1)
    if (_SEQ_AXIS is not None and x.ndim >= 3
            and x.shape[1] % max(1, _SEQ_AXIS[1]) == 0):
        rest[0] = _SEQ_AXIS[0]
    try:
        return jax.lax.with_sharding_constraint(x, P(_BATCH_AXES, *rest))
    except (ValueError, RuntimeError):   # no mesh context
        return x


def constrain_batch_only(x):
    """Pin ONLY the leading dim to the batch axes (no sequence sharding) —
    used for tensors whose dim-1 must stay unsharded (MoE dispatch buffers)."""
    if _BATCH_AXES is None:
        return x
    import jax
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(
            x, P(_BATCH_AXES, *([None] * (x.ndim - 1))))
    except (ValueError, RuntimeError):
        return x


def seq_sharding_active() -> bool:
    return _SEQ_AXIS is not None
