"""Mamba2 blocks via SSD (state-space duality, arXiv:2405.21060).

The sequence transform is the chunked SSD algorithm: quadratic attention-like
computation inside chunks, linear recurrence across chunks.  ``ssd_chunked``
is the pure-jnp core (also the oracle for the Pallas kernel); ``ssm_step``
is the O(1) decode update.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .layers import init_dense, rms_norm


def init_ssm(key, cfg: ModelConfig, dtype=None) -> Dict[str, Any]:
    dt = dtype or cfg.dtype
    d, di = cfg.d_model, cfg.d_inner
    H, N, G = cfg.ssm_heads, cfg.ssm_state, 1
    conv_dim = di + 2 * G * N
    d_in_proj = 2 * di + 2 * G * N + H
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "in_proj": init_dense(k1, d, d_in_proj, dt),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, conv_dim), jnp.float32)
                   / math.sqrt(cfg.ssm_conv)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((di,), dt),
        "out_proj": init_dense(k3, di, d, dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,C), w (K,C)."""
    K = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        shift = K - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int) -> jax.Array:
    """Chunked SSD scan.

    x  (B,S,H,P) inputs per head
    dt (B,S,H)   positive step sizes
    A  (H,)      negative decay rates
    Bm (B,S,H,N) input projections (already broadcast over heads)
    Cm (B,S,H,N) output projections
    returns y (B,S,H,P); state handled internally (zero init).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        # causal: zero-padding the tail never affects the first S outputs
        pad = Q - S % Q
        padded = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))), A,
            jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0))), Q)
        return padded[:, :S]
    nc = S // Q

    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtc = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, H, N)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, H, N)

    dA = dtc * A.astype(jnp.float32)               # (B,nc,Q,H), negative
    cum = jnp.cumsum(dA, axis=2)                   # inclusive cumsum
    # intra-chunk (attention-like) part
    CB = jnp.einsum("bnqhr,bnkhr->bnqkh", Cc, Bc)
    iq = jnp.arange(Q)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    # mask the exponent (not the product) so exp never sees a positive
    # argument — keeps gradients finite through the masked entries
    delta = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,K,H)
    decay = jnp.exp(jnp.where(causal, delta, -1e30))
    M = CB * decay * dtc[:, :, None, :, :]
    y_diag = jnp.einsum("bnqkh,bnkhp->bnqhp", M, xf)

    # chunk-boundary states
    last = cum[:, :, -1:, :]                                   # (B,nc,1,H)
    decay_to_end = jnp.exp(last - cum)                         # (B,nc,Q,H)
    S_chunk = jnp.einsum("bnkhr,bnkhp->bnhpr",
                         Bc * (decay_to_end * dtc)[..., None], xf)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(last[:, :, 0, :])                    # (B,nc,H)

    def step(s, inp):
        dec, add = inp                                         # (B,H), (B,H,P,N)
        s_out = s                                              # state BEFORE chunk
        s_next = s * dec[:, :, None, None] + add
        return s_next, s_out

    # NOTE: deliberately NOT unrolled under force_unroll() — the recurrence
    # body is a tiny elementwise update (2*B*H*P*N FLOPs/chunk, ~1e-5 of the
    # intra-chunk einsums, which live OUTSIDE this scan), while unrolling
    # nc=512 iterations x n_layers explodes probe compile time/memory.
    _, s_before = jax.lax.scan(
        step, jnp.zeros((Bsz, H, P, N), jnp.float32),
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_chunk, 1, 0)))
    s_before = jnp.moveaxis(s_before, 0, 1)                    # (B,nc,H,P,N)

    y_off = jnp.einsum("bnqhr,bnhpr->bnqhp", Cc * jnp.exp(cum)[..., None],
                       s_before)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(x.dtype)


def ssd_step(state: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
             Bm: jax.Array, Cm: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD update.

    state (B,H,P,N); x (B,H,P); dt (B,H); Bm/Cm (B,H,N).
    returns (new_state, y (B,H,P)).
    """
    dA = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))  # (B,H)
    add = jnp.einsum("bhp,bhr->bhpr", x.astype(jnp.float32)
                     * dt.astype(jnp.float32)[..., None], Bm.astype(jnp.float32))
    new = state * dA[:, :, None, None] + add
    y = jnp.einsum("bhpr,bhr->bhp", new, Cm.astype(jnp.float32))
    return new, y.astype(x.dtype)


def _split_proj(p, x: jax.Array, cfg: ModelConfig):
    di, H, N, G = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, 1
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [di, di + di + 2 * G * N], axis=-1)
    return z, xBC, dt


def ssm_block(p, x: jax.Array, cfg: ModelConfig, *,
              use_kernel: bool = False) -> jax.Array:
    """Full-sequence Mamba2 block. x (B,S,d) -> (B,S,d)."""
    Bsz, S, _ = x.shape
    di, H, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    P = cfg.ssm_head_dim
    z, xBC, dt = _split_proj(p, x, cfg)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    xs = xs.reshape(Bsz, S, H, P)
    Bm = jnp.broadcast_to(Bm[:, :, None, :], (Bsz, S, H, N))
    Cm = jnp.broadcast_to(Cm[:, :, None, :], (Bsz, S, H, N))
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    if use_kernel:
        from repro.kernels.ops import ssd_scan as _ssd
        y = _ssd(xs, dtp, A, Bm, Cm, chunk=cfg.ssm_chunk)
    else:
        y = ssd_chunked(xs, dtp, A, Bm, Cm, cfg.ssm_chunk)
    y = y + (p["D"].astype(jnp.float32)[:, None] * xs.astype(jnp.float32)
             ).astype(y.dtype)
    y = y.reshape(Bsz, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"]


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, H, P, N), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def ssm_block_decode(p, x: jax.Array, state: Dict[str, jax.Array],
                     cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token Mamba2 step. x (B,1,d)."""
    Bsz = x.shape[0]
    di, H, N, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    z, xBC, dt = _split_proj(p, x[:, 0], cfg)
    # conv ring: state["conv"] holds the previous K-1 inputs
    hist = jnp.concatenate([state["conv"],
                            xBC[:, None].astype(state["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = hist[:, 1:].astype(state["conv"].dtype)

    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    xs = xs.reshape(Bsz, H, P)
    Bm = jnp.broadcast_to(Bm[:, None, :], (Bsz, H, N))
    Cm = jnp.broadcast_to(Cm[:, None, :], (Bsz, H, N))
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    new_ssm, y = ssd_step(state["ssm"], xs, dtp, A, Bm, Cm)
    y = y + (p["D"].astype(jnp.float32)[:, None] * xs.astype(jnp.float32)
             ).astype(y.dtype)
    y = y.reshape(Bsz, 1, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)
                                 ).astype(y.dtype)[:, None, :],
                 p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], {"ssm": new_ssm, "conv": new_conv}
