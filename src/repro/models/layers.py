"""Primitive layers: norms, rotary embeddings, initializers."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def init_dense(key, d_in: int, d_out: int, dtype=jnp.bfloat16,
               scale: Optional[float] = None) -> jax.Array:
    s = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, dh); positions: (..., seq) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                         # (dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, dh/2)
    cos = jnp.cos(ang)[..., :, None, :]                   # (..., seq, 1, dh/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softmax_fp32(x: jax.Array, axis: int = -1) -> jax.Array:
    return jax.nn.softmax(x.astype(jnp.float32), axis=axis)


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       ignore_id: int = -100) -> jax.Array:
    """Mean token cross entropy in fp32. logits (..., V), labels (...).

    Written with explicit reductions instead of take_along_axis so a
    vocab-sharded logits tensor only needs small (B, S) all-reduces —
    a vocab-dim gather would force GSPMD to all-gather the full logits.
    """
    lf = logits.astype(jnp.float32)
    V = lf.shape[-1]
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    onehot = (iota == labels[..., None].clip(0)).astype(jnp.float32)
    gold = jnp.sum(lf * onehot, axis=-1)
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
