"""Feed-forward blocks: SwiGLU (LLaMA/Qwen family) and GELU (Whisper)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .layers import gelu, init_dense, swiglu


def init_swiglu(key, d: int, d_ff: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, d, d_ff, dtype),
        "w_up": init_dense(k2, d, d_ff, dtype),
        "w_down": init_dense(k3, d_ff, d, dtype),
    }


def swiglu_mlp(p, x: jax.Array) -> jax.Array:
    return swiglu(x @ p["w_gate"], x @ p["w_up"]) @ p["w_down"]


def init_gelu_mlp(key, d: int, d_ff: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_fc": init_dense(k1, d, d_ff, dtype),
        "b_fc": jnp.zeros((d_ff,), dtype),
        "w_proj": init_dense(k2, d_ff, d, dtype),
        "b_proj": jnp.zeros((d,), dtype),
    }


def gelu_mlp(p, x: jax.Array) -> jax.Array:
    return gelu(x @ p["w_fc"] + p["b_fc"]) @ p["w_proj"] + p["b_proj"]
