"""Quickstart: the Galvatron-BMW workflow in ~40 lines.

1. describe your model as per-layer workloads,
2. let the engine search the hybrid parallelism plan,
3. execute the plan with the sharded runtime.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.specs import layerspecs_for
from repro.core import GalvatronOptimizer, galvatron_variant, paper_8gpu, tpu_v5e_pod
from repro.data import DataConfig, batch_specs, synthetic_lm_batches
from repro.launch.mesh import make_local_mesh
from repro.runtime import ShardPolicy, init_train_state, make_train_step

GB = 1024 ** 3

# --- 1) search: BERT-Huge on the paper's 8-GPU testbed ---------------------
from repro.configs.paper_models import paper_model_specs
specs = paper_model_specs("bert-huge-32")
ocfg = galvatron_variant("bmw")
ocfg.batch_grid = [16, 32, 64]
ocfg.n_bins = 128
plan = GalvatronOptimizer(specs, paper_8gpu().with_budget(8 * GB),
                          ocfg).optimize()
print("BERT-Huge-32 @ 8x RTX-TITAN (8GB):")
print("  ", plan.summary())
print(f"   est. throughput: {plan.est_throughput:.1f} samples/s "
      f"(alpha_t={plan.alpha_t:.2f}, alpha_m={plan.alpha_m:.2f})")

# --- 2) search: an assigned arch on a TPU v5e slice ------------------------
cfg = get_config("qwen3-8b")
ocfg = galvatron_variant("bmw")
ocfg.batch_grid = [256]
ocfg.n_bins = 64
ocfg.micro_candidates = 2
ocfg.max_pp = 2
plan_tpu = GalvatronOptimizer(layerspecs_for(cfg, 4096), tpu_v5e_pod(64),
                              ocfg).optimize()
print("\nqwen3-8b @ 64x TPU v5e:")
print("  ", plan_tpu.summary())

# --- 3) execute: train a reduced model with the plan's policy --------------
cfg_small = cfg.reduced()
policy = ShardPolicy.from_strategy(plan_tpu.strategies[1])
mesh = make_local_mesh()
dcfg = DataConfig(seq_len=64, global_batch=4, vocab_size=cfg_small.vocab_size)
with mesh:
    step = make_train_step(cfg_small, mesh, policy, batch_specs(dcfg))
    params, opt = init_train_state(cfg_small, mesh, policy)
    gen = synthetic_lm_batches(dcfg)
    print("\ntraining reduced qwen3 with the searched policy:")
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, opt, m = step.fn(params, opt, batch)
        print(f"  step {i}: loss={float(m['loss']):.4f}")
print("quickstart done.")
