"""Batched serving example: continuous-batching decode over a reduced
assigned architecture (default: the MoE Kimi-K2 family, where the searched
expert sharding matters most).

    PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-370m]
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.launch.serve import Request, serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kimi-k2-1t-a32b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(42)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(2, 6))).tolist(),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    serve(cfg, reqs, batch=args.batch, context=128)
    done = sum(r.done for r in reqs)
    print(f"{done}/{len(reqs)} requests completed")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {len(r.prompt)} prompt -> "
              f"{len(r.generated)} new tokens")


if __name__ == "__main__":
    main()
