"""Profile-calibrated plan search (paper §V: per-layer times are PROFILED,
not guessed).  We measure real matmul-equivalent layer times on this host,
translate them to the target device's throughput, and let the Galvatron
engine search with the measured costs.

    PYTHONPATH=src python examples/profiled_search.py
"""
from repro.configs import get_config
from repro.configs.specs import layerspecs_for
from repro.core import GalvatronOptimizer, galvatron_variant, tpu_v5e_pod
from repro.core.profiler import measure_matmul_throughput, profile_layerspecs

cfg = get_config("qwen3-4b")
specs = layerspecs_for(cfg, 2048)

print(f"host matmul throughput: {measure_matmul_throughput()/1e9:.1f} GFLOP/s")
cluster = tpu_v5e_pod(64)
times = profile_layerspecs(specs, device_peak_flops=cluster.device.peak_flops)
uniq = sorted(set(times.values()))
print(f"profiled {len(times)} layers, {len(uniq)} distinct timings; "
      f"body layer = {times['layer0']*1e3:.3f} ms/sample (target-scaled)")

ocfg = galvatron_variant("bmw")
ocfg.batch_grid = [128, 256]
ocfg.n_bins = 96
ocfg.micro_candidates = 2
ocfg.max_pp = 2

plan_analytic = GalvatronOptimizer(specs, cluster, ocfg).optimize()
plan_profiled = GalvatronOptimizer(specs, cluster, ocfg,
                                   profiled_times=times).optimize()
print("\nanalytic-cost plan: ", plan_analytic.summary())
print("profiled-cost plan: ", plan_profiled.summary())
print(f"estimated throughputs: analytic {plan_analytic.est_throughput:.1f}, "
      f"profiled {plan_profiled.est_throughput:.1f} samples/s")
