"""Plan-search showcase: reproduce Fig. 6-style optimal plans for
heterogeneous models (Swin's uneven stages, T5-512/4's enc/dec imbalance)
and for assigned architectures on TPU pods.

    PYTHONPATH=src python examples/search_plans.py
"""
from repro.configs import get_config
from repro.configs.paper_models import paper_model_specs
from repro.configs.specs import layerspecs_for
from repro.core import (GalvatronOptimizer, galvatron_variant, paper_8gpu,
                        paper_16gpu_low, tpu_v5e_pod)

GB = 1024 ** 3


def show(title, specs, cluster, grid):
    cfg = galvatron_variant("bmw")
    cfg.batch_grid = grid
    cfg.n_bins = 96
    cfg.micro_candidates = 2
    plan = GalvatronOptimizer(specs, cluster, cfg).optimize()
    print(f"\n{title}")
    if plan is None:
        print("   infeasible")
        return
    print(f"   {plan.summary()}")
    print(f"   tpt={plan.est_throughput:.1f}/s  alpha_t={plan.alpha_t:.2f} "
          f"alpha_m={plan.alpha_m:.2f}  stage_mem(GB)="
          f"{[round(m/GB, 1) for m in (plan.est_stage_mem or [])]}")


def main():
    # Fig. 6 case A/B: BERT and Swin on 8 low-perf GPUs, 8GB
    show("case A: BERT-Huge-32, 8GPU @ 8G",
         paper_model_specs("bert-huge-32"),
         paper_8gpu().with_budget(8 * GB), [8, 16, 32])
    show("case B: Swin-Huge-32, 8GPU @ 8G (uneven layers)",
         paper_model_specs("swin-huge-32"),
         paper_8gpu().with_budget(8 * GB), [16, 32, 64])
    # Fig. 6 case C: imbalanced T5 on 16 GPUs
    show("case C: T5-512/4-32, 16GPU low-perf @ 8G (enc/dec imbalance)",
         paper_model_specs("t5-512/4-32"),
         paper_16gpu_low().with_budget(8 * GB), [16, 32, 64])
    # assigned archs on TPU slices.  kimi-k2 (1T params) is INFEASIBLE even
    # on a full 256-chip pod: AdamW states alone are 62 GB/chip vs 16 GB
    # HBM — the search engine reaches the same verdict as the §Perf
    # capacity analysis in EXPERIMENTS.md (needs >=4 pods or bf16 states).
    for arch, chips in [("qwen3-8b", 64), ("kimi-k2-1t-a32b", 256),
                        ("mamba2-370m", 64)]:
        cfg = get_config(arch)
        show(f"assigned: {arch} @ {chips}x v5e, seq 4096",
             layerspecs_for(cfg, 4096), tpu_v5e_pod(chips), [64, 128, 256])


if __name__ == "__main__":
    main()
