"""End-to-end driver: train a ~100M-parameter qwen3-family LM for a few
hundred steps with the full stack (search -> sharded executor -> data
pipeline -> checkpointing).

Default invocation trains a smaller (~15M) model for 200 steps so it
finishes in minutes on this CPU container; pass ``--hundred-m`` for the
full-size run (same code path, ~100M params):

    PYTHONPATH=src python examples/train_lm.py [--hundred-m] [--steps 300]
"""
import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    if args.hundred_m:
        # 12 x d512 + 152k vocab tied-ish ~ 100M params
        argv = ["--arch", "qwen3-4b", "--layers", "12", "--d-model", "512",
                "--steps", str(args.steps), "--batch", "8", "--seq", "256",
                "--ckpt-dir", "checkpoints/train_lm_100m",
                "--ckpt-every", "100"]
    else:
        argv = ["--arch", "qwen3-4b", "--reduced", "--layers", "4",
                "--d-model", "256", "--steps", str(args.steps),
                "--batch", "8", "--seq", "128",
                "--ckpt-dir", "checkpoints/train_lm",
                "--ckpt-every", "100"]
    train_mod.main(argv)


if __name__ == "__main__":
    main()
