"""Demo: the pluggable pipeline-schedule subsystem (DESIGN.md §5,
docs/schedules.md).

Runs the same tiny LM under all four compiled schedules — ``gpipe``,
``1f1b``, ``1f1b-interleaved`` (V=2) and the zero-bubble ``zb-h1``
(three-phase F/B/W table, executed through its forward projection) — on
a host-device pipe mesh, checks they produce identical losses/gradients
(they execute the same math, only the tick program differs), and prints
per-step wall time:

    PYTHONPATH=src python examples/pipeline_schedules.py [--stages 4]
"""
import argparse
import os
import time

# fake pipeline devices — must be set before jax initializes
_N_DEV = int(os.environ.get("PIPELINE_DEMO_DEVICES", "8"))
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + f" --xla_force_host_platform_device_count={_N_DEV}")

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402

from repro.configs import get_config                           # noqa: E402
from repro.launch.mesh import make_pipeline_mesh               # noqa: E402
from repro.models import init_lm, lm_loss                     # noqa: E402
from repro.runtime import (compile_schedule, make_pipeline_loss,   # noqa: E402
                           stage_split_params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--micro", type=int, default=8)
    ap.add_argument("--steps", type=int, default=3)
    args = ap.parse_args()

    P, m = args.stages, args.micro
    n_dev = len(jax.devices())
    mesh = make_pipeline_mesh(P, n_dev // P)
    cfg = get_config("qwen3-4b").reduced(n_layers=2 * P, d_model=128)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    Bm, S = 4, 32
    k = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(k, (m, Bm, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (m, Bm, S), 0, cfg.vocab_size),
    }
    flat = {k2: v.reshape(m * Bm, S) for k2, v in batch.items()}
    ref = float(lm_loss(params, flat, cfg))
    print(f"mesh={dict(mesh.shape)}  layers={cfg.n_layers}  m={m}")
    print(f"reference (non-pipelined executor-path) loss: {ref:.5f}\n")

    for sched, V in [("gpipe", 1), ("1f1b", 1), ("1f1b-interleaved", 2),
                     ("zb-h1", 1)]:
        prog = compile_schedule(sched, P, m, V if V > 1 else None)
        with mesh:
            ps = stage_split_params(params, P, V)
            fn = jax.jit(make_pipeline_loss(cfg, mesh, m, schedule=sched,
                                            n_chunks=V))
            loss, grads = jax.block_until_ready(fn(ps, batch))  # compile
            t0 = time.time()
            for _ in range(args.steps):
                loss, grads = jax.block_until_ready(fn(ps, batch))
            dt = (time.time() - t0) / args.steps
        print(f"{sched:18s} V={V}  ticks={prog.n_ticks:3d} "
              f"(bubble {prog.bubble_ticks})  loss={float(loss):.5f}  "
              f"Δref={abs(float(loss)-ref):.2e}  {dt*1e3:8.1f} ms/step")


if __name__ == "__main__":
    main()
