"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ref import flash_attention_ref, rmsnorm_ref, ssd_scan_ref

TOL = {jnp.float32: 3e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,S,H,KV,dh", [
    (1, 128, 2, 2, 32),     # MHA
    (2, 256, 4, 2, 64),     # GQA 2:1
    (1, 256, 8, 1, 64),     # MQA
    (2, 512, 4, 4, 128),    # MXU-aligned head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
def test_flash_attention_sweep(B, S, H, KV, dh, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("blocks", [(32, 128), (128, 32), (64, 64)])
def test_flash_attention_block_shapes(blocks):
    bq, bk = blocks
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 1, 4, 8, 16),
    (2, 128, 2, 8, 16, 32),
    (1, 256, 4, 64, 128, 64),   # production-like dims
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, H, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, H, N), dtype)
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol * 40, rtol=tol)


@pytest.mark.parametrize("shape", [(4, 128), (2, 37, 256), (1, 8, 8, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, shape, dtype)
    w = jax.random.normal(k2, shape[-1:], dtype)
    out = rmsnorm(x, w, interpret=True)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_ssd_kernel_in_model_block():
    """ssm_block(use_kernel=True) must match the jnp path."""
    from repro.configs import get_config
    from repro.models.ssm import init_ssm, ssm_block
    cfg = get_config("mamba2-370m").reduced().with_(ssm_chunk=16)
    p = init_ssm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    y0 = ssm_block(p, x, cfg, use_kernel=False)
    y1 = ssm_block(p, x, cfg, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-4,
                               rtol=2e-4)
