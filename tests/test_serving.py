"""Serving subsystem end-to-end (repro.serving + launch/serve.py).

The load-bearing guarantee: the paged continuous-batching engine is
**token-identical** to the dense-cache greedy reference for a mixed-length
request batch — same params, same prompts, byte-equal generations — while
holding KV for only the tokens actually cached.  On top of that: v3 plan
JSON round-trips with the serving section, PLN010 lints serving fields
against mesh arithmetic, and the SLO-axis search emits plans that certify
and carry self-consistent serving geometry.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import ModelConfig

TINY = ModelConfig(name="tiny-serve", arch_type="dense", n_layers=2,
                   d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                   vocab_size=128)


def _mixed_requests(rng, n, *, min_len=1, max_len=10, max_new=(2, 8)):
    from repro.launch.serve import Request
    reqs = []
    for i in range(n):
        plen = int(rng.integers(min_len, max_len + 1))
        prompt = rng.integers(0, TINY.vocab_size, size=plen).tolist()
        reqs.append(Request(i, prompt, int(rng.integers(*max_new))))
    return reqs


# ---------------------------------------------------------------------------
# paged engine == dense reference (the end-to-end differential)
# ---------------------------------------------------------------------------

def test_paged_engine_token_identical_to_dense_reference():
    """Mixed-length prompts, more requests than lanes (slot recycling),
    ragged max_new: every request's generation must equal the dense-cache
    greedy oracle token for token."""
    from repro.launch.serve import serve, serve_paged
    from repro.serving import EngineConfig

    rng = np.random.default_rng(0)
    reqs_paged = _mixed_requests(rng, 7)
    reqs_dense = [dataclasses.replace(r) if dataclasses.is_dataclass(r)
                  else type(r)(r.rid, list(r.prompt), r.max_new)
                  for r in reqs_paged]

    ecfg = EngineConfig(page_size=4, n_pages=24, decode_slots=3,
                        max_context=24, prefill_batch=2, prefill_chunk=4)
    metrics = serve_paged(TINY, reqs_paged, ecfg, seed=0, verbose=False)
    # dense oracle: every lane gets the full context (no paging, no reuse)
    serve(TINY, reqs_dense, batch=3, context=24, seed=0, verbose=False)

    for rp, rd in zip(reqs_paged, reqs_dense):
        assert rp.generated == rd.generated, (
            f"req {rp.rid}: paged {rp.generated} != dense {rd.generated}")
        assert rp.done and rd.done
        assert len(rp.generated) == rp.max_new

    summ = metrics.summary()
    assert summ["completed"] == len(reqs_paged)
    assert summ["new_tokens"] == sum(r.max_new for r in reqs_paged)
    assert summ["decode_steps"] >= 1 and summ["prefill_chunks"] >= 1
    assert 0.0 < summ["page_occupancy_max"] <= 1.0
    assert summ["ttft_ms_p50"] >= 0.0


def test_engine_arrivals_queueing_and_metrics():
    """Requests arriving over time stay queued until their arrival;
    queue-depth and occupancy telemetry reflect the contention."""
    from repro.launch.mesh import make_local_mesh
    from repro.models import init_lm
    from repro.serving import EngineConfig, ServeRequest, ServingEngine

    ecfg = EngineConfig(page_size=4, n_pages=8, decode_slots=2,
                        max_context=16, prefill_batch=2, prefill_chunk=4)
    params = jax.jit(lambda k: init_lm(k, TINY))(jax.random.PRNGKey(0))
    engine = ServingEngine(TINY, params, make_local_mesh(), ecfg)
    reqs = [ServeRequest(rid=f"r{i}", prompt=[3 + i, 5, 7], max_new=3,
                         arrival_s=0.0 if i < 2 else 0.01, deadline_ms=50.0)
            for i in range(5)]
    metrics = engine.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.tokens) == 3 for r in reqs)
    summ = metrics.summary()
    assert summ["completed"] == 5
    assert summ["queue_depth_max"] >= 1          # more requests than lanes
    assert max(metrics.page_occupancy) <= 1.0
    # per-request accounting: TTFT recorded before finish
    for rm in metrics.requests:
        assert rm.first_token_s is not None
        assert rm.finish_s >= rm.first_token_s
        assert rm.ttft_ms >= 0.0


def test_engine_rejects_oversized_prompt_and_unsupported_arch():
    from repro.launch.mesh import make_local_mesh
    from repro.models import init_lm
    from repro.serving import EngineConfig, ServeRequest, ServingEngine

    ecfg = EngineConfig(page_size=4, n_pages=8, decode_slots=2,
                        max_context=8, prefill_batch=2, prefill_chunk=4)
    params = jax.jit(lambda k: init_lm(k, TINY))(jax.random.PRNGKey(0))
    engine = ServingEngine(TINY, params, make_local_mesh(), ecfg)
    with pytest.raises(ValueError, match="exceeds max_context"):
        engine.run([ServeRequest(rid="big", prompt=list(range(9)),
                                 max_new=2)])
    ssm_cfg = dataclasses.replace(TINY, arch_type="ssm", ssm_state=8)
    with pytest.raises(NotImplementedError, match="paged serving"):
        ServingEngine(ssm_cfg, params, make_local_mesh(), ecfg)


def test_supports_paged_decode_gate_values():
    """MoE decoders pass the gate; SSM/hybrid/enc-dec are gated out —
    the predicate docs/serving.md cross-links."""
    from repro.models.transformer import supports_paged_decode
    moe_cfg = dataclasses.replace(TINY, arch_type="moe", n_experts=4,
                                  top_k=2)
    assert supports_paged_decode(moe_cfg)
    assert not supports_paged_decode(
        dataclasses.replace(TINY, arch_type="ssm", ssm_state=8))
    assert not supports_paged_decode(
        dataclasses.replace(TINY, arch_type="hybrid", ssm_state=8,
                            attn_every=2))
    assert not supports_paged_decode(
        dataclasses.replace(TINY, is_encoder_decoder=True, n_enc_layers=2))


def test_engine_config_validates_geometry():
    from repro.serving import EngineConfig
    with pytest.raises(ValueError, match="multiple"):
        EngineConfig(page_size=16, max_context=40)
    assert EngineConfig(page_size=16, max_context=64).pages_per_slot == 4


# ---------------------------------------------------------------------------
# plan JSON v3: serving section round-trip + lint
# ---------------------------------------------------------------------------

def _serving_plan(**over):
    from repro.core import ParallelPlan, ServingSection, enumerate_strategies
    sv = dict(slo_ms=30.0, page_size=16, max_context=256, decode_batch=8,
              prefill_chunk=32, decode_tp=2, decode_pp=2, prefill_tp=4,
              prefill_pp=1, kv_pool_pages=128)
    sv.update(over)
    s = enumerate_strategies(4)[0]
    return ParallelPlan(
        n_devices=8, pp_degree=2, partition=[4, 4], strategies=[s] * 8,
        global_batch=32, n_micro=4, schedule="1f1b",
        serving=ServingSection(**sv))


def test_v3_serving_roundtrip():
    from repro.core import PLAN_FORMAT_VERSION, ParallelPlan
    plan = _serving_plan()
    d = json.loads(plan.dumps())
    assert d["format_version"] == PLAN_FORMAT_VERSION == 5
    back = ParallelPlan.from_json(d)
    assert back.serving == plan.serving
    assert back.canonical_dumps() == plan.canonical_dumps()


def test_v2_plans_still_load_with_no_serving():
    from repro.core import ParallelPlan
    plan = _serving_plan()
    d = json.loads(plan.dumps())
    del d["serving"]
    d["format_version"] = 2
    back = ParallelPlan.from_json(d)
    assert back.serving is None


def test_detect_format_version_serving():
    from repro.analysis import detect_format_version
    d = json.loads(_serving_plan().dumps())
    assert detect_format_version(d) == 5
    d.pop("format_version")
    # unstamped + default sp_degree/seq_len: the serving section implies v3
    assert detect_format_version(d) == 3


def test_pln010_valid_serving_plan_certifies():
    from repro.analysis import verify_plan
    diags = verify_plan(_serving_plan())
    assert not [d for d in diags if d.severity == "error"], \
        [d.format() for d in diags]


@pytest.mark.parametrize("over,field", [
    (dict(decode_tp=3, decode_pp=2), "decode_tp"),       # 6 does not | 8
    (dict(prefill_tp=5), "prefill_tp"),
    (dict(decode_tp=0), "decode_tp"),
    (dict(page_size=0), "page_size"),
    (dict(max_context=250), "max_context"),              # not page multiple
    (dict(decode_batch=0), "decode_batch"),
    (dict(kv_pool_pages=4), "kv_pool_pages"),            # < decode_batch
    (dict(prefill_chunk=0), "prefill_chunk"),
    (dict(slo_ms=0.0), "slo_ms"),
])
def test_pln010_rejects_bad_serving_fields(over, field):
    from repro.analysis import verify_plan
    diags = verify_plan(_serving_plan(**over))
    errs = [d for d in diags if d.severity == "error" and d.rule == "PLN010"]
    assert errs, f"expected PLN010 error for {over}"
    assert any(field in d.location for d in errs), \
        [d.format() for d in errs]


def test_pln010_warnings():
    from repro.analysis import verify_plan
    # non-power-of-two page size and SLO-exceeding prediction warn
    diags = verify_plan(_serving_plan(page_size=12, max_context=240,
                                      est_tok_ms=45.0))
    warns = [d for d in diags if d.rule == "PLN010"
             and d.severity == "warning"]
    assert {("page_size" in d.location) or ("est_tok_ms" in d.location)
            for d in warns} == {True}
    assert len(warns) == 2


# ---------------------------------------------------------------------------
# SLO-axis search
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def slo_points():
    from repro.core import galvatron_variant, paper_8gpu
    from repro.core.layerspec import dense_layer
    from repro.serving import ServingPlanSearch

    specs = [dense_layer(f"l{i}", 512, 1024, 16, 16, 4096,
                         store_attn_matrix=True) for i in range(8)]
    cfg = galvatron_variant("bmw")
    cfg.batch_grid = [8, 16]
    cfg.n_bins = 64
    cfg.micro_candidates = 2
    search = ServingPlanSearch(specs, paper_8gpu(), config=cfg)
    points, frontier = search.sweep_slos([20.0, 60.0], max_context=512)
    return search, points, frontier


def test_slo_sweep_emits_certifying_v3_plans(slo_points):
    from repro.analysis import verify_plan_json
    search, points, frontier = slo_points
    assert len(points) == 2
    feasible = [p for p in points if p.feasible]
    assert feasible, "no SLO point feasible on the 8-GPU paper cluster"
    for pt in feasible:
        d = json.loads(pt.plan.dumps())
        assert d["format_version"] == 5
        diags = verify_plan_json(d)
        assert not [x for x in diags if x.severity == "error"], \
            [x.format() for x in diags]
        sv = pt.plan.serving
        assert sv.slo_ms == pt.slo_ms
        assert sv.max_context % sv.page_size == 0
        assert sv.kv_pool_pages >= sv.decode_batch
        assert sv.decode_tp * sv.decode_pp <= pt.plan.n_devices
        assert sv.est_tok_per_s > 0


def test_slo_budget_mapping_monotone(slo_points):
    """A looser SLO is a larger per-step byte budget, and the derived
    decode batch never shrinks as the SLO loosens."""
    search, points, frontier = slo_points
    assert points[1].budget_bytes > points[0].budget_bytes
    if points[0].feasible and points[1].feasible:
        assert (points[1].plan.serving.decode_batch
                >= points[0].plan.serving.decode_batch)


def test_serving_stats_exact_vs_heuristic():
    """from_model_config (exact) and from_layer_specs (heuristic from the
    boundary bytes) must agree on the order of magnitude of KV traffic."""
    from repro.configs import get_config
    from repro.configs.specs import layerspecs_for
    from repro.serving import ServingModelStats

    cfg = get_config("qwen3-4b")
    exact = ServingModelStats.from_model_config(cfg)
    heur = ServingModelStats.from_layer_specs(layerspecs_for(cfg, 1024))
    assert exact.param_bytes > 0 and exact.kv_bytes_per_token > 0
    assert heur.kv_bytes_per_token > 0
    ratio = exact.kv_bytes_per_token / heur.kv_bytes_per_token
    assert 0.05 < ratio < 20.0
