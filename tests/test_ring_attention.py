"""Ring-attention sequence parallelism + flash-attention ragged-length
differentials.

Single-process tests drive the kernels in interpret mode against the
``sdpa``-style oracle (``kernels/ref.py``); the ring kernel's
token-identity claim is certified on an 8-fake-device CPU mesh in a
subprocess (slow marker), matching test_distributed.py's pattern.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref
from repro.kernels.ring_attention import ring_flash_attention

TOL = 3e-5


def _qkv(B, S, T, H, KV, dh, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, T, KV, dh), dtype)
    v = jax.random.normal(ks[2], (B, T, KV, dh), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# satellite 1/3: ragged (non-block-multiple) lengths vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,T", [
    (130, 130),    # just past one 128 block
    (257, 257),    # just past two blocks
    (200, 200),    # mid-block tail
    (20, 20),      # shorter than one block
    (130, 70),     # ragged cross-attention lengths
    (96, 200),     # S < T, both non-multiples of 128
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 48)])
def test_flash_ragged_lengths_match_oracle(S, T, causal, window):
    if window is not None and window > T:
        pytest.skip("window > T raises by design (validation test below)")
    q, k, v = _qkv(2, S, T, 4, 2, 32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    assert out.shape == (2, S, 4, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=TOL, rtol=TOL)


@pytest.mark.parametrize("H,KV", [(8, 8), (8, 2), (8, 1), (6, 3)])
def test_flash_gqa_ratios_ragged(H, KV):
    q, k, v = _qkv(1, 100, 100, H, KV, 32, seed=1)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=TOL, rtol=TOL)


def test_flash_window_at_non_block_boundary():
    # window edge lands mid-block AND sequence has a padded tail
    q, k, v = _qkv(1, 200, 200, 2, 2, 32, seed=2)
    for w in (1, 7, 100, 200):
        out = flash_attention(q, k, v, causal=True, window=w, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=True, window=w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=TOL, rtol=TOL)


# ---------------------------------------------------------------------------
# satellite 2: validation + all-masked rows
# ---------------------------------------------------------------------------

def test_flash_rejects_bad_gqa_and_window():
    q, k, v = _qkv(1, 64, 64, 4, 4, 32)
    with pytest.raises(ValueError, match="divisible"):
        flash_attention(q, k[:, :, :3], v[:, :, :3], interpret=True)
    with pytest.raises(ValueError, match="window"):
        flash_attention(q, k, v, window=0, interpret=True)
    with pytest.raises(ValueError, match="window"):
        flash_attention(q, k, v, window=-5, interpret=True)
    with pytest.raises(ValueError, match="window"):
        flash_attention(q, k, v, window=65, interpret=True)


def test_flash_all_masked_rows_are_exact_zeros():
    # causal + window=1 sees only k == q; queries past T have no keys at
    # all — they must come out as exact zeros, not acc / 1e-20 noise
    q, k, v = _qkv(1, 64, 32, 2, 2, 32, seed=3)
    out = np.asarray(flash_attention(q, k, v, causal=True, window=1,
                                     interpret=True))
    assert (out[:, 32:] == 0.0).all()
    ref = np.asarray(flash_attention_ref(q, k, v, causal=True, window=1))
    np.testing.assert_allclose(out[:, :32], ref[:, :32], atol=TOL, rtol=TOL)


# ---------------------------------------------------------------------------
# tentpole: ring attention
# ---------------------------------------------------------------------------

def test_ring_degenerate_axis_size_1_is_flash():
    q, k, v = _qkv(1, 128, 128, 2, 2, 32, seed=4)
    out = ring_flash_attention(q, k, v, axis_name="seq", axis_size=1,
                               causal=True, interpret=True)
    ref = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_ring_validates_global_shapes():
    q, k, v = _qkv(1, 32, 32, 4, 2, 32)
    with pytest.raises(ValueError, match="window"):
        ring_flash_attention(q, k, v, axis_name="seq", axis_size=1,
                             window=0, interpret=True)


def test_model_attention_ring_impl_matches_ref():
    # models/attention.py routes impl="ring" through the ring kernel; at
    # sp_size=1 (no mesh needed) it must agree with the sdpa reference
    from repro.models.attention import attention, init_attention
    from repro.models.common import ModelConfig
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                      dtype="float32")
    p = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, 64))
    pos = jnp.broadcast_to(jnp.arange(96), (2, 96))
    out = attention(p, x, pos, cfg, impl="ring", sp_size=1)
    ref = attention(p, x, pos, cfg, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=TOL, rtol=TOL)


@pytest.mark.slow
def test_ring_token_identical_on_8_device_mesh():
    """Ring output must match the single-device flash kernel token-for-token
    (fp32 allclose + exact argmax) — the PR's acceptance criterion."""
    run_subprocess("""
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.runtime.pipeline import shard_map
from repro.kernels.ring_attention import ring_flash_attention
from repro.kernels.flash_attention import flash_attention

devs = np.array(jax.devices()).reshape(8)
mesh = Mesh(devs, ("seq",))
ks = jax.random.split(jax.random.PRNGKey(0), 3)

for (B, S, H, KV, dh, causal, window) in [
    (1, 256, 2, 2, 32, True, None),     # causal MHA
    (2, 512, 4, 2, 32, True, 96),       # sliding window crossing shards
    (1, 256, 4, 1, 64, False, None),    # bidirectional MQA
    (1, 64, 2, 2, 32, True, 5),         # tiny window, 8-token local shards
]:
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, KV, dh))
    v = jax.random.normal(ks[2], (B, S, KV, dh))
    fn = shard_map(
        lambda q, k, v: ring_flash_attention(
            q, k, v, axis_name="seq", axis_size=8, causal=causal,
            window=window, block_q=32, block_k=32, interpret=True),
        mesh, in_specs=(P(None, "seq"), P(None, "seq"), P(None, "seq")),
        out_specs=P(None, "seq"))
    out = np.asarray(fn(q, k, v))
    ref = np.asarray(flash_attention(q, k, v, causal=causal, window=window,
                                     block_q=32, block_k=32, interpret=True))
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
    assert (np.argmax(out.reshape(-1, dh), -1)
            == np.argmax(ref.reshape(-1, dh), -1)).all()
print("RING-IDENTITY-OK")
""", devices=8)


@pytest.mark.slow
def test_ring_attention_on_mesh_and_seq_shardings():
    """runtime/sequence.py executes a searched sp_degree: global arrays in,
    sharded ring attention out; batch_shardings puts token dims on seq."""
    run_subprocess("""
import jax, numpy as np
import jax.numpy as jnp
from repro.launch.mesh import make_ring_mesh
from repro.runtime import ShardPolicy, batch_shardings, ring_attention_on_mesh, seq_axis_size
from repro.kernels.flash_attention import flash_attention

mesh = make_ring_mesh(4, n_data=2)
assert seq_axis_size(mesh) == 4
ks = jax.random.split(jax.random.PRNGKey(1), 3)
q = jax.random.normal(ks[0], (2, 256, 2, 32))
k = jax.random.normal(ks[1], (2, 256, 2, 32))
v = jax.random.normal(ks[2], (2, 256, 2, 32))
fn = ring_attention_on_mesh(mesh, causal=True, block_q=32, block_k=32)
out = np.asarray(fn(q, k, v))
ref = np.asarray(flash_attention(q, k, v, causal=True, block_q=32,
                                 block_k=32, interpret=True))
np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

# batch token dims shard over seq only when the policy says sp > 1
pol = ShardPolicy(sp_degree=4)
bs = batch_shardings({"x": jax.ShapeDtypeStruct((4, 256, 8), jnp.float32)},
                     mesh, pol)["x"]
assert "seq" in str(bs.spec), bs.spec
bs1 = batch_shardings({"x": jax.ShapeDtypeStruct((4, 256, 8), jnp.float32)},
                      mesh)["x"]
assert "seq" not in str(bs1.spec), bs1.spec
print("SEQ-EXEC-OK")
""", devices=8)
