"""Schedule × vpp search axis: the optimizer treats the pipeline schedule
as a searched dimension and picks interleaved virtual stages when the
bubble dominates (ISSUE acceptance: tight memory, small m, large P)."""
import pytest

from repro.core import (GalvatronOptimizer, galvatron_variant, paper_8gpu,
                        bubble_fraction, inflight_microbatches,
                        pipeline_iter_time)
from repro.core.layerspec import dense_layer

GB = 1024 ** 3


def _specs(n=16):
    return [dense_layer(f"l{i}", 512, 1024, 16, 16, 4096,
                        store_attn_matrix=True) for i in range(n)]


def _search(schedules, *, budget_gb=3, vpp=(2,), fixed_pp=8, batch=8,
            specs=None):
    cfg = galvatron_variant("bmw")
    cfg.batch_grid = [batch]
    cfg.n_bins = 128
    cfg.micro_candidates = 2
    cfg.fixed_pp = fixed_pp
    cfg.schedules = schedules
    cfg.vpp_candidates = vpp
    opt = GalvatronOptimizer(specs or _specs(),
                             paper_8gpu().with_budget(budget_gb * GB), cfg)
    return opt.optimize()


def test_bubble_dominated_search_selects_interleaved():
    # small m (= P = 8), tight 3G budget: the (P-1)/m bubble dominates and
    # interleaving halves it — the search must find that
    base = _search(("1f1b",))
    both = _search(("1f1b", "1f1b-interleaved"))
    assert base is not None and both is not None
    assert both.schedule == "1f1b-interleaved"
    assert both.vpp_degree > 1
    # est_iter_time reflects the reduced bubble term
    assert both.est_iter_time < base.est_iter_time
    # consistent with the analytic model: bubble fraction halves at V=2
    assert bubble_fraction(8, 8, 2) == pytest.approx(
        bubble_fraction(8, 8, 1) / 2)


def test_interleaved_plan_is_serializable_and_layoutable():
    plan = _search(("1f1b", "1f1b-interleaved"))
    # every stage can be cut into V non-empty chunks
    assert min(plan.partition) >= plan.vpp_degree
    import json

    from repro.core import ParallelPlan
    plan2 = ParallelPlan.loads(plan.dumps())
    assert plan2 == plan
    assert plan2.vpp_degree == plan.vpp_degree


def test_interleaved_dropped_when_layers_too_few():
    # P * V > L: the candidate must be skipped, not crash
    plan = _search(("1f1b", "1f1b-interleaved"), vpp=(4,), specs=_specs(16),
                   fixed_pp=8)
    assert plan is not None
    assert plan.schedule == "1f1b"          # 8 * 4 > 16 layers
    assert plan.vpp_degree == 1


def test_interleaved_requires_full_microbatch_groups():
    # B=6, P=4 -> m=6 (ragged last group): the compiled interleaved
    # program's bubble exceeds the analytic (P-1)/(m*V) term, so the
    # candidate must be dropped rather than oversold
    plan = _search(("1f1b", "1f1b-interleaved"), fixed_pp=4, batch=6,
                   budget_gb=8)
    assert plan is not None
    assert plan.schedule == "1f1b"
    assert plan.vpp_degree == 1


def test_gpipe_axis_still_searched():
    plan = _search(("gpipe",), budget_gb=8)
    assert plan is not None and plan.schedule == "gpipe"


def test_pipeline_iter_time_generalizes_eq9():
    ts, ns = [1.0, 1.2, 1.1, 1.0], [0.9, 1.1, 1.0, 0.9]
    # V=1 is exactly the seed Eq. 9 form
    assert pipeline_iter_time(ts, ns, 8, 1) == pytest.approx(
        7 * 1.1 + sum(ts))
    # V=2 halves the non-critical drain contribution
    assert pipeline_iter_time(ts, ns, 8, 2) == pytest.approx(
        7 * 1.1 + 1.2 + (sum(ts) - 1.2) / 2)
    # homogeneous stages: m*t + (P-1)*t/V
    assert pipeline_iter_time([2.0] * 4, [2.0] * 4, 8, 2) == pytest.approx(
        8 * 2.0 + 3 * 2.0 / 2)


def test_interleaved_inflight_memory_exceeds_plain_1f1b_deep_stages():
    # interleaving trades memory for bubble: deeper stages hold strictly
    # more in-flight activation sets than plain 1F1B
    P, m = 8, 64
    for i in range(P):
        plain = inflight_microbatches(i, P, m, "1f1b")
        inter = inflight_microbatches(i, P, m, "1f1b-interleaved", vpp=2)
        assert inter >= plain - 1e-12, i


# ---------------------------------------------------------------------------
# zero-bubble (ZB-H1) on the search axis
# ---------------------------------------------------------------------------

def test_zb_h1_selected_when_bubble_dominates():
    # small m (= P = 8): the (P-1)/m bubble dominates and zb-h1 cuts it to
    # a third — the search must find that when memory allows
    base = _search(("1f1b",), budget_gb=8)
    both = _search(("1f1b", "zb-h1"), budget_gb=8)
    assert base is not None and both is not None
    assert both.schedule == "zb-h1"
    assert both.vpp_degree == 1
    assert both.est_iter_time < base.est_iter_time


def test_zb_h1_modeled_bubble_leq_1f1b_everywhere():
    # ISSUE acceptance: modeled bubble fraction <= 1f1b's at equal (P, m, V)
    for P in (2, 4, 8):
        for m in (P, 2 * P, 8 * P):
            zb = bubble_fraction(P, m, 1, schedule="zb-h1")
            f = bubble_fraction(P, m, 1, schedule="1f1b")
            assert zb <= f + 1e-15
            assert zb == pytest.approx(f / 3)


def test_zb_h1_inflight_memory_exceeds_1f1b_every_stage():
    # the price of the W split: deferred weight-grad stash on every stage
    for P, m in [(4, 4), (4, 8), (8, 64)]:
        for i in range(P):
            zb = inflight_microbatches(i, P, m, "zb-h1")
            f = inflight_microbatches(i, P, m, "1f1b")
            assert zb > f, (P, m, i)


def test_zb_h1_dropped_on_degenerate_pipelines():
    # P=1 (no bubble to fill): fall back instead of paying W memory
    plan = _search(("zb-h1",), fixed_pp=1, budget_gb=8)
    assert plan is not None and plan.schedule == "1f1b"
    # m < P never occurs from _micro_candidates (m starts at P), so a
    # zb-only request on a deep pipe still searches zb itself
    plan = _search(("zb-h1",), fixed_pp=8, budget_gb=8)
    assert plan is not None and plan.schedule == "zb-h1"


def test_zb_h1_plan_serializes_and_compiles():
    from repro.core import ParallelPlan
    from repro.runtime.plan_bridge import schedule_program_from_plan

    plan = _search(("zb-h1",), budget_gb=8)
    assert plan.schedule == "zb-h1"
    plan2 = ParallelPlan.loads(plan.dumps())
    assert plan2 == plan
    prog = schedule_program_from_plan(plan2)
    assert prog.is_three_phase
    assert prog.n_stages == plan.pp_degree
    assert prog.n_micro == plan.n_micro


def test_pipeline_iter_time_zb_h1_drain_refill():
    ts, ns = [1.0, 1.2, 1.1, 1.0], [0.9, 1.1, 1.0, 0.9]
    # zb-h1 divides the non-critical drain contribution by 3
    assert pipeline_iter_time(ts, ns, 8, 1, schedule="zb-h1") == pytest.approx(
        7 * 1.1 + 1.2 + (sum(ts) - 1.2) / 3)
    # homogeneous stages: m*t + (P-1)*t/3 — the (P-1)/(3m) bubble
    assert pipeline_iter_time([2.0] * 4, [2.0] * 4, 8, 1,
                              schedule="zb-h1") == pytest.approx(
        8 * 2.0 + 3 * 2.0 / 3)
