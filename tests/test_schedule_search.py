"""Schedule × vpp search axis: the optimizer treats the pipeline schedule
as a searched dimension and picks interleaved virtual stages when the
bubble dominates (ISSUE acceptance: tight memory, small m, large P)."""
import pytest

from repro.core import (GalvatronOptimizer, galvatron_variant, paper_8gpu,
                        bubble_fraction, inflight_microbatches,
                        pipeline_iter_time)
from repro.core.layerspec import dense_layer

GB = 1024 ** 3


def _specs(n=16):
    return [dense_layer(f"l{i}", 512, 1024, 16, 16, 4096,
                        store_attn_matrix=True) for i in range(n)]


def _search(schedules, *, budget_gb=3, vpp=(2,), fixed_pp=8, batch=8,
            specs=None):
    cfg = galvatron_variant("bmw")
    cfg.batch_grid = [batch]
    cfg.n_bins = 128
    cfg.micro_candidates = 2
    cfg.fixed_pp = fixed_pp
    cfg.schedules = schedules
    cfg.vpp_candidates = vpp
    opt = GalvatronOptimizer(specs or _specs(),
                             paper_8gpu().with_budget(budget_gb * GB), cfg)
    return opt.optimize()


def test_bubble_dominated_search_selects_interleaved():
    # small m (= P = 8), tight 3G budget: the (P-1)/m bubble dominates and
    # interleaving halves it — the search must find that
    base = _search(("1f1b",))
    both = _search(("1f1b", "1f1b-interleaved"))
    assert base is not None and both is not None
    assert both.schedule == "1f1b-interleaved"
    assert both.vpp_degree > 1
    # est_iter_time reflects the reduced bubble term
    assert both.est_iter_time < base.est_iter_time
    # consistent with the analytic model: bubble fraction halves at V=2
    assert bubble_fraction(8, 8, 2) == pytest.approx(
        bubble_fraction(8, 8, 1) / 2)


def test_interleaved_plan_is_serializable_and_layoutable():
    plan = _search(("1f1b", "1f1b-interleaved"))
    # every stage can be cut into V non-empty chunks
    assert min(plan.partition) >= plan.vpp_degree
    import json

    from repro.core import ParallelPlan
    plan2 = ParallelPlan.loads(plan.dumps())
    assert plan2 == plan
    assert plan2.vpp_degree == plan.vpp_degree


def test_interleaved_dropped_when_layers_too_few():
    # P * V > L: the candidate must be skipped, not crash
    plan = _search(("1f1b", "1f1b-interleaved"), vpp=(4,), specs=_specs(16),
                   fixed_pp=8)
    assert plan is not None
    assert plan.schedule == "1f1b"          # 8 * 4 > 16 layers
    assert plan.vpp_degree == 1


def test_interleaved_requires_full_microbatch_groups():
    # B=6, P=4 -> m=6 (ragged last group): the compiled interleaved
    # program's bubble exceeds the analytic (P-1)/(m*V) term, so the
    # candidate must be dropped rather than oversold
    plan = _search(("1f1b", "1f1b-interleaved"), fixed_pp=4, batch=6,
                   budget_gb=8)
    assert plan is not None
    assert plan.schedule == "1f1b"
    assert plan.vpp_degree == 1


def test_gpipe_axis_still_searched():
    plan = _search(("gpipe",), budget_gb=8)
    assert plan is not None and plan.schedule == "gpipe"


def test_pipeline_iter_time_generalizes_eq9():
    ts, ns = [1.0, 1.2, 1.1, 1.0], [0.9, 1.1, 1.0, 0.9]
    # V=1 is exactly the seed Eq. 9 form
    assert pipeline_iter_time(ts, ns, 8, 1) == pytest.approx(
        7 * 1.1 + sum(ts))
    # V=2 halves the non-critical drain contribution
    assert pipeline_iter_time(ts, ns, 8, 2) == pytest.approx(
        7 * 1.1 + 1.2 + (sum(ts) - 1.2) / 2)
    # homogeneous stages: m*t + (P-1)*t/V
    assert pipeline_iter_time([2.0] * 4, [2.0] * 4, 8, 2) == pytest.approx(
        8 * 2.0 + 3 * 2.0 / 2)


def test_interleaved_inflight_memory_exceeds_plain_1f1b_deep_stages():
    # interleaving trades memory for bubble: deeper stages hold strictly
    # more in-flight activation sets than plain 1F1B
    P, m = 8, 64
    for i in range(P):
        plain = inflight_microbatches(i, P, m, "1f1b")
        inter = inflight_microbatches(i, P, m, "1f1b-interleaved", vpp=2)
        assert inter >= plain - 1e-12, i
