"""Docs cannot rot: every fenced ``python`` block in ``docs/*.md`` is
extracted and executed, and every relative link in ``docs/**/*.md`` and
``README.md`` must resolve to a real file.

Rules for doc authors:
  * blocks tagged exactly ```` ```python ```` are executed in a fresh
    namespace (same process — keep them self-contained and fast, pure
    ``repro.core`` / ``runtime.schedules`` where possible);
  * use ```` ```bash ```` / ```` ```text ```` for illustrative snippets
    that must not run;
  * relative links may point at files or directories anywhere in the
    repo; ``#anchors`` and absolute URLs are not checked.
"""
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOCS = sorted((REPO / "docs").glob("**/*.md"))
LINKED_MD = DOCS + [REPO / "README.md"]

_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)
# [text](target) — skip absolute URLs and pure anchors
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _python_blocks():
    out = []
    for path in DOCS:
        for i, block in enumerate(_FENCE.findall(path.read_text())):
            out.append(pytest.param(
                path, block, id=f"{path.name}-block{i}"))
    return out


def test_docs_exist_and_have_examples():
    names = {p.name for p in DOCS}
    assert {"architecture.md", "search.md", "schedules.md",
            "plan-format.md"} <= names
    assert _python_blocks(), "docs/ lost all executable examples"


@pytest.mark.parametrize("path,block", _python_blocks())
def test_docs_python_examples_execute(path, block):
    code = compile(block, f"{path.name}:example", "exec")
    exec(code, {"__name__": f"docs_example_{path.stem}"})


def test_docs_search_cli_help_embed_is_current(monkeypatch, capsys):
    """docs/search.md embeds the CLI's usage + options sections; regenerate
    them from the live parser (at the same 80-column wrap) and require a
    byte match, so a flag rename/re-help can't leave the doc stale."""
    import sys

    from repro.launch import search as search_cli

    monkeypatch.setenv("COLUMNS", "80")
    # argparse derives prog (and hence usage-block wrapping) from argv[0]
    monkeypatch.setattr(sys, "argv", ["search.py"])
    with pytest.raises(SystemExit):
        search_cli.main(["--help"])
    help_text = capsys.readouterr().out
    lines = help_text.splitlines()
    usage = "\n".join(lines[:lines.index("")])
    options = help_text[help_text.index("options:"):].rstrip("\n")
    expected = usage + "\n\n" + options + "\n"
    doc = (REPO / "docs" / "search.md").read_text()
    m = re.search(r"```text\n(usage: search\.py.*?)```\n", doc, re.S)
    assert m, "docs/search.md lost its embedded --help block"
    assert m.group(1) == expected, (
        "docs/search.md --help embed is stale; regenerate with "
        "COLUMNS=80 python -m repro.launch.search --help")


def test_docs_analysis_cli_help_embed_is_current(monkeypatch, capsys):
    """docs/analysis.md embeds the lint CLI's --help; regenerate from the
    live parser at the same wrap and require a byte match."""
    from repro.launch import lint as lint_cli

    monkeypatch.setenv("COLUMNS", "80")
    with pytest.raises(SystemExit):
        lint_cli.main(["--help"])
    expected = capsys.readouterr().out
    doc = (REPO / "docs" / "analysis.md").read_text()
    m = re.search(r"```text\n(usage: python -m repro\.analysis.*?)```\n",
                  doc, re.S)
    assert m, "docs/analysis.md lost its embedded --help block"
    assert m.group(1) == expected, (
        "docs/analysis.md --help embed is stale; regenerate with "
        "COLUMNS=80 python -m repro.analysis --help")


def test_docs_serving_cli_help_embed_is_current(monkeypatch, capsys):
    """docs/serving.md embeds serve.py's --help; regenerate from the live
    parser at the same wrap and require a byte match."""
    from repro.launch import serve as serve_cli

    monkeypatch.setenv("COLUMNS", "80")
    with pytest.raises(SystemExit):
        serve_cli.main(["--help"])
    expected = capsys.readouterr().out
    doc = (REPO / "docs" / "serving.md").read_text()
    m = re.search(r"```text\n(usage: serve\.py.*?)```\n", doc, re.S)
    assert m, "docs/serving.md lost its embedded --help block"
    assert m.group(1) == expected, (
        "docs/serving.md --help embed is stale; regenerate with "
        "COLUMNS=80 python -m repro.launch.serve --help")


@pytest.mark.parametrize("path", LINKED_MD, ids=lambda p: p.name)
def test_docs_relative_links_resolve(path):
    assert path.exists(), path
    broken = []
    for target in _LINK.findall(path.read_text()):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target) or target.startswith("#"):
            continue                      # absolute URL / in-page anchor
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).resolve().exists():
            broken.append(target)
    assert not broken, f"broken relative links in {path}: {broken}"
