"""Profiled cost-model constants: collective microbenchmark profiles attach
to a ClusterSpec, route through the cost model identically in the scalar and
vectorized paths, and persist in a per-fingerprint JSON cache."""
import json

import pytest

from repro.core import (CollectiveProfile, CostModel, enumerate_strategies,
                        paper_8gpu, paper_16gpu_low)
from repro.core.hardware import COLLECTIVE_KINDS
from repro.core.layerspec import dense_layer, head_layer
from repro.core.profiler import (cached_collective_profiles,
                                 default_profile_cache_path,
                                 load_collective_profiles,
                                 profile_collectives,
                                 save_collective_profiles)

GB = 1024 ** 3

PROFILES = {
    "all_reduce": CollectiveProfile(latency_s=25e-6, bus_bandwidth=180e9,
                                    n_samples=3),
    "ppermute": CollectiveProfile(latency_s=8e-6, bus_bandwidth=220e9,
                                  n_samples=3),
}


# ---------------------------------------------------------------------------
# ClusterSpec.with_profiles / coefficient selection
# ---------------------------------------------------------------------------

def test_with_profiles_roundtrip_and_selection():
    cluster = paper_8gpu()
    prof = cluster.with_profiles(PROFILES)
    assert prof.profiles() == PROFILES
    assert cluster.profiles() == {}             # original untouched (frozen)
    # in-island group of a profiled kind: the measured pair
    lat, bw = prof.collective_coeffs("all_reduce", 4)
    assert (lat, bw) == (25e-6, 180e9)
    # unprofiled kind, degenerate group, cross-island group: analytic
    assert prof.collective_coeffs("all_gather", 4) \
        == (0.0, cluster.bandwidth_for_group(4))
    assert prof.collective_coeffs("all_reduce", 1) \
        == (0.0, cluster.bandwidth_for_group(1))
    big = prof.island_size * 2
    assert prof.collective_coeffs("all_reduce", big) \
        == (0.0, cluster.bandwidth_for_group(big))


def test_no_profiles_is_analytic_identity():
    cluster = paper_16gpu_low()
    for kind in COLLECTIVE_KINDS:
        for g in (1, 2, 8, 16):
            assert cluster.collective_coeffs(kind, g) \
                == (0.0, cluster.bandwidth_for_group(g))


# ---------------------------------------------------------------------------
# scalar vs vectorized cost tables under latency profiles
# ---------------------------------------------------------------------------

def test_tables_match_scalar_with_latency_profiles():
    """The profiled latency terms must hit the vectorized table builder and
    the scalar ``layer_costs`` identically — the byte-identity chain from
    backends down to costs rests on this."""
    cluster = paper_8gpu().with_profiles(PROFILES)
    cm = CostModel(cluster)
    specs = [dense_layer(f"l{i}", 256, 512, 8, 8, 2048,
                         store_attn_matrix=bool(i % 2)) for i in range(4)]
    specs.append(head_layer("head", 256, 512, 32000))
    strategies = enumerate_strategies(8)
    for inflight in (1, 3):
        tb = cm.layer_cost_tables(specs, strategies, 8.0, inflight=inflight)
        for l, sp in enumerate(specs):
            for j, s in enumerate(strategies):
                c = cm.layer_costs(sp, s, 8.0, inflight=inflight)
                assert tb.time_sync[l, j] == pytest.approx(c.time, rel=1e-9)
                assert tb.time_nosync[l, j] == pytest.approx(
                    c.time_nosync, rel=1e-9)
                assert tb.mem_ms[l, j] == pytest.approx(c.mem_ms, rel=1e-9)


def test_profiles_change_costs():
    """Sanity: a profile with real latency/bandwidth actually shifts the
    predicted communication time (the wiring is not dead)."""
    spec = dense_layer("l0", 512, 1024, 16, 16, 4096)
    base = CostModel(paper_8gpu())
    slow = CostModel(paper_8gpu().with_profiles({
        "all_reduce": CollectiveProfile(latency_s=5e-3, bus_bandwidth=1e9)}))
    strategies = enumerate_strategies(4)
    tp = next(s for s in strategies if s.tp > 1)
    assert slow.layer_costs(spec, tp, 8.0).time \
        > base.layer_costs(spec, tp, 8.0).time


# ---------------------------------------------------------------------------
# JSON cache
# ---------------------------------------------------------------------------

def test_cache_miss_measures_and_writes(tmp_path):
    path = tmp_path / "collectives.json"
    calls = []

    def fake_profile():
        calls.append(1)
        return dict(PROFILES)

    got = cached_collective_profiles(path, fingerprint="test:fake:8",
                                     profile_fn=fake_profile)
    assert got == PROFILES and len(calls) == 1
    # hit: served from disk, the profiler is NOT re-run
    again = cached_collective_profiles(
        path, fingerprint="test:fake:8",
        profile_fn=lambda: pytest.fail("cache hit must not re-profile"))
    assert again == PROFILES
    # refresh: forced re-measure overwrites the entry
    newer = {"all_reduce": CollectiveProfile(1e-6, 300e9, 5)}
    got = cached_collective_profiles(path, fingerprint="test:fake:8",
                                     refresh=True, profile_fn=lambda: newer)
    assert got == newer
    assert load_collective_profiles(path)["test:fake:8"] == newer


def test_cache_merges_fingerprints(tmp_path):
    path = tmp_path / "collectives.json"
    save_collective_profiles(path, {"other:machine:4": PROFILES})
    cached_collective_profiles(path, fingerprint="this:machine:8",
                               profile_fn=lambda: dict(PROFILES))
    on_disk = load_collective_profiles(path)
    assert set(on_disk) == {"other:machine:4", "this:machine:8"}


def test_cache_caches_empty_measurement(tmp_path):
    """Single-device hosts measure {} — cached too, so they don't re-probe
    on every run."""
    path = tmp_path / "collectives.json"
    assert cached_collective_profiles(path, fingerprint="cpu:cpu:1",
                                      profile_fn=lambda: {}) == {}
    assert cached_collective_profiles(
        path, fingerprint="cpu:cpu:1",
        profile_fn=lambda: pytest.fail("empty result must be cached")) == {}


def test_corrupt_cache_remeasures(tmp_path):
    path = tmp_path / "collectives.json"
    path.write_text("{not json")
    got = cached_collective_profiles(path, fingerprint="test:fake:8",
                                     profile_fn=lambda: dict(PROFILES))
    assert got == PROFILES
    assert load_collective_profiles(path)["test:fake:8"] == PROFILES


def test_default_cache_path_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_COLLECTIVES_CACHE", str(tmp_path / "c.json"))
    assert default_profile_cache_path() == tmp_path / "c.json"


def test_profile_collectives_safe_on_single_device():
    """CPU CI has one device: the microbenchmark degrades to {} instead of
    crashing (callers keep the analytic constants)."""
    import jax
    if jax.local_device_count() >= 2:
        pytest.skip("multi-device host: collectives are measurable")
    assert profile_collectives() == {}


def test_profile_json_roundtrip(tmp_path):
    path = tmp_path / "collectives.json"
    save_collective_profiles(path, {"fp:x:2": PROFILES})
    loaded = load_collective_profiles(path)["fp:x:2"]
    assert loaded == PROFILES
    # unknown kinds in the file are dropped, known fields survive verbatim
    raw = json.loads(path.read_text())
    raw["fp:x:2"]["bogus_collective"] = {"latency_s": 1, "bus_bandwidth": 1}
    path.write_text(json.dumps(raw))
    assert set(load_collective_profiles(path)["fp:x:2"]) == set(PROFILES)
