"""Plan verifier (repro.analysis.plan_lint): structured loading errors,
format-version policy, and property-based fuzzing over random degree
tuples (hypothesis when installed, the deterministic shim otherwise).

Also pins the satellite error-handling contract: ``ParallelPlan.from_json``
raises :class:`PlanFormatError` naming the offending field (never a bare
``KeyError``), and ``runtime/plan_bridge.py`` wraps uncompilable schedule
combos in a structured ``DiagnosticError``."""
import json
import pathlib

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.analysis import (DiagnosticError, detect_format_version,
                            load_plan_file, load_plan_json, verify_plan,
                            verify_plan_json)
from repro.core import (PLAN_FORMAT_VERSION, ParallelPlan, PlanFormatError,
                        Strategy, enumerate_strategies)

REPO = pathlib.Path(__file__).resolve().parent.parent
EXAMPLE_PLANS = sorted((REPO / "examples" / "plans").glob("*.plan.json"))


def error_rules(diags):
    return sorted({d.rule for d in diags if d.severity == "error"})


def make_plan(n_devices=8, pp=2, layers=8, schedule="1f1b", m=4, V=1,
              batch=32, strategy=None):
    group = n_devices // pp
    s = strategy or enumerate_strategies(group)[0]
    per = layers // pp
    return ParallelPlan(
        n_devices=n_devices, pp_degree=pp,
        partition=[per] * (pp - 1) + [layers - per * (pp - 1)],
        strategies=[s] * layers, global_batch=batch, n_micro=m,
        schedule=schedule, vpp_degree=V)


# ---------------------------------------------------------------------------
# clean plans certify
# ---------------------------------------------------------------------------

def test_valid_plan_has_no_errors():
    diags = verify_plan(make_plan())
    assert error_rules(diags) == []


@pytest.mark.parametrize("path", EXAMPLE_PLANS, ids=lambda p: p.name)
def test_checked_in_example_plans_certify(path):
    plan, report = load_plan_file(str(path))
    assert report.ok
    assert plan.n_devices >= 1
    assert detect_format_version(json.loads(path.read_text())) == \
        PLAN_FORMAT_VERSION


def test_example_plan_artifacts_exist():
    # CI lints these; losing them silently would hollow the lint job out
    assert EXAMPLE_PLANS, "examples/plans/*.plan.json disappeared"


# ---------------------------------------------------------------------------
# property-based fuzz over random degree tuples
# ---------------------------------------------------------------------------

@settings(max_examples=40)
@given(st.integers(0, 4), st.integers(0, 3), st.integers(1, 4),
       st.integers(0, 4), st.booleans())
def test_fuzz_legal_plans_never_error(log_dev, log_pp, m, strat_i, zb):
    """Any plan built from the real enumeration rules (pp | n_devices,
    per-layer strategies from enumerate_strategies(group), a legal
    schedule) verifies with zero errors."""
    n_devices = 2 ** log_dev
    pp = 2 ** min(log_pp, log_dev)
    group = n_devices // pp
    strategies = enumerate_strategies(group)
    s = strategies[strat_i % len(strategies)]
    schedule = "zb-h1" if (zb and pp > 1 and m >= pp) else "1f1b"
    plan = make_plan(n_devices=n_devices, pp=pp, layers=4 * pp,
                     schedule=schedule, m=m, batch=16 * m, strategy=s)
    diags = verify_plan(plan)
    assert error_rules(diags) == [], [d.format() for d in diags]


@settings(max_examples=40)
@given(st.integers(0, 4), st.integers(0, 4), st.integers(1, 6))
def test_fuzz_wrong_strategy_total_is_always_flagged(log_dev, log_wrong, m):
    """Whenever a layer's degrees don't multiply to the stage group size,
    PLN002 fires — for every random (n_devices, wrong_total) pair."""
    n_devices = 2 ** log_dev
    pp = 2 if n_devices >= 2 else 1
    group = n_devices // pp
    wrong = 2 ** log_wrong
    plan = make_plan(n_devices=n_devices, pp=pp, layers=2 * pp, m=m,
                     batch=8 * m, strategy=Strategy((("dp", wrong),)))
    rules = error_rules(verify_plan(plan))
    assert ("PLN002" in rules) == (wrong != group), rules


@settings(max_examples=30)
@given(st.sampled_from(["gpipe", "1f1b", "1f1b-interleaved", "zb-h1"]),
       st.integers(0, 3), st.integers(1, 8), st.integers(1, 2))
def test_fuzz_schedule_legality_matches_verifier(name, log_pp, m, V):
    """PLN004 fires exactly on the combos schedule_legal rejects."""
    from repro.analysis import schedule_legal
    pp = 2 ** log_pp
    plan = make_plan(n_devices=8 * pp, pp=pp, layers=4 * pp, schedule=name,
                     m=m, V=V, batch=8 * m)
    rules = error_rules(verify_plan(plan))
    assert ("PLN004" in rules) == (not schedule_legal(name, pp, m, V)), \
        (name, pp, m, V, rules)


# ---------------------------------------------------------------------------
# structural rules + version policy
# ---------------------------------------------------------------------------

def test_partition_rules():
    plan = make_plan()
    plan.partition = [3, 4]                      # sums to 7, not 8 layers
    assert "PLN003" in error_rules(verify_plan(plan))
    plan = make_plan()
    plan.partition = [8, 0]
    assert "PLN003" in error_rules(verify_plan(plan))


def test_missing_field_is_a_structured_diagnostic():
    d = make_plan().to_json()
    del d["partition"]
    with pytest.raises(DiagnosticError) as ei:
        load_plan_json(d)
    assert ei.value.rules() == ["PLN009"]
    assert any("partition" in x.location for x in ei.value.diagnostics)


def test_future_version_rejected():
    d = make_plan().to_json()
    d["format_version"] = PLAN_FORMAT_VERSION + 1
    assert error_rules(verify_plan_json(d)) == ["PLN001"]


def test_v0_plans_warn_by_default_and_fail_under_strict():
    d = make_plan().to_json()
    for k in ("format_version", "schedule", "vpp_degree", "est_iter_time",
              "est_throughput", "est_stage_mem", "alpha_t", "alpha_m",
              "searched_by", "search_stats"):
        d.pop(k, None)
    assert detect_format_version(d) == 0
    lax = verify_plan_json(d)
    assert "PLN001" in {x.rule for x in lax if x.severity == "warning"}
    assert "PLN001" not in error_rules(lax)
    assert "PLN001" in error_rules(verify_plan_json(d, strict=True))
    with pytest.raises(DiagnosticError):
        load_plan_json(d, strict=True)
    plan, _ = load_plan_json(d, strict=False)    # lax load still works
    assert (plan.schedule, plan.vpp_degree) == ("1f1b", 1)


def test_not_json_file_is_structured(tmp_path):
    p = tmp_path / "broken.plan.json"
    p.write_text("{not json")
    with pytest.raises(DiagnosticError) as ei:
        load_plan_file(str(p))
    assert ei.value.rules() == ["PLN009"]


# ---------------------------------------------------------------------------
# satellite: from_json / plan_bridge never leak bare KeyError
# ---------------------------------------------------------------------------

def test_from_json_raises_plan_format_error_naming_the_field():
    d = make_plan().to_json()
    del d["n_micro"]
    with pytest.raises(PlanFormatError) as ei:
        ParallelPlan.from_json(d)
    assert ei.value.field == "n_micro"
    assert "n_micro" in str(ei.value)
    # and never a bare KeyError
    with pytest.raises(ValueError):
        ParallelPlan.from_json({})


def test_from_json_rejects_future_version():
    d = make_plan().to_json()
    d["format_version"] = PLAN_FORMAT_VERSION + 5
    with pytest.raises(PlanFormatError) as ei:
        ParallelPlan.from_json(d)
    assert ei.value.field == "format_version"


def test_from_json_names_broken_strategy_entry():
    d = make_plan().to_json()
    d["strategies"][2] = {"levels": "zzz"}
    with pytest.raises(PlanFormatError) as ei:
        ParallelPlan.from_json(d)
    assert "strategies[2]" in ei.value.field


def test_plan_bridge_wraps_uncompilable_schedule():
    from repro.runtime.plan_bridge import schedule_program_from_plan
    plan = make_plan()
    plan.schedule = "1f1b-interleaved"           # vpp_degree stays 1
    with pytest.raises(DiagnosticError) as ei:
        schedule_program_from_plan(plan)
    assert "PLN004" in ei.value.rules()
    # legal plans compile through the bridge, with optional validation
    prog = schedule_program_from_plan(make_plan(), validate=True)
    assert prog.n_stages == 2
