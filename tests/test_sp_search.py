"""Sequence parallelism as a searched axis: cost-model SP terms, the
opt-in search-space extension, the physical per-device batch floor, the
long-context feasibility flip (the PR's acceptance criterion), PLN011
lint, and the plan -> runtime policy bridge."""
import numpy as np
import pytest

from repro.core import CLUSTERS, GalvatronOptimizer, ParallelPlan, Strategy
from repro.core.cost_model import (CostModel, CostModelConfig,
                                   _SP_INVALID_TIME)
from repro.core.layerspec import dense_layer
from repro.core.optimizer import OptimizerConfig
from repro.core.strategy import PARADIGMS, SP, SP_PARADIGMS

GB = 1024 ** 3
CLUSTER = CLUSTERS["8x-rtx-titan-pcie"]


def _spec(seq=4096):
    return dense_layer("body", seq, 1024, 16, 4, 4096)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_sp_paradigm_is_opt_in():
    assert SP not in PARADIGMS           # paper leaf counts preserved
    assert SP_PARADIGMS == PARADIGMS + (SP,)
    opt = GalvatronOptimizer([_spec()], CLUSTER, OptimizerConfig())
    assert all(s.sp == 1
               for pp in opt.search_space.per_pp.values() for s in pp)
    opt_sp = GalvatronOptimizer([_spec()], CLUSTER,
                                OptimizerConfig(use_sp=True))
    assert any(s.sp > 1
               for pp in opt_sp.search_space.per_pp.values() for s in pp)


def test_max_sp_caps_the_searched_degree():
    opt = GalvatronOptimizer([_spec()], CLUSTER,
                             OptimizerConfig(use_sp=True, max_sp=2))
    sps = {s.sp for pp in opt.search_space.per_pp.values() for s in pp}
    assert max(sps) == 2


def test_sp_divides_activation_memory_and_prices_ring_comm():
    cm = CostModel(CLUSTER)
    spec = _spec()
    plain = cm.layer_costs(spec, Strategy((("dp", 1),), ckpt=False), 4.0)
    sp4 = cm.layer_costs(spec, Strategy((("sp", 4),), ckpt=False), 4.0)
    # activations shrink by exactly sp (params replicate, so ms is equal)
    assert sp4.mem_f == pytest.approx(plain.mem_f / 4)
    assert sp4.mem_ms == plain.mem_ms
    # ring hand-offs + sp gradient all-reduce make time strictly larger
    # than a pure single-device forward of the same per-device workload
    assert sp4.time < _SP_INVALID_TIME
    assert sp4.time > 0


def test_sp_invalid_for_ssm_and_non_dividing_seq():
    from repro.core.layerspec import ssm_layer
    cm = CostModel(CLUSTER)
    ssm = ssm_layer("ssm", 4096, 1024)
    c = cm.layer_costs(ssm, Strategy((("sp", 4),), ckpt=False), 4.0)
    assert c.time == _SP_INVALID_TIME          # sequential state scan
    odd = _spec(seq=4097)                      # 4097 % 4 != 0
    c2 = cm.layer_costs(odd, Strategy((("sp", 4),), ckpt=False), 4.0)
    assert c2.time == _SP_INVALID_TIME
    assert np.isfinite(c2.mem_f) and np.isfinite(c2.mem_ms)


def test_scalar_and_vectorized_sp_tables_agree_exactly():
    cm = CostModel(CLUSTER)
    specs = [_spec(), _spec(seq=4097)]
    strats = [Strategy((("sp", 4),), ckpt=False),
              Strategy((("sp", 2), ("tp", 2)), ckpt=True),
              Strategy((("sdp", 2), ("sp", 2)), ckpt=False),
              Strategy((("dp", 4),), ckpt=False)]
    tables = cm.layer_cost_tables(specs, strats, 8.0, inflight=2)
    for i, spec in enumerate(specs):
        for j, s in enumerate(strats):
            c = cm.layer_costs(spec, s, 8.0, inflight=2)
            assert tables.time_sync[i, j] == c.time, (i, j)
            assert tables.time_nosync[i, j] == c.time_nosync, (i, j)
            assert tables.mem_f[i, j] == c.mem_f, (i, j)
            assert tables.mem_ms[i, j] == c.mem_ms, (i, j)


def test_min_samples_per_device_floor():
    spec = _spec()
    floor = CostModel(CLUSTER, CostModelConfig(min_samples_per_device=1.0))
    # dp8 with a single-sample micro batch would put 1/8 sample per device
    c = floor.layer_costs(spec, Strategy((("dp", 8),), ckpt=False), 1.0)
    assert c.time == _SP_INVALID_TIME
    # sp8 keeps the whole sample per data lane — valid
    c2 = floor.layer_costs(spec, Strategy((("sp", 8),), ckpt=False), 1.0)
    assert c2.time < _SP_INVALID_TIME
    # default config keeps the paper's unconstrained model bit-identical
    free = CostModel(CLUSTER)
    c3 = free.layer_costs(spec, Strategy((("dp", 8),), ckpt=False), 1.0)
    assert c3.time < _SP_INVALID_TIME
    # the vectorized path applies the same floor
    t = floor.layer_cost_tables([spec], [Strategy((("dp", 8),), ckpt=False),
                                         Strategy((("sp", 8),), ckpt=False)],
                                1.0)
    assert t.time_sync[0, 0] == _SP_INVALID_TIME
    assert t.time_sync[0, 1] < _SP_INVALID_TIME


# ---------------------------------------------------------------------------
# the acceptance criterion: long-context feasibility flip
# ---------------------------------------------------------------------------

def _longctx_setup():
    from repro.configs import get_config
    from repro.configs.specs import layerspecs_for
    cfg = get_config("qwen3-4b")
    specs = layerspecs_for(cfg, 131072)
    cluster = CLUSTERS["16x-a100-nvlink-ib100"]
    cc = CostModelConfig(min_samples_per_device=1.0)
    base = dict(batch_grid=(1, 2, 4), micro_candidates=2, n_bins=64)
    return specs, cluster, cc, base


def test_longctx_infeasible_at_sp1_feasible_with_sp():
    specs, cluster, cc, base = _longctx_setup()
    budget = [32 * GB]
    opt1 = GalvatronOptimizer(specs, cluster, OptimizerConfig(**base), cc)
    assert opt1.sweep_budgets(budget).points[0].plan is None

    opt2 = GalvatronOptimizer(specs, cluster,
                              OptimizerConfig(use_sp=True, **base), cc)
    plan = opt2.sweep_budgets(budget).points[0].plan
    assert plan is not None
    assert plan.sp_degree > 1
    assert plan.seq_len == 131072
    assert plan.seq_len % plan.sp_degree == 0
    # the emitted plan certifies (no errors; PLN011 included)
    from repro.analysis import verify_plan_json
    diags = verify_plan_json(plan.to_json())
    assert not [d for d in diags if d.severity == "error"], diags


def test_sp1_plans_unchanged_by_enabling_use_sp_where_sp_loses():
    # short context, ample budget: SP never wins, and the superset search
    # space must still emit a certifying plan
    spec = [_spec(seq=512) for _ in range(4)]
    base = dict(batch_grid=(8,), micro_candidates=2, n_bins=64)
    p1 = GalvatronOptimizer(spec, CLUSTER, OptimizerConfig(**base)) \
        .sweep_budgets([8 * GB]).points[0].plan
    p2 = GalvatronOptimizer(spec, CLUSTER,
                            OptimizerConfig(use_sp=True, **base)) \
        .sweep_budgets([8 * GB]).points[0].plan
    assert p1 is not None and p2 is not None
    assert p2.est_throughput >= p1.est_throughput * (1 - 1e-9)


# ---------------------------------------------------------------------------
# PLN011 lint
# ---------------------------------------------------------------------------

def _plan(sp_degree=1, seq_len=0, strategies=None, pp=1, n_dev=8):
    strategies = strategies or [Strategy((("dp", 8 // pp),), ckpt=False)] * 4
    return ParallelPlan(
        n_devices=n_dev, pp_degree=pp, partition=[4 // pp] * pp,
        strategies=strategies, global_batch=8, n_micro=1,
        sp_degree=sp_degree, seq_len=seq_len)


def _diags(plan):
    from repro.analysis import verify_plan_json
    return [d for d in verify_plan_json(plan.to_json())
            if d.rule == "PLN011"]


def test_pln011_sp_degree_must_divide_device_groups():
    # strategies are per-stage legal (total == n_devices/pp, so PLN002 is
    # silent) but the stamped sp_degree does not factor out of n_devices
    strats = [Strategy((("sp", 2), ("dp", 4)),)] * 4
    bad = _plan(sp_degree=3, seq_len=4098, strategies=strats)
    found = _diags(bad)
    assert any(d.severity == "error" and "divide" in d.message
               for d in found), found
    ok = _plan(sp_degree=4, seq_len=4096,
               strategies=[Strategy((("sp", 4), ("dp", 2)),)] * 4)
    assert not [d for d in _diags(ok) if d.severity == "error"]


def test_pln011_seq_len_divisibility_and_unrecorded_warning():
    strats = [Strategy((("sp", 4), ("dp", 2)),)] * 4
    bad = _plan(sp_degree=4, seq_len=4098, strategies=strats)
    assert any(d.severity == "error" and "seq_len" in d.location
               for d in _diags(bad))
    unrec = _plan(sp_degree=4, seq_len=0, strategies=strats)
    found = _diags(unrec)
    assert any(d.severity == "warning" for d in found), found


def test_pln011_layer_sp_exceeding_stamp_is_an_error():
    strats = [Strategy((("sp", 4), ("dp", 2)),)] * 4
    bad = _plan(sp_degree=2, seq_len=4096, strategies=strats)
    assert any(d.severity == "error" and "sp_degree" in d.location
               for d in _diags(bad))


def test_pln011_silent_on_sp1_plans():
    assert _diags(_plan()) == []


# ---------------------------------------------------------------------------
# plan -> runtime bridge
# ---------------------------------------------------------------------------

def test_policy_from_plan_carries_sp_degree():
    from repro.configs import get_config
    from repro.runtime.plan_bridge import policy_from_plan
    cfg = get_config("qwen3-4b")
    strats = [Strategy((("sp", 4), ("dp", 2)),)] * cfg.n_layers
    plan = ParallelPlan(
        n_devices=8, pp_degree=1, partition=[cfg.n_layers],
        strategies=strats, global_batch=8, n_micro=1,
        sp_degree=4, seq_len=65536)
    pol = policy_from_plan(cfg, plan)
    assert pol.sp_degree == 4


def test_shard_policy_from_strategy_stamps_sp():
    from repro.runtime import ShardPolicy
    pol = ShardPolicy.from_strategy(Strategy((("sp", 4), ("tp", 2)),))
    assert pol.sp_degree == 4
