"""End-to-end system behaviour: search a plan with the paper's engine, map
it onto a local mesh policy, train, checkpoint, restore, serve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.specs import layerspecs_for
from repro.core import (GalvatronOptimizer, OptimizerConfig, galvatron_variant,
                        tpu_v5e_pod)
from repro.data import DataConfig, batch_specs, synthetic_lm_batches
from repro.launch.mesh import make_local_mesh
from repro.runtime import ShardPolicy, init_train_state, make_train_step

GB = 1024 ** 3


def test_search_plan_for_assigned_arch_on_tpu_cluster():
    """The paper's engine plans a real assigned architecture for a v5e pod."""
    cfg = get_config("qwen3-8b")
    specs = layerspecs_for(cfg, 4096)
    ocfg = galvatron_variant("bmw")
    ocfg.batch_grid = [256]
    ocfg.n_bins = 96
    ocfg.micro_candidates = 2
    ocfg.max_pp = 4
    cluster = tpu_v5e_pod(64)     # searchable-size slice of the pod
    plan = GalvatronOptimizer(specs, cluster, ocfg).optimize()
    assert plan is not None, "search found no feasible plan"
    assert plan.est_throughput > 0
    assert max(plan.est_stage_mem) <= cluster.budget() * 1.01
    pol = ShardPolicy.from_strategy(plan.strategies[1])
    assert isinstance(pol.tp, bool)


def test_train_checkpoint_restore_resume(tmp_path):
    from repro.checkpointing import restore_train_state, save_train_state
    cfg = get_config("qwen3-4b").reduced(n_layers=2, d_model=128)
    mesh = make_local_mesh()
    dcfg = DataConfig(seq_len=16, global_batch=4, vocab_size=cfg.vocab_size)
    pol = ShardPolicy(tp=False, zero=False)
    with mesh:
        step = make_train_step(cfg, mesh, pol, batch_specs(dcfg))
        params, opt = init_train_state(cfg, mesh, pol)
        gen = synthetic_lm_batches(dcfg)
        for i in range(3):
            b = {k: jnp.asarray(v) for k, v in next(gen).items()}
            params, opt, m = step.fn(params, opt, b)
        save_train_state(3, params, opt, tmp_path)
        p2, o2, s = restore_train_state(params, opt, tmp_path)
        assert s == 3
        np.testing.assert_array_equal(
            np.asarray(p2["final_norm"], np.float32),
            np.asarray(params["final_norm"], np.float32))
        # resumed state keeps training
        b = {k: jnp.asarray(v) for k, v in next(gen).items()}
        p3, o3, m2 = step.fn(p2, o2, b)
        assert bool(jnp.isfinite(m2["loss"]))


def test_loss_decreases_over_short_run():
    cfg = get_config("qwen3-4b").reduced(n_layers=2, d_model=128)
    mesh = make_local_mesh()
    dcfg = DataConfig(seq_len=32, global_batch=8, vocab_size=cfg.vocab_size)
    pol = ShardPolicy(tp=False, zero=False)
    with mesh:
        step = make_train_step(cfg, mesh, pol, batch_specs(dcfg))
        params, opt = init_train_state(cfg, mesh, pol)
        gen = synthetic_lm_batches(dcfg)
        losses = []
        for i in range(12):
            b = {k: jnp.asarray(v) for k, v in next(gen).items()}
            params, opt, m = step.fn(params, opt, b)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses
