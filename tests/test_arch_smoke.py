"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
assigned family — 2 layers, d_model<=512, <=4 experts — one forward + one
train step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import (decode_step, encdec_loss, init_decode_state,
                          init_encdec, init_lm, lm_forward, lm_loss)
from repro.optim import AdamWConfig, adamw_init, adamw_update

ASSIGNED = ["qwen2-72b", "qwen2.5-14b", "internvl2-26b", "kimi-k2-1t-a32b",
            "qwen3-4b", "zamba2-1.2b", "whisper-medium", "mamba2-370m",
            "arctic-480b", "qwen3-8b"]


def test_all_assigned_archs_registered():
    archs = list_archs()
    for a in ASSIGNED:
        assert a in archs


def _batch(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.arch_type == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_vision))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_and_train_step(arch):
    full = get_config(arch)
    cfg = full.reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    key = jax.random.PRNGKey(0)
    batch = _batch(cfg, key)
    B, S = batch["tokens"].shape

    if cfg.is_encoder_decoder:
        params = init_encdec(key, cfg, max_dec_len=256)
        loss_fn = lambda p: encdec_loss(p, batch, cfg)
    else:
        params = init_lm(key, cfg)
        logits, aux = lm_forward(params, batch["tokens"], cfg,
                                 patches=batch.get("patches"))
        assert logits.shape == (B, S, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        loss_fn = lambda p: lm_loss(p, batch, cfg)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    opt = adamw_init(params)
    new_params, opt, metrics = adamw_update(params, grads, opt, AdamWConfig())
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, b: a + float(jnp.abs(b[0].astype(jnp.float32)
                                       - b[1].astype(jnp.float32)).sum()),
        jax.tree.map(lambda x, y: (x, y), new_params, params), 0.0)
    assert delta > 0.0


@pytest.mark.parametrize("arch", ["qwen3-4b", "kimi-k2-1t-a32b",
                                  "mamba2-370m", "zamba2-1.2b"])
def test_reduced_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_lm(key, cfg)
    state = init_decode_state(cfg, 2, 64)
    tok = jnp.zeros((2,), jnp.int32)
    for _ in range(3):
        logits, state = decode_step(params, state, tok, cfg)
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_remat_segments_same_loss():
    cfg = get_config("qwen3-4b").reduced()
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    batch = _batch(cfg, key)
    l0 = lm_loss(params, batch, cfg)
    l1 = lm_loss(params, batch, cfg, remat_segments=[True])
    assert abs(float(l0) - float(l1)) < 1e-4
