"""Multi-device integration tests (subprocesses with fake host devices —
the main process must keep seeing 1 CPU device)."""
import pytest

from conftest import run_subprocess


@pytest.mark.slow
def test_executor_tp_zero_training_8dev():
    out = run_subprocess("""
import jax, jax.numpy as jnp
mesh = jax.make_mesh((4, 2), ("data", "model"))
from repro.configs import get_config
from repro.runtime import ShardPolicy, make_train_step, init_train_state
from repro.data import DataConfig, synthetic_lm_batches, batch_specs
cfg = get_config("qwen3-4b").reduced(n_layers=2, d_model=256)
dcfg = DataConfig(seq_len=32, global_batch=8, vocab_size=cfg.vocab_size)
pol = ShardPolicy(tp=True, zero=True, remat_segments=(True,))
with mesh:
    step = make_train_step(cfg, mesh, pol, batch_specs(dcfg))
    params, opt = init_train_state(cfg, mesh, pol)
    gen = synthetic_lm_batches(dcfg)
    losses = []
    for i in range(8):
        b = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, opt, m = step.fn(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    # verify params actually sharded over model axis
    wq = params["stacks"][0]["attn"]["wq"]
    assert len(wq.sharding.device_set) == 8
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_pipeline_runtime_matches_reference_8dev():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
mesh = jax.make_mesh((4, 2), ("pipe", "data"))
from repro.configs import get_config
from repro.models import init_lm, lm_loss
from repro.runtime.pipeline import make_pipeline_loss, stage_split_params
cfg = get_config("qwen3-4b").reduced(n_layers=4, d_model=128)
key = jax.random.PRNGKey(0)
params = init_lm(key, cfg)
m, Bm, S = 6, 4, 16
toks = jax.random.randint(key, (m, Bm, S), 0, cfg.vocab_size)
labels = jax.random.randint(jax.random.PRNGKey(1), (m, Bm, S), 0, cfg.vocab_size)
with mesh:
    ps = stage_split_params(params, 4)
    loss_fn = make_pipeline_loss(cfg, mesh, n_micro=m)
    loss, grads = jax.jit(loss_fn)(ps, {"tokens": toks, "labels": labels})
flat = {"tokens": toks.reshape(m*Bm, S), "labels": labels.reshape(m*Bm, S)}
ref = lm_loss(params, flat, cfg)
rg = jax.grad(lambda p: lm_loss(p, flat, cfg))(params)
assert abs(float(loss) - float(ref)) < 1e-3
for name in ["embed", "final_norm", "head"]:
    g = np.asarray(grads[name], np.float32); r = np.asarray(rg[name], np.float32)
    assert np.abs(g - r).max() < 0.02 * max(np.abs(r).max(), 1e-3) + 1e-4, name
gs = np.asarray(grads["stacks"][0]["attn"]["wq"], np.float32).reshape(4, -1)
rs = np.asarray(rg["stacks"][0]["attn"]["wq"], np.float32).reshape(4, -1)
assert np.abs(gs - rs).max() < 0.02 * np.abs(rs).max() + 1e-4
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_moe_expert_parallel_serving_8dev():
    out = run_subprocess("""
import jax, jax.numpy as jnp
mesh = jax.make_mesh((2, 4), ("data", "model"))
from repro.configs import get_config
from repro.runtime import ShardPolicy, make_serve_step
from repro.models import init_lm, init_decode_state
cfg = get_config("kimi-k2-1t-a32b").reduced()
pol = ShardPolicy(tp=True, zero=False)
key = jax.random.PRNGKey(0)
with mesh:
    sstep = make_serve_step(cfg, mesh, pol, batch=4, context=64)
    params = jax.jit(lambda k: init_lm(k, cfg),
                     out_shardings=sstep.in_shardings[0])(key)
    st = jax.jit(lambda: init_decode_state(cfg, 4, 64),
                 out_shardings=sstep.in_shardings[1])()
    tok = jnp.zeros((4,), jnp.int32)
    for _ in range(3):
        logits, st = sstep.fn(params, st, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert bool(jnp.isfinite(logits).all())
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_entrypoint_tiny():
    """End-to-end dryrun driver on a small arch/shape (full 512-dev mesh)."""
    import subprocess, sys, os, pathlib
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-370m",
         "--shape", "decode_32k"],
        env=env, capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "1 ok, 0 failed" in res.stdout


@pytest.mark.slow
def test_moe_shmap_dispatch_matches_einsum_16dev():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
mesh = jax.make_mesh((4, 4), ("data", "model"))
from repro.configs import get_config
from repro.models.flags import batch_sharding
from repro.models.moe import init_moe, moe_ffn
cfg = get_config("kimi-k2-1t-a32b").reduced().with_(dtype=jnp.float32,
                                                    capacity_factor=8.0)
p = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)
with mesh:
    with batch_sharding(("data",), mesh=mesh):
        o1, a1 = jax.jit(lambda p, x: moe_ffn(p, x, cfg, dispatch="shmap"))(p, x)
    o2, a2 = moe_ffn(p, x, cfg, dispatch="einsum")
np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-4)
assert abs(float(a1) - float(a2)) < 1e-5
print("OK")
""", devices=16)
    assert "OK" in out


@pytest.mark.slow
def test_seq_shard_policy_same_loss_8dev():
    """The §Perf stash-only sequence-parallel policy must be numerically
    identical to the baseline (it only moves shardings)."""
    out = run_subprocess("""
import jax, jax.numpy as jnp
mesh = jax.make_mesh((2, 4), ("data", "model"))
from repro.configs import get_config
from repro.runtime import ShardPolicy, make_train_step, init_train_state
from repro.data import DataConfig, synthetic_lm_batches, batch_specs
cfg = get_config("qwen3-4b").reduced(n_layers=2, d_model=256).with_(
    dtype=jnp.float32)
dcfg = DataConfig(seq_len=64, global_batch=4, vocab_size=cfg.vocab_size)
losses = {}
for seq_shard in (False, True):
    pol = ShardPolicy(tp=True, zero=True, remat_segments=(True,),
                      seq_shard=seq_shard)
    with mesh:
        step = make_train_step(cfg, mesh, pol, batch_specs(dcfg))
        params, opt = init_train_state(cfg, mesh, pol)
        gen = synthetic_lm_batches(dcfg)
        ls = []
        for _ in range(3):
            b = {k: jnp.asarray(v) for k, v in next(gen).items()}
            params, opt, m = step.fn(params, opt, b)
            ls.append(float(m["loss"]))
    losses[seq_shard] = ls
for a, b in zip(losses[False], losses[True]):
    assert abs(a - b) < 2e-4, (losses[False], losses[True])
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_pipeline_all_schedules_match_reference_8dev():
    """Schedule-equivalence: gpipe / 1f1b / 1f1b-interleaved (V=2) /
    zb-h1 all reproduce the non-pipelined executor-path loss and
    gradients (the zero-bubble program executes its forward projection;
    autodiff realizes the B/W split)."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
mesh = jax.make_mesh((4, 2), ("pipe", "data"))
from repro.configs import get_config
from repro.models import init_lm, lm_loss
from repro.runtime.pipeline import make_pipeline_loss, stage_split_params
cfg = get_config("qwen3-4b").reduced(n_layers=8, d_model=128)
key = jax.random.PRNGKey(0)
params = init_lm(key, cfg)
m, Bm, S = 6, 4, 16
toks = jax.random.randint(key, (m, Bm, S), 0, cfg.vocab_size)
labels = jax.random.randint(jax.random.PRNGKey(1), (m, Bm, S), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": labels}
flat = {"tokens": toks.reshape(m*Bm, S), "labels": labels.reshape(m*Bm, S)}
ref = lm_loss(params, flat, cfg)
rg = jax.grad(lambda p: lm_loss(p, flat, cfg))(params)
rs = np.asarray(rg["stacks"][0]["attn"]["wq"], np.float32)
with mesh:
    for sched, V in [("gpipe", 1), ("1f1b", 1), ("1f1b-interleaved", 2),
                     ("zb-h1", 1)]:
        ps = stage_split_params(params, 4, V)
        loss_fn = make_pipeline_loss(cfg, mesh, n_micro=m, schedule=sched,
                                     n_chunks=V)
        loss, grads = jax.jit(loss_fn)(ps, batch)
        assert abs(float(loss) - float(ref)) < 1e-3, sched
        for name in ["embed", "final_norm"]:
            g = np.asarray(grads[name], np.float32)
            r = np.asarray(rg[name], np.float32)
            assert np.abs(g - r).max() < 0.02 * max(np.abs(r).max(), 1e-3) + 1e-4, (sched, name)
        gs = np.asarray(grads["stacks"][0]["attn"]["wq"], np.float32)
        # undo the (P, V, Lc) round-robin placement: stage s = v*P + i
        order = np.transpose(gs, (1, 0, 2) + tuple(range(3, gs.ndim)))
        flat_g = order.reshape(rs.shape)
        assert np.abs(flat_g - rs).max() < 0.02 * np.abs(rs).max() + 1e-4, sched
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_pipeline_1f1b_memory_schedule_matches_gpipe_8dev():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
mesh = jax.make_mesh((4, 2), ("pipe", "data"))
from repro.configs import get_config
from repro.models import init_lm
from repro.runtime.pipeline import make_pipeline_loss, stage_split_params
cfg = get_config("qwen3-4b").reduced(n_layers=4, d_model=128)
key = jax.random.PRNGKey(0)
params = init_lm(key, cfg)
m, Bm, S = 4, 4, 16
toks = jax.random.randint(key, (m, Bm, S), 0, cfg.vocab_size)
labels = jax.random.randint(jax.random.PRNGKey(1), (m, Bm, S), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": labels}
with mesh:
    ps = stage_split_params(params, 4)
    lg = jax.jit(make_pipeline_loss(cfg, mesh, n_micro=m, schedule="gpipe"))
    l1 = jax.jit(make_pipeline_loss(cfg, mesh, n_micro=m, schedule="1f1b"))
    loss_g, grads_g = lg(ps, batch)
    loss_1, grads_1 = l1(ps, batch)
assert abs(float(loss_g) - float(loss_1)) < 1e-4
g0 = np.asarray(grads_g["stacks"][0]["attn"]["wq"], np.float32)
g1 = np.asarray(grads_1["stacks"][0]["attn"]["wq"], np.float32)
assert np.abs(g0 - g1).max() < 1e-3 * max(1.0, np.abs(g0).max())
print("OK")
""")
    assert "OK" in out
