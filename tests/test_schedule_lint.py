"""Schedule verifier (repro.analysis.schedule_lint): the full acceptance
grid certifies clean with liveness pinned exactly against the cost model,
and every seeded mutation of a valid program table is flagged with the
right rule id — no silent passes.

Mutations are built with ``dataclasses.replace`` on copies of the compiled
(T, P) tables, so each one corrupts exactly the invariant named in its
test."""
import dataclasses

import numpy as np
import pytest

from repro.analysis import (DiagnosticError, certify_live_buffers,
                            certify_program, schedule_grid, schedule_legal,
                            verify_program)
from repro.core.pipeline_balance import (ZB_W_ACT_FRAC,
                                         inflight_microbatches,
                                         zb_w_pending_max)
from repro.runtime.schedules import (PHASE_B, PHASE_F, PHASE_W,
                                     compile_schedule)

GRID = list(schedule_grid())


def error_rules(pr):
    return sorted({d.rule for d in verify_program(pr)
                   if d.severity == "error"})


def _mutable(pr):
    """A program whose table arrays are private writable copies."""
    return dataclasses.replace(
        pr, mb_index=pr.mb_index.copy(), chunk_index=pr.chunk_index.copy(),
        valid=pr.valid.copy(), loss_valid=pr.loss_valid.copy(),
        phase=None if pr.phase is None else pr.phase.copy())


# ---------------------------------------------------------------------------
# the acceptance grid: P in {1,2,4,8} x m in {1..16} x V in {1,2}
# ---------------------------------------------------------------------------

def test_grid_covers_all_four_schedules():
    names = {g[0] for g in GRID}
    assert names == {"gpipe", "1f1b", "1f1b-interleaved", "zb-h1"}
    assert len(GRID) == 179          # 64 + 64 + 14 + 37 legal combos


@pytest.mark.parametrize("name,P,m,V", GRID,
                         ids=lambda v: str(v))
def test_grid_certifies_with_zero_errors(name, P, m, V):
    pr = compile_schedule(name, P, m, V if V > 1 else None)
    report = certify_program(pr)
    assert report.ok, report.format()


@pytest.mark.parametrize("name,P,m,V", GRID, ids=lambda v: str(v))
def test_certified_liveness_matches_cost_model_exactly(name, P, m, V):
    """The liveness analysis and core/pipeline_balance.py agree *exactly*
    on every stage's peak live activation sets (and on the deferred
    weight-grad pile for zb-h1) — drift on either side is a CI failure."""
    pr = compile_schedule(name, P, m, V if V > 1 else None)
    certs = certify_live_buffers(pr)
    assert [c.stage for c in certs] == list(range(P))
    for c in certs:
        assert c.live_sets == pytest.approx(
            inflight_microbatches(c.stage, P, m, name, V), abs=1e-9)
        if name == "zb-h1":
            assert c.w_pending == zb_w_pending_max(c.stage, P, m)
            assert c.live_sets == pytest.approx(
                c.fwd_stash + ZB_W_ACT_FRAC * c.w_pending)
        else:
            assert c.w_pending == 0


def test_schedule_legal_mirrors_optimizer_rules():
    assert schedule_legal("gpipe", 1, 1, 1)
    assert schedule_legal("1f1b", 8, 16, 1)
    assert not schedule_legal("1f1b", 8, 16, 2)       # single-chunk
    assert not schedule_legal("1f1b-interleaved", 1, 4, 2)   # P == 1
    assert not schedule_legal("1f1b-interleaved", 4, 6, 2)   # ragged m % P
    assert schedule_legal("1f1b-interleaved", 4, 8, 2)
    assert not schedule_legal("zb-h1", 1, 4, 1)       # P == 1
    assert not schedule_legal("zb-h1", 4, 2, 1)       # m < P
    assert schedule_legal("zb-h1", 4, 4, 1)
    assert not schedule_legal("nope", 4, 8, 1)


# ---------------------------------------------------------------------------
# seeded mutations: each corruption is flagged with its specific rule id
# ---------------------------------------------------------------------------

def test_mutation_swap_two_ticks_breaks_happens_before():
    """Swapping stage 0's first two slots (F0 and F1 for gpipe; F and B
    for zb-h1) runs a consumer at or before its producer -> SCH001."""
    pr = _mutable(compile_schedule("zb-h1", 4, 8))
    ts = [t for t in range(pr.n_ticks) if pr.valid[t, 0]][3:5]   # F3, B0
    for a in (pr.mb_index, pr.chunk_index, pr.phase):
        a[ts[0], 0], a[ts[1], 0] = int(a[ts[1], 0]), int(a[ts[0], 0])
    assert "SCH001" in error_rules(pr)


def test_mutation_drop_dependency_edge_is_use_before_def():
    """Invalidating stage 1's F for one micro-batch leaves stage 2's F (and
    stage 1's own B) consuming a buffer that is never produced -> SCH002,
    and the program no longer covers all work -> SCH004."""
    pr = _mutable(compile_schedule("zb-h1", 4, 8))
    for t in range(pr.n_ticks):
        if (pr.valid[t, 1] and pr.phase[t, 1] == PHASE_F
                and pr.mb_index[t, 1] == 3):
            pr.valid[t, 1] = False
    rules = error_rules(pr)
    assert "SCH002" in rules and "SCH004" in rules


def test_mutation_inflate_inflight_cap():
    """Swapping stage 0's first B with a later F makes it bank one more
    forward than the flush cap min(P - i, m) allows -> SCH006 (and the
    memory model no longer matches -> SCH007)."""
    pr = _mutable(compile_schedule("zb-h1", 4, 8))
    tb = next(t for t in range(pr.n_ticks)
              if pr.valid[t, 0] and pr.phase[t, 0] == PHASE_B)
    tf = next(t for t in range(tb + 1, pr.n_ticks)
              if pr.valid[t, 0] and pr.phase[t, 0] == PHASE_F)
    for a in (pr.mb_index, pr.chunk_index, pr.phase):
        a[tb, 0], a[tf, 0] = int(a[tf, 0]), int(a[tb, 0])
    rules = error_rules(pr)
    assert "SCH006" in rules
    assert "SCH007" in rules


def test_mutation_orphan_w_tick():
    """Retargeting a W slot at a different micro-batch double-consumes one
    activation-gradient buffer (SCH003) and leaves the original
    micro-batch's W missing (SCH004)."""
    pr = _mutable(compile_schedule("zb-h1", 4, 8))
    tw = next(t for t in range(pr.n_ticks)
              if pr.valid[t, 2] and pr.phase[t, 2] == PHASE_W)
    pr.mb_index[tw, 2] = (int(pr.mb_index[tw, 2]) + 1) % pr.n_micro
    rules = error_rules(pr)
    assert "SCH003" in rules and "SCH004" in rules


def test_mutation_w_without_b_is_use_before_def():
    """Dropping a B but keeping its W: the weight gradient consumes an
    activation gradient that is never computed -> SCH002."""
    pr = _mutable(compile_schedule("zb-h1", 2, 4))
    tb = next(t for t in range(pr.n_ticks)
              if pr.valid[t, 1] and pr.phase[t, 1] == PHASE_B
              and pr.mb_index[t, 1] == 2)
    pr.valid[tb, 1] = False
    rules = error_rules(pr)
    assert "SCH002" in rules


def test_mutation_single_phase_handoff_garbage():
    """Retargeting one interleaved slot at the wrong micro-batch breaks
    the one-tick/one-hop ring hand-off (SCH009) and duplicates the other
    micro-batch's event (SCH003)."""
    pr = _mutable(compile_schedule("1f1b-interleaved", 4, 8, 2))
    t = next(t for t in range(pr.n_ticks) if pr.valid[t, 2])
    pr.mb_index[t, 2] = (int(pr.mb_index[t, 2]) + 1) % pr.n_micro
    rules = error_rules(pr)
    assert "SCH009" in rules
    assert "SCH003" in rules and "SCH004" in rules


def test_mutation_three_phase_flush_order():
    """Swapping the first two F micro-batches on a zb-h1 stage destroys
    the flush order the runtime's forward projection requires -> SCH009."""
    pr = _mutable(compile_schedule("zb-h1", 2, 4))
    f_ticks = [t for t in range(pr.n_ticks)
               if pr.valid[t, 0] and pr.phase[t, 0] == PHASE_F][:2]
    a = pr.mb_index
    a[f_ticks[0], 0], a[f_ticks[1], 0] = (int(a[f_ticks[1], 0]),
                                          int(a[f_ticks[0], 0]))
    assert "SCH009" in error_rules(pr)


def test_mutation_loss_on_wrong_stage():
    pr = _mutable(compile_schedule("gpipe", 4, 6))
    t = next(t for t in range(pr.n_ticks) if pr.valid[t, 0])
    pr.loss_valid[t, 0] = True
    assert "SCH005" in error_rules(pr)


def test_mutation_malformed_indices():
    pr = _mutable(compile_schedule("gpipe", 4, 6))
    t = next(t for t in range(pr.n_ticks) if pr.valid[t, 1])
    pr.mb_index[t, 1] = pr.n_micro + 3
    assert "SCH010" in error_rules(pr)


def test_mutation_stretch_program_breaks_bubble_pin():
    """Padding two pure-bubble ticks onto the end changes the compiled
    bubble away from the priced bubble_fraction -> SCH008."""
    pr = compile_schedule("gpipe", 4, 6)
    pad = 2
    z = lambda a, fill: np.concatenate(
        [a, np.full((pad,) + a.shape[1:], fill, a.dtype)])
    stretched = dataclasses.replace(
        pr, n_ticks=pr.n_ticks + pad, mb_index=z(pr.mb_index, 0),
        chunk_index=z(pr.chunk_index, 0), valid=z(pr.valid, False),
        loss_valid=z(pr.loss_valid, False), phase=z(pr.phase, 0))
    assert "SCH008" in error_rules(stretched)


# ---------------------------------------------------------------------------
# compile_schedule(validate=True): the verifier as a compiler post-condition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,P,m,V",
                         [("gpipe", 4, 6, 1), ("1f1b", 4, 8, 1),
                          ("1f1b-interleaved", 4, 8, 2), ("zb-h1", 4, 8, 1)])
def test_compile_validate_passes_on_legal_combos(name, P, m, V):
    pr = compile_schedule(name, P, m, V if V > 1 else None, validate=True)
    assert pr.n_stages == P


def test_compile_validate_rejects_priced_drift():
    """Combos the optimizer would never propose (ragged interleaved
    groups, zb-h1 with m < P) compile, but their bubble diverges from the
    priced bubble_fraction — validate=True surfaces that as a structured
    DiagnosticError instead of an executable-but-mispriced program."""
    with pytest.raises(DiagnosticError) as ei:
        compile_schedule("1f1b-interleaved", 4, 6, 2, validate=True)
    assert "SCH008" in ei.value.rules()
    with pytest.raises(DiagnosticError) as ei:
        compile_schedule("zb-h1", 4, 2, validate=True)
    assert "SCH008" in ei.value.rules()
    # DiagnosticError is a ValueError: existing except-ValueError callers
    # keep working
    assert issubclass(DiagnosticError, ValueError)


def test_verify_program_emits_certification_telemetry():
    pr = compile_schedule("zb-h1", 4, 8)
    report = certify_program(pr)
    assert report.ok
    infos = [d for d in report.diagnostics if d.severity == "info"]
    assert any(d.rule == "SCH007" for d in infos)    # liveness numbers
    assert any(d.rule == "SCH008" for d in infos)    # bubble pin
