"""Jax-pitfall AST linter (repro.analysis.jax_lint): each rule fires on a
minimal reproduction of its pitfall, respects the declared-static escape
hatches, and — the CI contract — the real ``src/`` tree lints clean."""
import pathlib
import textwrap

import pytest

from repro.analysis import lint_paths, lint_source

REPO = pathlib.Path(__file__).resolve().parent.parent


def rules(code, path="t.py", severity=None):
    diags = lint_source(textwrap.dedent(code), path)
    if severity:
        diags = [d for d in diags if d.severity == severity]
    return sorted({d.rule for d in diags})


# ---------------------------------------------------------------------------
# JAX001: side effects in lax.scan bodies
# ---------------------------------------------------------------------------

def test_print_in_scan_body_is_error():
    assert rules("""
        from jax import lax
        def body(carry, x):
            print("step", x)
            return carry + x, carry
        def run(xs):
            return lax.scan(body, 0.0, xs)
    """, severity="error") == ["JAX001"]


def test_global_write_in_scan_body_is_error():
    assert rules("""
        import jax
        steps = 0
        def body(c, x):
            global steps
            steps += 1
            return c, x
        def run(xs):
            return jax.lax.scan(body, 0.0, xs)
    """, severity="error") == ["JAX001"]


def test_closure_append_in_scan_body_warns():
    assert rules("""
        from jax import lax
        acc = []
        def body(c, x):
            acc.append(x)
            return c, x
        def run(xs):
            return lax.scan(body, 0.0, xs)
    """, severity="warning") == ["JAX001"]


def test_scan_lambda_body_is_checked():
    assert rules("""
        from jax import lax
        acc = []
        def run(xs):
            return lax.scan(lambda c, x: (c, acc.append(x)), 0.0, xs)
    """) == ["JAX001"]


def test_local_mutation_in_scan_body_is_fine():
    assert rules("""
        from jax import lax
        def body(c, x):
            parts = []
            parts.append(x)
            return c, parts[0]
        def run(xs):
            return lax.scan(body, 0.0, xs)
    """) == []


# ---------------------------------------------------------------------------
# JAX002: concrete bool checks on traced parameters
# ---------------------------------------------------------------------------

def test_bool_check_on_traced_param_warns():
    assert rules("""
        import jax
        @jax.jit
        def f(x, flag):
            if flag:
                return x
            return -x
    """, severity="warning") == ["JAX002"]


def test_static_argnames_param_is_exempt():
    assert rules("""
        import functools, jax
        @functools.partial(jax.jit, static_argnames=("causal", "window"))
        def f(x, causal, window):
            if causal:
                return x
            return -x
    """) == []


def test_static_argnums_param_is_exempt():
    assert rules("""
        import functools, jax
        @functools.partial(jax.jit, static_argnums=(1,))
        def f(x, causal):
            if causal:
                return x
            return -x
    """) == []


def test_is_none_checks_do_not_fire():
    assert rules("""
        import jax
        @jax.jit
        def f(x, mask):
            if mask is None:
                return x
            return x * mask
    """) == []


# ---------------------------------------------------------------------------
# JAX003: unhashable static args
# ---------------------------------------------------------------------------

def test_mutable_static_default_is_error():
    assert rules("""
        import functools, jax
        @functools.partial(jax.jit, static_argnames=("shape",))
        def f(x, shape=[1, 2]):
            return x.reshape(shape)
    """, severity="error") == ["JAX003"]


def test_tuple_static_default_is_fine():
    assert rules("""
        import functools, jax
        @functools.partial(jax.jit, static_argnames=("shape",))
        def f(x, shape=(1, 2)):
            return x.reshape(shape)
    """) == []


# ---------------------------------------------------------------------------
# JAX004: repro/core/ stays NumPy-only
# ---------------------------------------------------------------------------

def test_jax_import_in_core_is_error():
    assert rules("import jax.numpy as jnp\n",
                 path="src/repro/core/cost_model.py") == ["JAX004"]
    assert rules("from jax import lax\n",
                 path="src/repro/core/dp_search.py") == ["JAX004"]


def test_core_profiler_is_the_sanctioned_exception():
    assert rules("import jax\n", path="src/repro/core/profiler.py") == []


def test_jax_import_outside_core_is_fine():
    assert rules("import jax\n", path="src/repro/runtime/pipeline.py") == []


def test_syntax_error_is_reported_not_raised():
    assert rules("def broken(:\n") == ["JAX000"]


# ---------------------------------------------------------------------------
# the CI contract: the real tree is clean
# ---------------------------------------------------------------------------

def test_src_tree_lints_clean():
    diags = lint_paths([str(REPO / "src")])
    assert diags == [], "\n".join(d.format() for d in diags)
