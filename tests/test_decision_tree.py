"""Search-space construction: reproduces the paper's exact counts and rules."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sampler
    from _hypothesis_compat import given, settings, st

from repro.core import construct_search_space, enumerate_strategies
from repro.core.strategy import DP, SDP, TP, Strategy


def test_paper_counts_8_gpus():
    # §III-B: 68 strategies before Takeaway #3, 44 after.
    assert construct_search_space(8, prune_dp_sdp=False).total_leaves() == 68
    assert construct_search_space(8).total_leaves() == 44


def test_per_pp_counts_8_gpus():
    ss = construct_search_space(8)
    assert len(ss.strategies(8)) == 2     # group=1: serial +/- ckpt
    assert len(ss.strategies(4)) == 6     # group=2
    assert len(ss.strategies(2)) == 14    # group=4
    assert len(ss.strategies(1)) == 22    # group=8


def test_no_dp_sdp_mix():
    for pp, strats in construct_search_space(16).per_pp.items():
        for s in strats:
            used = {p for p, _ in s.levels}
            assert not ({DP, SDP} <= used), s.name()


def test_ckpt_doubles_space():
    with_ = construct_search_space(8, allow_ckpt=True).total_leaves()
    without = construct_search_space(8, allow_ckpt=False).total_leaves()
    assert with_ == 2 * without


@given(st.integers(min_value=0, max_value=6))
@settings(max_examples=7, deadline=None)
def test_strategies_cover_group(k):
    n = 2 ** k
    for s in enumerate_strategies(n):
        assert s.total == n
        for _, deg in s.levels:
            assert deg >= 2 and (deg & (deg - 1)) == 0   # power of two
        # paradigms never repeat across levels
        paras = [p for p, _ in s.levels]
        assert len(paras) == len(set(paras))


def test_max_tp_filter():
    ss = construct_search_space(8, max_tp=2)
    for strats in ss.per_pp.values():
        assert all(s.tp <= 2 for s in strats)


def test_strategy_roundtrip():
    s = Strategy((("dp", 4), ("tp", 2)), ckpt=True)
    assert Strategy.from_json(s.to_json()) == s
    assert s.dp == 4 and s.tp == 2 and s.sdp == 1
    assert s.data_degree == 4 and s.total == 8
    assert s.name() == "dp4-tp2-ckpt"
