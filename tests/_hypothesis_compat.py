"""Tiny deterministic stand-in for ``hypothesis`` so the property tests keep
running (with reduced coverage) when the real package is not installed.

Usage in test modules::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st

Each strategy deterministically enumerates/samples values from a seeded RNG,
and ``@given`` expands into a plain loop over ``max_examples`` drawn tuples —
no shrinking, no database, but the same test body runs on a spread of inputs
and failures print the offending example.
"""
from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable, List, Sequence

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A deterministic value sampler (mirrors hypothesis' SearchStrategy)."""

    def __init__(self, draw: Callable[[random.Random], Any],
                 edge_cases: Sequence[Any] = ()):
        self._draw = draw
        self._edge_cases = list(edge_cases)

    def example_stream(self, rng: random.Random, n: int) -> List[Any]:
        out = list(self._edge_cases[:n])
        while len(out) < n:
            out.append(self._draw(rng))
        return out


class strategies:
    """Namespace matching ``hypothesis.strategies`` for the subset we use."""

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 1 << 30) -> _Strategy:
        return _Strategy(lambda r: r.randint(min_value, max_value),
                         edge_cases=[min_value, max_value])

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
        return _Strategy(lambda r: r.uniform(min_value, max_value),
                         edge_cases=[min_value, max_value])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda r: bool(r.getrandbits(1)),
                         edge_cases=[False, True])

    @staticmethod
    def sampled_from(options: Sequence[Any]) -> _Strategy:
        options = list(options)
        return _Strategy(lambda r: r.choice(options), edge_cases=options)

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(r: random.Random) -> List[Any]:
            n = r.randint(min_size, max_size)
            return [elements._draw(r) for _ in range(n)]
        return _Strategy(draw)


st = strategies


def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES,
             deadline: Any = None, **_ignored):
    """Decorator: attach the example budget to the test function."""
    def wrap(fn):
        fn._compat_max_examples = max_examples
        return fn
    return wrap


def given(*strats: _Strategy):
    """Decorator: run the test once per deterministically drawn input tuple."""
    def wrap(fn):
        # like real hypothesis, strategies bind right-to-left: the LAST
        # len(strats) parameters receive drawn values (by keyword), and any
        # leading parameters stay visible to pytest as fixtures
        params = list(inspect.signature(fn).parameters.values())
        n_bound = len(strats)
        bound_names = [p.name for p in params[len(params) - n_bound:]]

        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(fn, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(0)
            streams = [s.example_stream(rng, n) for s in strats]
            for example in zip(*streams):
                try:
                    fn(*args, **dict(zip(bound_names, example)), **kwargs)
                except Exception:
                    print(f"Falsifying example ({fn.__name__}): {example!r}")
                    raise
        # hide the strategy-bound params from pytest's fixture resolution
        # (real hypothesis rewrites the signature the same way)
        runner.__signature__ = inspect.Signature(
            params[:len(params) - n_bound])
        del runner.__wrapped__
        return runner
    return wrap
