"""Expert parallelism as a searched axis: cost-model EP terms, the opt-in
search-space extension, the MoE throughput flip (the PR's acceptance
criterion), PLN012 lint, v5 plan round-trip, and the plan -> runtime
policy bridge."""
import json

import numpy as np
import pytest

from repro.core import CLUSTERS, GalvatronOptimizer, ParallelPlan, Strategy
from repro.core.cost_model import (CostModel, CostModelConfig,
                                   _SP_INVALID_TIME)
from repro.core.layerspec import dense_layer, moe_layer
from repro.core.optimizer import OptimizerConfig
from repro.core.strategy import EP, EP_PARADIGMS, PARADIGMS, SP, SP_PARADIGMS

GB = 1024 ** 3
CLUSTER = CLUSTERS["8x-rtx-titan-pcie"]


def _moe_spec(i=0, E=8, k=2, cf=1.25):
    return moe_layer(f"l{i}", 2048, 2048, 16, 16, 8192, E, k,
                     capacity_factor=cf)


def _dense_spec(seq=2048):
    return dense_layer("body", seq, 2048, 16, 16, 8192)


# ---------------------------------------------------------------------------
# search space
# ---------------------------------------------------------------------------

def test_ep_paradigm_is_opt_in():
    assert EP not in PARADIGMS           # paper leaf counts preserved
    assert EP not in SP_PARADIGMS
    assert EP_PARADIGMS == PARADIGMS + (SP, EP)
    opt = GalvatronOptimizer([_moe_spec()], CLUSTER, OptimizerConfig())
    assert all(s.ep == 1
               for pp in opt.search_space.per_pp.values() for s in pp)
    opt_ep = GalvatronOptimizer([_moe_spec()], CLUSTER,
                                OptimizerConfig(use_ep=True))
    assert any(s.ep > 1
               for pp in opt_ep.search_space.per_pp.values() for s in pp)


def test_use_ep_composes_with_use_sp():
    opt = GalvatronOptimizer([_moe_spec()], CLUSTER,
                             OptimizerConfig(use_sp=True, use_ep=True))
    degrees = {(s.sp, s.ep)
               for pp in opt.search_space.per_pp.values() for s in pp}
    assert any(sp > 1 for sp, _ in degrees)
    assert any(ep > 1 for _, ep in degrees)


def test_max_ep_caps_the_searched_degree():
    opt = GalvatronOptimizer([_moe_spec()], CLUSTER,
                             OptimizerConfig(use_ep=True, max_ep=2))
    eps = {s.ep for pp in opt.search_space.per_pp.values() for s in pp}
    assert max(eps) == 2


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_ep_shards_expert_states_and_prices_all_to_all():
    cm = CostModel(CLUSTER)
    spec = _moe_spec()
    plain = cm.layer_costs(spec, Strategy((("dp", 8),), ckpt=False), 4.0)
    ep8 = cm.layer_costs(spec, Strategy((("ep", 8),), ckpt=False), 4.0)
    # expert params / optimizer state shrink by ep (dense part replicates)
    assert ep8.mem_ms < plain.mem_ms
    exp_frac = spec.expert_param_frac
    expect = plain.mem_ms * ((1 - exp_frac) + exp_frac / 8)
    assert ep8.mem_ms == pytest.approx(expect, rel=1e-6)
    # all-to-all is on the critical path: finite, positive time
    assert 0 < ep8.time < _SP_INVALID_TIME


def test_ep_invalid_for_dense_and_non_dividing_experts():
    cm = CostModel(CLUSTER)
    c = cm.layer_costs(_dense_spec(), Strategy((("ep", 4),), ckpt=False), 4.0)
    assert c.time == _SP_INVALID_TIME            # no experts to shard
    odd = _moe_spec(E=6)                         # 6 % 4 != 0
    c2 = cm.layer_costs(odd, Strategy((("ep", 4),), ckpt=False), 4.0)
    assert c2.time == _SP_INVALID_TIME
    ok = cm.layer_costs(odd, Strategy((("ep", 2),), ckpt=False), 4.0)
    assert ok.time < _SP_INVALID_TIME            # 6 % 2 == 0
    assert np.isfinite(c2.mem_f) and np.isfinite(c2.mem_ms)


def test_ep_imbalance_penalizes_hot_ranks():
    spec = _moe_spec()
    even = CostModel(CLUSTER).layer_costs(
        spec, Strategy((("ep", 8),), ckpt=False), 4.0)
    hot = CostModel(CLUSTER, CostModelConfig(ep_imbalance=0.5)).layer_costs(
        spec, Strategy((("ep", 8),), ckpt=False), 4.0)
    assert hot.time > even.time
    # imbalance does not touch ep=1 strategies at all
    s1 = Strategy((("dp", 8),), ckpt=False)
    assert (CostModel(CLUSTER, CostModelConfig(ep_imbalance=0.5))
            .layer_costs(spec, s1, 4.0).time
            == CostModel(CLUSTER).layer_costs(spec, s1, 4.0).time)


def test_scalar_and_vectorized_ep_tables_agree_exactly():
    cm = CostModel(CLUSTER, CostModelConfig(ep_imbalance=0.2))
    specs = [_moe_spec(), _moe_spec(E=6), _dense_spec()]
    strats = [Strategy((("ep", 8),), ckpt=False),
              Strategy((("ep", 2), ("dp", 4)), ckpt=True),
              Strategy((("ep", 2), ("tp", 2), ("sdp", 2)), ckpt=False),
              Strategy((("sp", 2), ("ep", 4)), ckpt=False),
              Strategy((("dp", 8),), ckpt=False)]
    tables = cm.layer_cost_tables(specs, strats, 8.0, inflight=2)
    for i, spec in enumerate(specs):
        for j, s in enumerate(strats):
            c = cm.layer_costs(spec, s, 8.0, inflight=2)
            assert tables.time_sync[i, j] == c.time, (i, j)
            assert tables.time_nosync[i, j] == c.time_nosync, (i, j)
            assert tables.mem_f[i, j] == c.mem_f, (i, j)
            assert tables.mem_ms[i, j] == c.mem_ms, (i, j)


# ---------------------------------------------------------------------------
# the acceptance criterion: MoE throughput flip
# ---------------------------------------------------------------------------

def _moe_setup():
    specs = [_moe_spec(i) for i in range(4)]
    base = dict(batch_grid=(8,), micro_candidates=2, n_bins=64)
    return specs, base


def test_moe_slower_at_ep1_faster_with_ep():
    """At the pinned 6 GB budget every ep=1 plan is strictly slower than
    the certified ep>1 plan the EP-enabled search finds — the flip
    BENCH_moe.json records."""
    specs, base = _moe_setup()
    budget = [6 * GB]
    p1 = GalvatronOptimizer(specs, CLUSTER, OptimizerConfig(**base)) \
        .sweep_budgets(budget).points[0].plan
    p2 = GalvatronOptimizer(specs, CLUSTER,
                            OptimizerConfig(use_ep=True, **base)) \
        .sweep_budgets(budget).points[0].plan
    assert p1 is not None and p2 is not None
    assert p1.ep_degree == 1
    assert p2.ep_degree > 1
    assert p2.est_throughput > p1.est_throughput
    # the emitted plan certifies (no errors; PLN012 included)
    from repro.analysis import verify_plan_json
    diags = verify_plan_json(p2.to_json())
    assert not [d for d in diags if d.severity == "error"], diags


def test_ep1_plans_bit_identical_with_use_ep_off():
    """use_ep=False (the default) must not perturb the search at all —
    byte-identical canonical plans, the default-off discipline."""
    specs, base = _moe_setup()
    p1 = GalvatronOptimizer(specs, CLUSTER, OptimizerConfig(**base)) \
        .sweep_budgets([8 * GB]).points[0].plan
    p2 = GalvatronOptimizer(specs, CLUSTER,
                            OptimizerConfig(use_ep=False, **base)) \
        .sweep_budgets([8 * GB]).points[0].plan
    assert p1.canonical_dumps() == p2.canonical_dumps()
    assert p1.ep_degree == 1


def test_ep_search_where_ep_loses_never_hurts():
    # ample budget: the ep=1 winner survives the superset search
    specs, base = _moe_setup()
    p1 = GalvatronOptimizer(specs, CLUSTER, OptimizerConfig(**base)) \
        .sweep_budgets([12 * GB]).points[0].plan
    p2 = GalvatronOptimizer(specs, CLUSTER,
                            OptimizerConfig(use_ep=True, **base)) \
        .sweep_budgets([12 * GB]).points[0].plan
    assert p1 is not None and p2 is not None
    assert p2.est_throughput >= p1.est_throughput * (1 - 1e-9)


# ---------------------------------------------------------------------------
# PLN012 lint
# ---------------------------------------------------------------------------

def _plan(ep_degree=1, strategies=None, pp=1, n_dev=8):
    strategies = strategies or [Strategy((("dp", 8 // pp),), ckpt=False)] * 4
    return ParallelPlan(
        n_devices=n_dev, pp_degree=pp, partition=[4 // pp] * pp,
        strategies=strategies, global_batch=8, n_micro=1,
        ep_degree=ep_degree)


def _diags(plan):
    from repro.analysis import verify_plan_json
    return [d for d in verify_plan_json(plan.to_json())
            if d.rule == "PLN012"]


def test_pln012_ep_degree_must_divide_device_groups():
    strats = [Strategy((("ep", 2), ("dp", 4)),)] * 4
    bad = _plan(ep_degree=3, strategies=strats)
    assert any(d.severity == "error" and "divide" in d.message
               for d in _diags(bad)), _diags(bad)
    ok = _plan(ep_degree=2, strategies=strats)
    assert not [d for d in _diags(ok) if d.severity == "error"]


def test_pln012_layer_ep_exceeding_stamp_is_an_error():
    strats = [Strategy((("ep", 4), ("dp", 2)),)] * 4
    bad = _plan(ep_degree=2, strategies=strats)
    assert any(d.severity == "error" and "ep_degree" in d.location
               for d in _diags(bad))


def test_pln012_unused_axis_is_a_warning():
    # stamp claims ep=2 but every layer runs ep=1: the axis buys nothing
    bad = _plan(ep_degree=2)
    found = _diags(bad)
    assert any(d.severity == "warning" for d in found), found


def test_pln012_mixed_degrees_dense_plus_moe_is_info_only():
    strats = ([Strategy((("dp", 8),), ckpt=False)] * 2
              + [Strategy((("ep", 2), ("dp", 4)),)] * 2)
    found = _diags(_plan(ep_degree=2, strategies=strats))
    assert found and all(d.severity == "info" for d in found), found


def test_pln012_silent_on_ep1_plans():
    assert _diags(_plan()) == []


# ---------------------------------------------------------------------------
# plan format v5
# ---------------------------------------------------------------------------

def test_v5_ep_degree_roundtrips_and_validates():
    strats = [Strategy((("ep", 2), ("dp", 4)),)] * 4
    plan = _plan(ep_degree=2, strategies=strats)
    plan2 = ParallelPlan.loads(plan.dumps())
    assert plan2 == plan
    assert plan2.ep_degree == 2
    with pytest.raises(ValueError, match="ep_degree"):
        _plan(ep_degree=0)


def test_v4_json_without_ep_degree_still_loads():
    d = _plan().to_json()
    del d["ep_degree"]                # v4-era plan JSON has no ep key
    d["format_version"] = 4
    plan = ParallelPlan.from_json(d)
    assert plan.ep_degree == 1


def test_detect_format_version_ep():
    from repro.analysis.plan_lint import detect_format_version
    d = json.loads(_plan(ep_degree=2).dumps())
    del d["format_version"]
    assert detect_format_version(d) == 5
    d1 = json.loads(_plan().dumps())
    del d1["format_version"]          # ep_degree=1 alone does not imply v5
    del d1["ep_degree"]
    assert detect_format_version(d1) < 5


# ---------------------------------------------------------------------------
# plan -> runtime bridge
# ---------------------------------------------------------------------------

def test_policy_from_plan_carries_ep_degree():
    from repro.configs import get_config
    from repro.runtime.plan_bridge import policy_from_plan
    cfg = get_config("qwen3-4b")
    strats = [Strategy((("ep", 4), ("dp", 2)),)] * cfg.n_layers
    plan = ParallelPlan(
        n_devices=8, pp_degree=1, partition=[cfg.n_layers],
        strategies=strats, global_batch=8, n_micro=1, ep_degree=4)
    pol = policy_from_plan(cfg, plan)
    assert pol.ep_degree == 4
    assert pol.expert_axis == "expert"
    pol1 = policy_from_plan(cfg, ParallelPlan(
        n_devices=8, pp_degree=1, partition=[cfg.n_layers],
        strategies=[Strategy((("dp", 8),), ckpt=False)] * cfg.n_layers,
        global_batch=8, n_micro=1))
    assert pol1.ep_degree == 1 and pol1.expert_axis == "model"


def test_shard_policy_from_strategy_stamps_ep():
    from repro.runtime import ShardPolicy
    pol = ShardPolicy.from_strategy(Strategy((("ep", 4), ("dp", 2)),))
    assert pol.ep_degree == 4 and pol.expert_axis == "expert"
    pol1 = ShardPolicy.from_strategy(Strategy((("dp", 8),), ckpt=False))
    assert pol1.ep_degree == 1 and pol1.expert_axis == "model"


def test_search_cli_wires_ep_flags():
    from repro.launch.search import build_optimizer
    import argparse
    args = argparse.Namespace(
        variant="bmw", batch_grid="", n_bins=64, micro_candidates=2,
        max_pp=0, schedules="", backend="", jobs=0, prune=True,
        sp=False, max_sp=0, ep=True, max_ep=2,
        min_samples_per_device=0.0)
    opt = build_optimizer([_moe_spec()], CLUSTER, args)
    assert opt.cfg.use_ep and opt.cfg.max_ep == 2
    eps = {s.ep for pp in opt.search_space.per_pp.values() for s in pp}
    assert max(eps) == 2
