"""BMW balance machinery: exact partitioning, balance degrees, Eq. 7/8
invariants of the adjustment step."""
import itertools

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sampler
    from _hypothesis_compat import given, settings, st

from repro.core.pipeline_balance import (PartitionEval, adjust_partition,
                                         balance_degrees,
                                         inflight_microbatches,
                                         memory_balanced_partition,
                                         stage_bounds,
                                         time_balanced_partition,
                                         validate_adjustment)


def _brute_partition(loads, P):
    L = len(loads)
    best, best_p = float("inf"), None
    for cuts in itertools.combinations(range(1, L), P - 1):
        bounds = [0, *cuts, L]
        parts = [bounds[i + 1] - bounds[i] for i in range(P)]
        m = max(sum(loads[bounds[i]:bounds[i + 1]]) for i in range(P))
        if m < best:
            best, best_p = m, parts
    return best, best_p


@given(st.lists(st.floats(min_value=0.1, max_value=10.0), min_size=4,
                max_size=9), st.integers(min_value=2, max_value=4))
@settings(max_examples=30, deadline=None)
def test_time_partition_optimal(loads, P):
    if P > len(loads):
        return
    parts = time_balanced_partition(loads, P)
    assert sum(parts) == len(loads) and len(parts) == P
    assert all(p >= 1 for p in parts)
    got = max(sum(loads[a:b]) for a, b in stage_bounds(parts))
    best, _ = _brute_partition(loads, P)
    assert got <= best + 1e-9


def test_inflight_1f1b_vs_gpipe():
    # 1F1B: stage 0 of 4 holds 4 micro-batches, last stage holds 1
    assert inflight_microbatches(0, 4, 8) == 4
    assert inflight_microbatches(3, 4, 8) == 1
    assert inflight_microbatches(0, 4, 2) == 2      # capped by m
    assert inflight_microbatches(0, 4, 8, "gpipe") == 8


def test_inflight_interleaved_per_chunk_accounting():
    # P=4, V=2: device 0 warms up 2*3 + (2-1)*4 + 1 = 11 chunk activation
    # sets = 5.5 full-stage units; device 3 (last) 2*0 + 4 + 1 = 5 -> 2.5
    assert inflight_microbatches(0, 4, 16, "1f1b-interleaved", vpp=2) == 5.5
    assert inflight_microbatches(3, 4, 16, "1f1b-interleaved", vpp=2) == 2.5
    # capped by the m*V chunks that exist
    assert inflight_microbatches(0, 4, 4, "1f1b-interleaved", vpp=2) == 4.0
    # V=1 falls back to plain 1F1B
    assert inflight_microbatches(0, 4, 8, "1f1b-interleaved", vpp=1) == 4


def test_memory_partition_counteracts_1f1b():
    """Uniform layers: the memory-balanced 1F1B partition puts FEWER layers
    on shallow stages (they hold more in-flight micro-batches)."""
    mems = [1.0] * 16
    p = memory_balanced_partition(mems, 4, n_micro=8)
    assert sum(p) == 16
    assert p[0] <= p[-1]


def test_balance_degrees_bounds():
    t, m = balance_degrees([1.0, 1.0, 1.0, 1.0], [4.0, 3.0, 2.0, 1.0])
    assert abs(t - 0.75) < 1e-9          # perfect time balance: 1 - 1/P
    assert 0.0 <= m <= 0.75


def test_adjust_moves_from_slowest():
    parts = adjust_partition([4, 4, 4, 4], [1.0, 9.0, 1.0, 1.0])
    assert [3, 5] not in parts           # moved from stage 1 only
    assert any(p[1] == 3 for p in parts)
    for p in parts:
        assert sum(p) == 16


def test_validate_criteria():
    ok = PartitionEval([3, 5], [1.0, 2.0], [1.0, 2.0], [5.0, 5.0], True)
    assert validate_adjustment(ok, prev_max_time=3.0, budget=6.0,
                               pt_max_mem=5.5)
    # (1) slower than previous max
    assert not validate_adjustment(ok, 1.5, 6.0, 5.5)
    # (2) over budget
    assert not validate_adjustment(ok, 3.0, 4.0, 5.5)
    # (3) above time-balanced partition's max memory
    assert not validate_adjustment(ok, 3.0, 6.0, 4.0)
    bad = PartitionEval([3, 5], [1.0, 2.0], [1.0, 2.0], [5.0, 5.0], False)
    assert not validate_adjustment(bad, 3.0, 6.0, 5.5)
