"""Tentpole invariants: the memoized + vectorized search engine must be a
pure speedup — byte-identical plans, bit-identical cost tables."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sampler
    from _hypothesis_compat import given, settings, st

from repro.core import (CostModel, GalvatronOptimizer, enumerate_strategies,
                        galvatron_variant, paper_8gpu, paper_16gpu_low,
                        strategy_set_id)
from repro.core.dp_search import dp_search_stage, dp_search_stage_reference
from repro.core.layerspec import dense_layer, head_layer, moe_layer

GB = 1024 ** 3


def _specs(n=8, seq=512, d=1024):
    return [dense_layer(f"l{i}", seq, d, 16, 16, 4 * d,
                        store_attn_matrix=True) for i in range(n)]


def _optimize(specs, cluster, **kw):
    cfg = galvatron_variant("bmw")
    cfg.batch_grid = [8, 16]
    cfg.n_bins = 128
    cfg.micro_candidates = 2
    for k, v in kw.items():
        setattr(cfg, k, v)
    opt = GalvatronOptimizer(specs, cluster, cfg)
    return opt.optimize(), opt.stats


# ---------------------------------------------------------------------------
# memo cache: byte-identical plans, nonzero hit counts
# ---------------------------------------------------------------------------

def test_cache_on_off_identical_plans_and_nonzero_hits():
    specs = _specs(8)
    cluster = paper_8gpu().with_budget(8 * GB)
    cached, stats = _optimize(specs, cluster)
    uncached, stats_off = _optimize(specs, cluster, enable_stage_cache=False)
    assert cached is not None and uncached is not None
    assert cached == uncached                   # ParallelPlan equality
    assert stats["stage_cache_hits"] > 0
    assert stats_off["stage_cache_hits"] == 0


def test_seed_mode_identical_plans():
    """Full legacy mode (reference DP + no caches) finds the same plan."""
    specs = _specs(8)
    cluster = paper_16gpu_low().with_budget(6 * GB)
    fast, _ = _optimize(specs, cluster)
    seed, _ = _optimize(specs, cluster, enable_stage_cache=False,
                        vectorized_cost=False)
    assert fast == seed


def test_stage_cache_persists_across_optimize_calls():
    """ROADMAP "next rungs" item: repeated optimize() on one instance
    reuses the stage cache; clear_cache() is the escape hatch."""
    specs = _specs(8)
    cluster = paper_8gpu().with_budget(8 * GB)
    cfg = galvatron_variant("bmw")
    cfg.batch_grid = [8, 16]
    cfg.n_bins = 128
    cfg.micro_candidates = 2
    opt = GalvatronOptimizer(specs, cluster, cfg)
    p1 = opt.optimize()
    h1, m1 = opt.stats["stage_cache_hits"], opt.stats["stage_cache_misses"]
    p2 = opt.optimize()
    assert p2 == p1
    # second sweep is identical -> every stage search is a hit, no new misses
    assert opt.stats["stage_cache_misses"] == m1
    assert opt.stats["stage_cache_hits"] > h1
    # cumulative telemetry is threaded into the plan
    assert p2.search_stats["stage_cache_hits"] == opt.stats["stage_cache_hits"]
    opt.clear_cache()
    # clear_cache() zeroes the telemetry too: the instance is
    # indistinguishable from a freshly constructed one
    assert all(v == 0 for v in opt.stats.values())
    p3 = opt.optimize()
    assert p3 == p1
    # cache really dropped: the re-search replays the cold-start miss count
    # (all hits would leave misses at 0)
    assert opt.stats["stage_cache_misses"] == m1
    assert opt.stats["stage_cache_hits"] == h1


def test_plan_carries_search_stats_but_compares_equal():
    specs = _specs(6)
    cluster = paper_8gpu().with_budget(8 * GB)
    plan, _ = _optimize(specs, cluster)
    assert plan.search_stats is not None
    assert plan.search_stats["stage_searches"] > 0
    # telemetry must not break plan equality (compare=False field)
    other, _ = _optimize(specs, cluster, enable_stage_cache=False)
    assert plan.search_stats != other.search_stats
    assert plan == other


# ---------------------------------------------------------------------------
# vectorized tables == scalar layer_costs
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=4),
       st.sampled_from([2, 4, 8]),
       st.floats(min_value=0.5, max_value=64.0),
       st.integers(min_value=1, max_value=6),
       st.booleans())
@settings(max_examples=20, deadline=None)
def test_tables_match_scalar_within_1e9(n_layers, group, B_m, inflight, moe):
    cluster = paper_16gpu_low()
    specs = [dense_layer(f"l{i}", 256 * (1 + i % 3), 512, 8, 8, 2048,
                         store_attn_matrix=bool(i % 2))
             for i in range(n_layers)]
    if moe:
        specs.append(moe_layer("moe", 256, 512, 8, 8, 1024, 8, 2))
    specs.append(head_layer("head", 256, 512, 32000))
    cm = CostModel(cluster, profiled_times={"l0": 1.3e-3})
    strategies = enumerate_strategies(group)
    tb = cm.layer_cost_tables(specs, strategies, B_m, inflight=inflight)
    for l, sp in enumerate(specs):
        for j, s in enumerate(strategies):
            c = cm.layer_costs(sp, s, B_m, inflight=inflight)
            r = cm.reshard_cost(sp, s, B_m)
            for got, want in [(tb.time_sync[l, j], c.time),
                              (tb.time_nosync[l, j], c.time_nosync),
                              (tb.time_fwd[l, j], c.time_fwd),
                              (tb.mem_f[l, j], c.mem_f),
                              (tb.mem_b[l, j], c.mem_b),
                              (tb.mem_ms[l, j], c.mem_ms),
                              (tb.reshard[l, j], r)]:
                assert got == pytest.approx(want, rel=1e-9, abs=1e-30)


# ---------------------------------------------------------------------------
# vectorized stage DP == seed reference implementation
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=6),
       st.floats(min_value=1.0, max_value=16.0),
       st.integers(min_value=1, max_value=4),
       st.sampled_from([1, 4, 8]))
@settings(max_examples=15, deadline=None)
def test_dp_matches_reference_implementation(n_layers, budget_gb, inflight,
                                             n_micro):
    cm = CostModel(paper_8gpu())
    specs = _specs(n_layers, seq=256, d=512)
    strategies = enumerate_strategies(8)
    kw = dict(inflight=inflight, n_bins=128, n_micro=n_micro)
    fast = dp_search_stage(specs, strategies, cm, 8.0, budget_gb * GB, **kw)
    ref = dp_search_stage_reference(specs, strategies, cm, 8.0,
                                    budget_gb * GB, **kw)
    assert fast.feasible == ref.feasible
    if ref.feasible:
        assert fast.time == ref.time
        assert fast.time_nosync == ref.time_nosync
        assert fast.e_all == ref.e_all
        assert fast.e_fwd == ref.e_fwd
        assert fast.strategies == ref.strategies


def test_strategy_set_id_stable():
    a = enumerate_strategies(8)
    b = enumerate_strategies(8)
    assert a is not b
    assert strategy_set_id(a) == strategy_set_id(b)
    assert strategy_set_id(a) != strategy_set_id(enumerate_strategies(4))


def test_cost_tables_row_slice_is_view():
    cm = CostModel(paper_8gpu())
    tb = cm.layer_cost_tables(_specs(6), enumerate_strategies(4), 8.0)
    sl = tb.rows(2, 5)
    assert sl.time_sync.shape[0] == 3
    assert np.shares_memory(sl.time_sync, tb.time_sync)
