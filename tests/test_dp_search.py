"""Dynamic-programming search: optimality vs brute force, monotonicity,
budget compliance (Alg. 3)."""
import itertools

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sampler
    from _hypothesis_compat import given, settings, st

from repro.core import (CostModel, Strategy, dp_search_stage,
                        enumerate_strategies, paper_8gpu)
from repro.core.dp_search import _exact_e_all
from repro.core.layerspec import dense_layer

GB = 1024 ** 3


def _specs(n=4, seq=512, d=512):
    return [dense_layer(f"l{i}", seq, d, 8, 8, 4 * d, causal=False,
                        gated=False, store_attn_matrix=True)
            for i in range(n)]


def _brute_force(specs, strategies, cm, mb, budget):
    best = (float("inf"), None)
    L = len(specs)
    tables = [[cm.layer_costs(sp, s, mb) for s in strategies] for sp in specs]
    for choice in itertools.product(range(len(strategies)), repeat=L):
        mem_f = np.array([[tables[l][j].mem_f for j in range(len(strategies))]
                          for l in range(L)])
        mem_b = np.array([[tables[l][j].mem_b for j in range(len(strategies))]
                          for l in range(L)])
        mem_ms = np.array([[tables[l][j].mem_ms for j in range(len(strategies))]
                           for l in range(L)])
        e_all = _exact_e_all(mem_f, mem_b, mem_ms, list(choice))
        if e_all > budget:
            continue
        t = sum(tables[l][j].time for l, j in enumerate(choice))
        for l in range(1, L):
            if strategies[choice[l]].levels != strategies[choice[l - 1]].levels:
                t += cm.reshard_cost(specs[l], strategies[choice[l]], mb)
        if t < best[0]:
            best = (t, choice)
    return best


@pytest.mark.parametrize("budget_gb", [2.0, 4.0, 8.0])
def test_dp_matches_brute_force(budget_gb):
    cm = CostModel(paper_8gpu())
    specs = _specs(3)
    strategies = enumerate_strategies(4)[:6]   # keep brute force tractable
    res = dp_search_stage(specs, strategies, cm, 8.0, budget_gb * GB,
                          n_bins=2048)
    bf_t, bf_choice = _brute_force(specs, strategies, cm, 8.0, budget_gb * GB)
    if bf_choice is None:
        assert not res.feasible
        return
    assert res.feasible
    # DP quantizes memory into bins -> allow small slack vs exact brute force
    assert res.time <= bf_t * 1.05 + 1e-9
    assert res.e_all <= budget_gb * GB * 1.01


@given(st.floats(min_value=1.0, max_value=12.0))
@settings(max_examples=10, deadline=None)
def test_monotone_in_budget(budget_gb):
    cm = CostModel(paper_8gpu())
    specs = _specs(4)
    strategies = enumerate_strategies(8)
    small = dp_search_stage(specs, strategies, cm, 8.0, budget_gb * GB)
    big = dp_search_stage(specs, strategies, cm, 8.0, 2 * budget_gb * GB)
    if small.feasible:
        assert big.feasible
        assert big.time <= small.time + 1e-9


def test_budget_respected():
    cm = CostModel(paper_8gpu())
    specs = _specs(6)
    strategies = enumerate_strategies(8)
    budget = 4.0 * GB
    res = dp_search_stage(specs, strategies, cm, 16.0, budget)
    assert res.feasible
    assert res.e_all <= budget * 1.001
    assert len(res.strategies) == 6


def test_infeasible_when_budget_tiny():
    cm = CostModel(paper_8gpu())
    res = dp_search_stage(_specs(4), enumerate_strategies(8), cm, 64.0,
                          16 * 1024 ** 2)   # 16MB: nothing fits
    assert not res.feasible


def test_ckpt_chosen_under_pressure():
    """With a tight budget the DP should turn CKPT on for some layers."""
    cm = CostModel(paper_8gpu())
    specs = _specs(8, seq=1024, d=1024)
    strategies = enumerate_strategies(8)
    loose = dp_search_stage(specs, strategies, cm, 32.0, 20 * GB)
    tight = dp_search_stage(specs, strategies, cm, 32.0, 3 * GB)
    assert loose.feasible and tight.feasible
    n_ckpt_tight = sum(s.ckpt for s in tight.strategies)
    n_ckpt_loose = sum(s.ckpt for s in loose.strategies)
    assert n_ckpt_tight >= n_ckpt_loose
    assert tight.time >= loose.time
