"""End-to-end Galvatron search: reproduces the paper's *relative* claims on
a small instance (8 GPUs, BERT-Huge-32-like)."""
import pytest

from repro.core import (GalvatronOptimizer, OptimizerConfig, deepspeed_3d,
                        galvatron_variant, paper_8gpu, pure_baseline)
from repro.configs.paper_models import paper_model_specs

GB = 1024 ** 3
GRID = [8, 16, 32, 64]


@pytest.fixture(scope="module")
def specs():
    return paper_model_specs("bert-huge-32")


def _tpt(specs, cluster, cfg):
    cfg.batch_grid = GRID
    cfg.n_bins = 128
    cfg.micro_candidates = 3
    plan = GalvatronOptimizer(specs, cluster, cfg).optimize()
    return plan.est_throughput if plan else 0.0


@pytest.fixture(scope="module")
def throughputs(specs):
    cluster = paper_8gpu().with_budget(8 * GB)
    out = {}
    for name, cfg in [
        ("dp", pure_baseline("dp", 8)),
        ("tp", pure_baseline("tp", 8)),
        ("pp", pure_baseline("pp", 8)),
        ("sdp", pure_baseline("sdp", 8)),
        ("3d", deepspeed_3d(8)),
        ("dp+tp", galvatron_variant("dp+tp")),
        ("dp+pp", galvatron_variant("dp+pp")),
        ("galvatron", galvatron_variant("galvatron")),
        ("base", galvatron_variant("base")),
        ("bmw", galvatron_variant("bmw")),
    ]:
        out[name] = _tpt(specs, cluster, cfg)
    return out


def test_pure_dp_ooms_at_8gb(throughputs):
    # Table II: PyTorch DDP OOMs on BERT-Huge-32 under 8G.
    assert throughputs["dp"] == 0.0


def test_hybrid_beats_every_pure_strategy(throughputs):
    best_pure = max(throughputs[k] for k in ("dp", "tp", "pp", "sdp"))
    assert throughputs["galvatron"] >= best_pure


def test_full_space_beats_limited_dimensions(throughputs):
    # Galvatron(4-dim) >= DP+TP and DP+PP automatic baselines
    assert throughputs["galvatron"] >= throughputs["dp+tp"] - 1e-9
    assert throughputs["galvatron"] >= throughputs["dp+pp"] - 1e-9


def test_ckpt_dimension_helps_under_tight_memory(throughputs):
    # Galvatron-Base (5-dim incl CKPT) >= Galvatron (4-dim) at 8GB
    assert throughputs["base"] >= throughputs["galvatron"] - 1e-9


def test_bmw_is_best_overall(throughputs):
    best_other = max(v for k, v in throughputs.items() if k != "bmw")
    assert throughputs["bmw"] >= best_other * 0.999


def test_search_returns_valid_plan(specs):
    cluster = paper_8gpu().with_budget(16 * GB)
    cfg = galvatron_variant("bmw")
    cfg.batch_grid = [16, 32]
    cfg.n_bins = 128
    plan = GalvatronOptimizer(specs, cluster, cfg).optimize()
    assert plan is not None
    assert sum(plan.partition) == len(specs)
    assert len(plan.strategies) == len(specs)
    assert all(s.total * plan.pp_degree == 8 for s in plan.strategies)
    assert plan.est_stage_mem is not None
    assert max(plan.est_stage_mem) <= 16 * GB * 1.01


def test_search_time_scales_linearly():
    """Fig. 5a: search time grows ~linearly with layer count."""
    import time
    from repro.core.layerspec import dense_layer
    cluster = paper_8gpu().with_budget(8 * GB)

    def run(n_layers):
        specs = [dense_layer(f"l{i}", 512, 768, 12, 12, 3072,
                             store_attn_matrix=True) for i in range(n_layers)]
        cfg = galvatron_variant("base")
        cfg.batch_grid = [16]
        cfg.n_bins = 128
        t0 = time.time()
        GalvatronOptimizer(specs, cluster, cfg).optimize()
        return time.time() - t0

    t8, t32 = run(8), run(32)
    # 4x layers should cost clearly less than ~12x time (linear-ish, noisy CI)
    assert t32 < 12 * max(t8, 0.05)
