"""Optimizer, data pipeline, checkpointing, plan serialization, roofline."""
import math
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParallelPlan, Strategy
from repro.data import DataConfig, batch_specs, synthetic_lm_batches, text_corpus_batches
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_limits_update():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(params, g, opt, cfg)
    assert float(metrics["grad_norm"]) > 1e5   # reported raw norm


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, 10, 100)) < 0.2
    assert abs(float(cosine_schedule(10, 10, 100)) - 1.0) < 1e-5
    assert float(cosine_schedule(100, 10, 100)) <= 0.11


def test_adamw_states_match_param_tree():
    params = {"a": jnp.zeros((2, 3), jnp.bfloat16), "b": [jnp.ones(4)]}
    opt = adamw_init(params)
    assert opt["master"]["a"].dtype == jnp.float32
    assert opt["m"]["b"][0].shape == (4,)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_batches_deterministic():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab_size=100, seed=7)
    a = next(synthetic_lm_batches(cfg))
    b = next(synthetic_lm_batches(cfg))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    assert a["labels"].shape == (4, 16)
    assert a["tokens"].max() < 100


def test_batch_specs_match_generator():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab_size=100,
                     vision_tokens=8, d_vision=32)
    batch = next(synthetic_lm_batches(cfg))
    specs = batch_specs(cfg)
    assert set(batch) == set(specs)
    for k in batch:
        assert batch[k].shape == specs[k].shape, k


def test_text_corpus_packing(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("hello world, this is a tiny corpus for packing! " * 50)
    cfg = DataConfig(seq_len=32, global_batch=2, vocab_size=256, seed=1)
    gen = text_corpus_batches(p, cfg)
    b1 = next(gen)
    assert b1["tokens"].shape == (2, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpointing import restore_train_state, save_train_state
    params = {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.float32)}}
    opt = adamw_init(params)
    d = save_train_state(42, params, opt, tmp_path)
    assert (d / "params.npz").exists()
    p2, o2, step = restore_train_state(params, opt, tmp_path)
    assert step == 42
    np.testing.assert_array_equal(np.asarray(p2["w"], np.float32),
                                  np.asarray(params["w"], np.float32))
    assert p2["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(o2["m"]["nested"]["b"]),
                                  np.asarray(opt["m"]["nested"]["b"]))


# ---------------------------------------------------------------------------
# plan serialization
# ---------------------------------------------------------------------------

def test_plan_roundtrip():
    plan = ParallelPlan(
        n_devices=8, pp_degree=2, partition=[3, 3],
        strategies=[Strategy((("dp", 2), ("tp", 2)), ckpt=True)] * 6,
        global_batch=64, n_micro=8, est_throughput=12.5)
    plan2 = ParallelPlan.loads(plan.dumps())
    assert plan2.pp_degree == 2
    assert plan2.strategies == plan.strategies
    assert plan2.micro_batch_size == 8
    assert "dp2-tp2-ckpt" in plan2.summary()


# ---------------------------------------------------------------------------
# roofline HLO parsing
# ---------------------------------------------------------------------------

def test_collective_parse_synthetic():
    from repro.roofline import collective_bytes_from_hlo
    hlo = """
  %ag = bf16[16,512]{1,0} all-gather(bf16[1,512] %x), dimensions={0}
  %ar.1 = f32[256]{0} all-reduce(f32[256] %y), to_apply=%add
  %rs = bf16[2,64]{1,0} reduce-scatter(bf16[16,64] %z), dimensions={0}
  %a2a = f32[8,32]{1,0} all-to-all(f32[8,32] %w), dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(bf16[4,4] %v), source_target_pairs={{0,1}}
  %not_a_collective = f32[999] add(f32[999] %a, f32[999] %b)
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 16 * 512 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 2 * 64 * 2
    assert out["all-to-all"] == 8 * 32 * 4
    assert out["collective-permute"] == 4 * 4 * 2


def test_modeled_memory_sanity():
    """Key §Perf finding: the paper-faithful baseline (remat, no sequence
    parallelism) does NOT fit qwen3-8b train_4k on 16GB v5e — the stash of
    layer inputs alone exceeds HBM; sequence-sharding the stash over the
    model axis (Megatron SP, our beyond-paper optimization) fixes it."""
    from repro.configs import get_config
    from repro.configs.specs import layerspecs_for
    from repro.roofline.analysis import modeled_memory
    cfg = get_config("qwen3-8b")
    specs = layerspecs_for(cfg, 4096)
    base = modeled_memory(specs, mode="train", chips=256, tp=16,
                          data_shards=16, remat=True, batch=256)
    assert base.traffic_bytes_per_device > 0
    assert not base.fits                          # stash alone > 16GB
    sp = modeled_memory(specs, mode="train", chips=256, tp=16,
                        data_shards=16, remat=True, batch=256, seq_shard=16)
    assert sp.fits
    assert sp.resident_bytes_per_device < base.resident_bytes_per_device


def test_cross_entropy_matches_naive():
    from repro.models.layers import cross_entropy_loss
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 5, 11))
    labels = jax.random.randint(key, (2, 5), 0, 11)
    got = cross_entropy_loss(logits, labels)
    lf = jax.nn.log_softmax(logits, -1)
    ref = -jnp.take_along_axis(lf, labels[..., None], -1).mean()
    assert abs(float(got) - float(ref)) < 1e-5


# ---------------------------------------------------------------------------
# profiler + plan bridge + low-precision optimizer states
# ---------------------------------------------------------------------------

def test_profiler_produces_positive_times_and_feeds_cost_model():
    from repro.core import CostModel, Strategy, paper_8gpu
    from repro.core.layerspec import dense_layer
    from repro.core.profiler import measure_matmul_throughput, profile_layerspecs
    assert measure_matmul_throughput(256, iters=2) > 1e8   # >0.1 GFLOP/s
    specs = [dense_layer(f"l{i}", 128, 256, 4, 4, 512) for i in range(2)]
    times = profile_layerspecs(specs, iters=1)
    assert set(times) == {"l0", "l1"}
    assert all(t > 0 for t in times.values())
    cm = CostModel(paper_8gpu(), profiled_times=times)
    c = cm.layer_costs(specs[0], Strategy((("dp", 8),)), 8.0)
    assert c.time > 0


def test_plan_bridge_policies():
    from repro.configs import get_config
    from repro.configs.specs import layerspecs_for
    from repro.core import ParallelPlan, Strategy
    from repro.runtime.plan_bridge import policy_from_plan
    cfg = get_config("qwen3-8b")
    s = Strategy((("sdp", 16), ("tp", 16)), ckpt=True)
    plan = ParallelPlan(n_devices=256, pp_degree=1, partition=[cfg.n_layers],
                        strategies=[s] * cfg.n_layers, global_batch=256,
                        n_micro=1)
    pol = policy_from_plan(cfg, plan, specs=layerspecs_for(cfg, 4096))
    assert pol.tp and pol.zero
    assert pol.remat_segments == (True,)
    assert pol.seq_shard        # 8B stash overflows 16G -> §Perf rule fires
    # small model: no seq shard needed
    cfg4 = get_config("qwen3-4b")
    plan4 = ParallelPlan(n_devices=256, pp_degree=1,
                         partition=[cfg4.n_layers],
                         strategies=[s] * cfg4.n_layers, global_batch=256,
                         n_micro=1)
    pol4 = policy_from_plan(cfg4, plan4, specs=layerspecs_for(cfg4, 4096))
    assert not pol4.seq_shard


def test_bf16_optimizer_state_memory_and_convergence():
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    params = {"w": jnp.array([4.0, -2.0])}
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, state_dtype="bf16")
    opt = adamw_init(params, cfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    assert opt["master"]["w"].dtype == jnp.float32
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 5e-2
