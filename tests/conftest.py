"""Shared test utilities.

NOTE: no XLA_FLAGS manipulation here — smoke tests and benches must see the
real 1-device CPU platform.  Multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves (see test_distributed.py).
"""
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def run_subprocess(code: str, *, devices: int = 8, timeout: int = 600):
    """Run python code in a fresh process with N fake devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}")
    return res.stdout


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)
