"""Paged KV-cache page-table invariants (repro.serving.page_table).

The PageManager is pure function-of-state and jit-compatible: every op
returns a new PageState.  These tests check the allocator's accounting —
no double allocation, exact free/used counts, rank-matched grants under
contention, graceful refusal when the pool is exhausted — all of which the
serving engine relies on for correctness (a double-granted page would
silently cross-contaminate two requests' KV).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import PageManager


def mk(n_pages=16, n_slots=4, page_size=8, pages_per_slot=4):
    return PageManager(n_pages=n_pages, n_slots=n_slots,
                       page_size=page_size, pages_per_slot=pages_per_slot)


def owners_consistent(pm, st):
    """page_owner and page_rows must agree exactly."""
    owner = np.asarray(st.page_owner)
    rows = np.asarray(st.page_rows)
    for slot in range(pm.n_slots):
        for p in rows[slot]:
            if p >= 0:
                assert owner[p] == slot, (slot, p, owner[p])
    for page, o in enumerate(owner):
        if o >= 0:
            assert page in rows[o], (page, o)


def test_init_all_free():
    pm = mk()
    st = pm.init()
    assert int(pm.free_pages(st)) == pm.n_pages
    assert int(pm.used_pages(st)) == 0
    assert float(pm.occupancy(st)) == 0.0
    assert not bool(jnp.any(st.active))


def test_admit_reserves_ceil_div_pages():
    pm = mk(page_size=8)
    st = pm.init()
    for plen, want in [(1, 1), (8, 1), (9, 2), (16, 2), (17, 3)]:
        st2, ok = pm.admit(st, 0, plen)
        assert bool(ok)
        assert int(pm.used_pages(st2)) == want
        assert int(st2.lengths[0]) == 0 and bool(st2.active[0])
        owners_consistent(pm, st2)


def test_admit_rollback_when_pool_too_small():
    pm = mk(n_pages=2, page_size=8, pages_per_slot=4)
    st = pm.init()
    st, ok = pm.admit(st, 0, 17)          # needs 3 pages, pool has 2
    assert not bool(ok)
    # full rollback: nothing allocated, slot not activated
    assert int(pm.used_pages(st)) == 0
    assert not bool(st.active[0])


def test_free_slot_returns_pages():
    pm = mk()
    st = pm.init()
    st, ok = pm.admit(st, 1, 20)
    assert bool(ok)
    used = int(pm.used_pages(st))
    assert used == 3
    st = pm.free_slot(st, 1)
    assert int(pm.used_pages(st)) == 0
    assert not bool(st.active[1])
    assert not bool(jnp.any(st.page_rows[1] >= 0))
    owners_consistent(pm, st)


def test_no_double_allocation_across_slots():
    pm = mk(n_pages=8, n_slots=4, page_size=8, pages_per_slot=2)
    st = pm.init()
    for slot in range(4):
        st, ok = pm.admit(st, slot, 16)   # 2 pages each -> exactly full
        assert bool(ok)
    owner = np.asarray(st.page_owner)
    assert (owner >= 0).all()             # pool exactly exhausted
    rows = np.asarray(st.page_rows)
    flat = rows[rows >= 0]
    assert len(set(flat.tolist())) == len(flat)   # all distinct pages
    owners_consistent(pm, st)


def test_ensure_append_capacity_rank_matching():
    """Three lanes hit a page boundary at once with only 2 free pages:
    exactly two rank-matched grants, the third lane is refused (not
    corrupted)."""
    pm = mk(n_pages=5, n_slots=3, page_size=4, pages_per_slot=4)
    st = pm.init()
    for slot in range(3):
        st, ok = pm.admit(st, slot, 4)    # 1 page each -> 2 pages free
        assert bool(ok)
    st = pm.advance(st, jnp.array([True, True, True]))  # len 1
    # jump to the boundary: next token needs a second page per lane
    st = st._replace(lengths=jnp.array([4, 4, 4], jnp.int32))
    want = jnp.array([True, True, True])
    st2, ok = pm.ensure_append_capacity(st, want)
    assert int(jnp.sum(ok)) == 2
    assert int(pm.free_pages(st2)) == 0
    owners_consistent(pm, st2)
    # the refused lane keeps its old single page, untouched
    refused = int(jnp.argmin(ok))
    assert int(jnp.sum(st2.page_rows[refused] >= 0)) == 1


def test_ensure_append_capacity_noop_mid_page():
    pm = mk(page_size=8)
    st = pm.init()
    st, _ = pm.admit(st, 0, 4)
    st = st._replace(lengths=jnp.array([2, 0, 0, 0], jnp.int32))
    before = int(pm.used_pages(st))
    st2, ok = pm.ensure_append_capacity(st, jnp.array([True, False, False,
                                                       False]))
    assert bool(ok[0])
    assert int(pm.used_pages(st2)) == before      # mid-page: nothing to do


def test_ensure_append_capacity_respects_max_context():
    pm = mk(n_pages=16, n_slots=2, page_size=4, pages_per_slot=2)  # max 8 tok
    st = pm.init()
    st, _ = pm.admit(st, 0, 4)
    st = st._replace(lengths=jnp.array([8, 0], jnp.int32))  # at the ceiling
    st2, ok = pm.ensure_append_capacity(st, jnp.array([True, False]))
    assert not bool(ok[0])                # cannot grow past pages_per_slot


def test_ops_jit_compatible():
    pm = mk()
    st = pm.init()

    @jax.jit
    def go(st):
        st, ok = pm.admit(st, 0, 12)
        st, ok2 = pm.ensure_append_capacity(
            st, jnp.array([True, False, False, False]))
        st = pm.advance(st, jnp.array([True, False, False, False]))
        return st, ok, ok2

    st, ok, ok2 = go(st)
    assert bool(ok) and bool(ok2[0])
    assert int(st.lengths[0]) == 1
    owners_consistent(pm, st)


def test_recycle_slot_reuses_pages():
    pm = mk(n_pages=4, n_slots=2, page_size=8, pages_per_slot=2)
    st = pm.init()
    st, ok = pm.admit(st, 0, 16)
    assert bool(ok) and int(pm.free_pages(st)) == 2
    st = pm.free_slot(st, 0)
    st, ok = pm.admit(st, 0, 16)          # recycled slot gets pages again
    assert bool(ok) and int(pm.free_pages(st)) == 2
    owners_consistent(pm, st)
