"""Property-based verification of the pipeline-balance core (§IV-B).

Fuzzed over random layer-time/memory vectors, stage counts, schedules and
virtual-chunk degrees: every partition helper must return a *structurally
valid* partition (sums to L, no empty stage), the balance degrees of Eq. 6
must stay in [0, 1], and the greedy §IV-B2 adjustment must never shed a
stage to empty.  Runs under real ``hypothesis`` when installed, else the
deterministic ``_hypothesis_compat`` shim.
"""
import itertools

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sampler
    from _hypothesis_compat import given, settings, st

from repro.core.pipeline_balance import (adjust_partition, balance_degrees,
                                         inflight_microbatches,
                                         memory_balanced_partition,
                                         stage_bounds,
                                         time_balanced_partition)

SCHEDULES = ("gpipe", "1f1b", "1f1b-interleaved")


def _check_partition(part, L, P):
    assert len(part) == P
    assert sum(part) == L
    assert min(part) >= 1
    # stage_bounds must tile [0, L) exactly
    bounds = stage_bounds(part)
    assert bounds[0][0] == 0 and bounds[-1][1] == L
    assert all(b0 < b1 for b0, b1 in bounds)
    assert all(bounds[i][1] == bounds[i + 1][0] for i in range(P - 1))


# ---------------------------------------------------------------------------
# partitions: sum to L, >= 1 layer per stage
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(min_value=0.0, max_value=10.0),
                min_size=1, max_size=24),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=40, deadline=None)
def test_time_balanced_partition_is_valid(times, P):
    P = min(P, len(times))
    part = time_balanced_partition(times, P)
    _check_partition(part, len(times), P)


@given(st.lists(st.floats(min_value=0.0, max_value=1e9),
                min_size=1, max_size=24),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=16),
       st.sampled_from(SCHEDULES),
       st.sampled_from([1, 2, 4]))
@settings(max_examples=40, deadline=None)
def test_memory_balanced_partition_is_valid(mems, P, n_micro, schedule, vpp):
    P = min(P, len(mems))
    part = memory_balanced_partition(mems, P, n_micro, schedule, vpp)
    _check_partition(part, len(mems), P)


@given(st.lists(st.floats(min_value=0.01, max_value=5.0),
                min_size=2, max_size=8),
       st.integers(min_value=2, max_value=3))
@settings(max_examples=15, deadline=None)
def test_time_balanced_partition_is_optimal(times, P):
    """The O(P·L²) DP must actually minimize the max stage load — checked
    against brute-force enumeration of all contiguous cut placements."""
    L = len(times)
    P = min(P, L)
    part = time_balanced_partition(times, P)
    pref = np.concatenate([[0.0], np.cumsum(times)])

    def max_load(cuts):
        edges = [0, *cuts, L]
        return max(pref[b] - pref[a] for a, b in zip(edges, edges[1:]))

    best = min(max_load(c) for c in itertools.combinations(range(1, L), P - 1))
    got = max_load(list(np.cumsum(part))[:-1])
    assert got <= best + 1e-9


# ---------------------------------------------------------------------------
# balance degrees (Eq. 6) in [0, 1]
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(min_value=0.0, max_value=1e6),
                min_size=1, max_size=16),
       st.lists(st.floats(min_value=0.0, max_value=1e12),
                min_size=1, max_size=16))
@settings(max_examples=40, deadline=None)
def test_balance_degrees_in_unit_interval(times, mems):
    a_t, a_m = balance_degrees(times, mems)
    assert 0.0 <= a_t <= 1.0
    assert 0.0 <= a_m <= 1.0
    # max/sum >= 1/n  =>  alpha <= 1 - 1/n
    assert a_t <= 1.0 - 1.0 / len(times) + 1e-12
    assert a_m <= 1.0 - 1.0 / len(mems) + 1e-12


def test_balance_degrees_extremes():
    # perfectly balanced 4 stages: alpha = 1 - 1/4
    assert balance_degrees([1, 1, 1, 1], [2, 2, 2, 2]) == (0.75, 0.75)
    # one stage carries everything: alpha = 0
    a_t, a_m = balance_degrees([5, 0, 0], [7, 0, 0])
    assert a_t == 0.0 and a_m == 0.0


# ---------------------------------------------------------------------------
# greedy adjustment (§IV-B2) never empties a stage
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(min_value=0.0, max_value=10.0),
                min_size=2, max_size=24),
       st.integers(min_value=2, max_value=8),
       st.lists(st.floats(min_value=0.0, max_value=1.0),
                min_size=8, max_size=8))
@settings(max_examples=40, deadline=None)
def test_adjust_partition_never_empties_a_stage(times, P, noise):
    P = min(P, len(times))
    part = time_balanced_partition(times, P)
    stage_times = [(noise[i % len(noise)] + 0.1) * (1 + i) for i in range(P)]
    for cand in adjust_partition(part, stage_times):
        _check_partition(cand, len(times), P)
        # exactly one boundary layer moved to an adjacent stage
        delta = [a - b for a, b in zip(cand, part)]
        assert sum(delta) == 0 and sum(abs(d) for d in delta) == 2


def test_adjust_partition_single_layer_slowest_stage_yields_nothing():
    # the slowest stage has 1 layer -> nothing can be shed
    assert adjust_partition([1, 3], [10.0, 1.0]) == []


# ---------------------------------------------------------------------------
# in-flight micro-batch accounting
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=32),
       st.sampled_from(SCHEDULES),
       st.sampled_from([1, 2, 4]))
@settings(max_examples=40, deadline=None)
def test_inflight_microbatches_bounds(P, m, schedule, vpp):
    for i in range(P):
        infl = inflight_microbatches(i, P, m, schedule, vpp)
        assert 0.0 < infl <= m  # never more than every micro-batch in flight
    # 1F1B flush: shallower stages hold at least as much as deeper ones
    if schedule == "1f1b":
        vals = [inflight_microbatches(i, P, m, schedule, 1) for i in range(P)]
        assert all(a >= b for a, b in zip(vals, vals[1:]))
