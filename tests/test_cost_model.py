"""Cost estimator: paper Table I validation, Takeaway #3, overlap slowdown."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sampler
    from _hypothesis_compat import given, settings, st

from repro.core import CostModel, CostModelConfig, Strategy, paper_8gpu
from repro.core.layerspec import dense_layer, total_params
from repro.configs.paper_models import paper_model_specs

GB = 1024 ** 3

# paper Table I ground truth: (params, activation bytes / sample)
TABLE_I = {
    "bert-huge-32": (672e6, 3149.39),
    "bert-huge-48": (987e6, 4657.51),
    "bert-xhuge": (10.2e9, 24210.05),
    "vit-huge-32": (632e6, 646.5),
    "vit-huge-48": (947e6, 968.59),
    "vit-xhuge": (10.1e9, 5313.9),
    "t5-large-32": (502e6, 4119.66),
    "t5-large-48": (737e6, 6107.75),
    "t5-512/4-32": (502e6, 1777.06),
    "t5-512/4-48": (737e6, 2473.10),
    "swin-huge-32": (701e6, 726.59),
    "swin-huge-48": (1016e6, 1016.8),
    "gpt3-15b": (15.4e9, None),
    "gpt3-39b": (39.1e9, None),
    "gpt3-65b": (64.9e9, None),
}


@pytest.mark.parametrize("name,expected", list(TABLE_I.items()))
def test_param_counts_match_table1(name, expected):
    params, _ = expected
    got = total_params(paper_model_specs(name))
    assert abs(got - params) / params < 0.12, (name, got / 1e6)


@pytest.mark.parametrize("name", [k for k, v in TABLE_I.items() if v[1]])
def test_activation_sizes_order_of_table1(name):
    """Activations are profiled quantities in the paper; our analytic model
    with one global calibration constant should land within 2x for every
    model (it's the RELATIVE layer costs that drive the search)."""
    _, act_mb = TABLE_I[name]
    specs = paper_model_specs(name)
    got_mb = sum(s.bnd_bytes_per_sample + s.int_bytes_per_sample
                 for s in specs) / (1024 ** 2)
    assert 0.5 < got_mb / act_mb < 2.0, (name, got_mb, act_mb)


def _mk_layer():
    return dense_layer("l", 512, 1024, 16, 16, 4096, causal=False,
                       gated=False, store_attn_matrix=True)


def test_takeaway3_sdp_beats_dp_sdp_mix():
    """Pure SDP total COMMUNICATION VOLUME < any DP x SDP mixture
    (Takeaway #3: 3(N-1)/N < 2(N1-1)/N1 + 3(N2-1)/N2 for N1*N2=N).
    The paper's proof is about volume, so we isolate communication with a
    zero-FLOP layer (with compute, overlap can hide either side)."""
    import dataclasses
    cm = CostModel(paper_8gpu())
    spec = dataclasses.replace(_mk_layer(), flops_per_sample=0.0)
    pure = cm.layer_costs(spec, Strategy((("sdp", 8),)), 8.0)
    for (d, s) in [(2, 4), (4, 2)]:
        mixed = cm.layer_costs(
            spec, Strategy((("dp", d), ("sdp", s))), 8.0)
        assert pure.time <= mixed.time + 1e-12
        assert pure.mem_ms <= mixed.mem_ms + 1e-6


def test_ckpt_trades_memory_for_time():
    cm = CostModel(paper_8gpu())
    spec = _mk_layer()
    s = Strategy((("dp", 8),))
    base = cm.layer_costs(spec, s, 8.0)
    ck = cm.layer_costs(spec, s.with_ckpt(), 8.0)
    assert ck.mem_f < base.mem_f          # forward stash shrinks
    assert ck.time > base.time            # recompute costs time
    assert ck.mem_b > base.mem_b          # backward peak appears


def test_tp_shards_states_dp_replicates():
    cm = CostModel(paper_8gpu())
    spec = _mk_layer()
    dp = cm.layer_costs(spec, Strategy((("dp", 8),)), 8.0)
    tp = cm.layer_costs(spec, Strategy((("tp", 8),)), 8.0)
    sdp = cm.layer_costs(spec, Strategy((("sdp", 8),)), 8.0)
    assert dp.mem_ms > tp.mem_ms
    assert dp.mem_ms > sdp.mem_ms
    # DP has no fwd comm; TP does
    assert dp.time_fwd < tp.time_fwd


def test_overlap_slowdown_increases_cost():
    cluster = paper_8gpu()
    import dataclasses
    no_slow = dataclasses.replace(
        cluster, device=dataclasses.replace(cluster.device,
                                            overlap_slowdown=1.0))
    spec = _mk_layer()
    s = Strategy((("dp", 8),))
    t_slow = CostModel(cluster).layer_costs(spec, s, 64.0).time
    t_fast = CostModel(no_slow).layer_costs(spec, s, 64.0).time
    assert t_slow > t_fast


@given(st.integers(min_value=1, max_value=6),
       st.floats(min_value=1.0, max_value=64.0))
@settings(max_examples=20, deadline=None)
def test_memory_positive_and_monotone_in_batch(k, b):
    cm = CostModel(paper_8gpu())
    spec = _mk_layer()
    s = Strategy((("dp", 2 ** min(k, 3)),))
    c1 = cm.layer_costs(spec, s, b)
    c2 = cm.layer_costs(spec, s, 2 * b)
    assert c1.mem_f > 0 and c1.mem_ms > 0
    assert c2.mem_f > c1.mem_f
    assert c2.time >= c1.time
