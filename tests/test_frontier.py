"""Budget-sweep frontier engine invariants (DESIGN.md §6).

The contract under test: ``sweep_budgets`` is a pure restructuring of N
serial searches — byte-identical plans at every budget on a shared
quantization grid, whether the sweep runs serially or fans (B, P)
candidates across the thread pool; the frontier is monotone, feasible at
its own budgets, and JSON round-trips; and ``clear_cache()`` returns the
optimizer to a bit-exact cold start.
"""
import json

import pytest

from repro.core import (GalvatronOptimizer, PlanFrontier, ParallelPlan,
                        Strategy, galvatron_variant, paper_8gpu)
from repro.core.frontier import FrontierPoint
from repro.core.layerspec import dense_layer

GB = 1024 ** 3
BUDGETS = [4 * GB, 6 * GB, 8 * GB, 12 * GB]


def _specs(n=8, seq=512, d=1024):
    return [dense_layer(f"l{i}", seq, d, 16, 16, 4 * d,
                        store_attn_matrix=True) for i in range(n)]


def _mkopt(specs, cluster=None, *, budget=None, quant=None, variant="bmw",
           **kw):
    cfg = galvatron_variant(variant)
    cfg.batch_grid = [8, 16]
    cfg.n_bins = 128
    cfg.micro_candidates = 2
    cfg.budget_bytes = budget
    cfg.quant_bytes = quant
    for k, v in kw.items():
        setattr(cfg, k, v)
    return GalvatronOptimizer(specs, cluster or paper_8gpu(), cfg)


def _canon(plan):
    return plan.canonical_dumps() if plan is not None else None


# ---------------------------------------------------------------------------
# differential: sweep == serial optimize, serially and in parallel
# ---------------------------------------------------------------------------

def test_single_point_sweep_matches_plain_optimize():
    """sweep_budgets([b]) degenerates to optimize() at budget b — same
    quantization grid, byte-identical plan JSON."""
    specs = _specs(8)
    for b in (5 * GB, 8 * GB, 12 * GB):
        serial = _mkopt(specs, paper_8gpu().with_budget(b)).optimize()
        frontier = _mkopt(specs, paper_8gpu().with_budget(b)).sweep_budgets([b])
        assert frontier.quant_bytes == b
        assert _canon(frontier.points[0].plan) == _canon(serial)


@pytest.mark.parametrize("variant", ["bmw", "base"])
def test_sweep_matches_serial_grid(variant):
    """Every frontier point is byte-identical to an independent serial
    optimize() at that budget pinned to the sweep's quantization grid."""
    specs = _specs(8)
    frontier = _mkopt(specs, variant=variant).sweep_budgets(BUDGETS)
    for p in frontier.points:
        serial = _mkopt(specs, budget=p.budget_bytes,
                        quant=max(BUDGETS), variant=variant).optimize()
        assert _canon(p.plan) == _canon(serial), p.budget_bytes / GB


def test_sweep_pinned_to_min_budget_matches_dedicated_searches():
    """Anchoring the grid at min(budgets) gives every point the resolution
    a dedicated optimize() at that budget would use — including budgets
    *above* the anchor, whose bin caps exceed n_bins."""
    specs = _specs(8)
    frontier = _mkopt(specs, quant=min(BUDGETS)).sweep_budgets(BUDGETS)
    assert frontier.quant_bytes == min(BUDGETS)
    for p in frontier.points:
        dedicated = _mkopt(specs, budget=p.budget_bytes,
                           quant=min(BUDGETS)).optimize()
        assert _canon(p.plan) == _canon(dedicated), p.budget_bytes / GB
    # the smallest point IS the plain single-budget search (quant == budget)
    plain = _mkopt(specs, paper_8gpu().with_budget(min(BUDGETS))).optimize()
    assert _canon(frontier.points[0].plan) == _canon(plain)


def test_parallel_sweep_identical_and_stats_consistent():
    specs = _specs(8)
    serial_opt = _mkopt(specs)
    parallel_opt = _mkopt(specs)
    fr_serial = serial_opt.sweep_budgets(BUDGETS)
    fr_parallel = parallel_opt.sweep_budgets(BUDGETS, parallel=True,
                                             max_workers=3)
    # plans byte-identical in any worker interleaving
    for p, q in zip(fr_parallel.points, fr_serial.points):
        assert _canon(p.plan) == _canon(q.plan)
    assert fr_parallel == fr_serial      # search_stats excluded from eq
    # aggregated cache counters stay consistent across the shard merges
    for stats in (serial_opt.stats, parallel_opt.stats,
                  fr_parallel.search_stats):
        assert (stats["stage_cache_hits"] + stats["stage_cache_misses"]
                == stats["stage_searches"])
        assert stats["stage_cache_misses"] > 0


# ---------------------------------------------------------------------------
# frontier invariants
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def frontier_and_opt():
    specs = _specs(8)
    opt = _mkopt(specs)
    return opt.sweep_budgets(BUDGETS), opt, specs


def test_throughput_nondecreasing_in_budget(frontier_and_opt):
    frontier, _, _ = frontier_and_opt
    tpts = frontier.throughputs()
    assert all(b >= a - 1e-12 for a, b in zip(tpts, tpts[1:]))


def test_every_plan_feasible_at_its_own_budget(frontier_and_opt):
    """Peak stage memory (Eq. 2, recomputed through the scalar cost-model
    path, independent of the DP) fits under each point's budget."""
    frontier, opt, specs = frontier_and_opt
    assert frontier.feasible_points(), "test setup: all budgets OOMed"
    for p in frontier.feasible_points():
        mems = opt.cost.plan_peak_stage_mem(specs, p.plan)
        assert max(mems) <= p.budget_bytes * (1 + 1e-9)
        # and the search's own estimate agrees with the recompute
        assert max(p.plan.est_stage_mem) <= p.budget_bytes
        assert mems == pytest.approx(p.plan.est_stage_mem, rel=1e-9)


def test_frontier_json_roundtrip(frontier_and_opt):
    frontier, _, _ = frontier_and_opt
    again = PlanFrontier.loads(frontier.dumps())
    assert again == frontier
    assert again.budgets() == frontier.budgets()
    assert [_canon(p.plan) for p in again.points] \
        == [_canon(p.plan) for p in frontier.points]


def test_frontier_roundtrip_preserves_schedule_and_vpp():
    """PR-2 plan fields (schedule, vpp_degree) survive the frontier JSON."""
    plan = ParallelPlan(
        n_devices=8, pp_degree=4, partition=[2, 2, 2, 2],
        strategies=[Strategy((("dp", 2),))] * 8, global_batch=16, n_micro=8,
        schedule="1f1b-interleaved", vpp_degree=2,
        est_iter_time=0.5, est_throughput=32.0,
        est_stage_mem=[1.0 * GB] * 4)
    fr = PlanFrontier(points=[
        FrontierPoint(2 * GB, None, 0.0),
        FrontierPoint(4 * GB, plan, plan.est_throughput),
    ], quant_bytes=4 * GB)
    again = PlanFrontier.loads(fr.dumps())
    assert again == fr
    got = again.points[1].plan
    assert got.schedule == "1f1b-interleaved" and got.vpp_degree == 2
    assert not again.points[0].feasible


def test_plan_at_and_knee_points(frontier_and_opt):
    frontier, _, _ = frontier_and_opt
    # query between swept points: best feasible plan at or below the query
    mid = (BUDGETS[1] + BUDGETS[2]) / 2
    got = frontier.plan_at(mid)
    best_below = max(
        (p for p in frontier.feasible_points() if p.budget_bytes <= mid),
        key=lambda p: p.predicted_throughput)
    assert got == best_below.plan
    assert frontier.plan_at(0.0) is None
    knees = frontier.knee_points()
    tpts = [p.predicted_throughput for p in knees]
    assert tpts == sorted(set(tpts))     # strictly increasing
    # knee flags land in the JSON
    d = frontier.to_json()
    assert sum(1 for p in d["points"] if p["knee"]) == len(knees)


# ---------------------------------------------------------------------------
# cache lifecycle
# ---------------------------------------------------------------------------

def test_clear_cache_reproduces_cold_start():
    """Audit: clear_cache() drops all four memo dicts and zeroes stats —
    the instance then replays a bit-exact cold-start search."""
    specs = _specs(8)
    opt = _mkopt(specs)
    p1 = opt.optimize()
    cold = {k: v for k, v in opt.stats.items() if k != "search_seconds"}
    assert any(cold.values())
    opt.optimize()                       # warm the caches further
    opt.clear_cache()
    for cache in (opt._stage_cache, opt._table_cache, opt._ref_cache,
                  opt._part_cache):
        assert len(cache) == 0
    assert all(v == 0 for v in opt.stats.values())
    p2 = opt.optimize()
    assert _canon(p2) == _canon(p1)
    assert {k: v for k, v in opt.stats.items()
            if k != "search_seconds"} == cold


def test_budget_axis_switch_keeps_budget_independent_caches():
    """Re-searching with a different budget axis drops only the stage
    cache; cost tables / reference costs / seed partitions are reused —
    the incremental-re-search path when only the budget changes."""
    specs = _specs(8)
    opt = _mkopt(specs)
    fr1 = opt.sweep_budgets(BUDGETS)
    builds = opt.stats["table_builds"]
    assert builds > 0 and len(opt._table_cache) > 0
    fr2 = opt.sweep_budgets([5 * GB, 9 * GB])
    # no new table builds: the (strategy-set, B_m, inflight) keys are
    # budget-independent, so the second sweep runs entirely off the memo
    assert opt.stats["table_builds"] == builds
    assert opt.stats["table_hits"] > 0
    # and the incremental answer matches a cold sweep
    fresh = _mkopt(specs).sweep_budgets([5 * GB, 9 * GB])
    assert [_canon(p.plan) for p in fr2.points] \
        == [_canon(p.plan) for p in fresh.points]
    assert fr1.budgets() == sorted(BUDGETS)


def test_sweep_budgets_validates_input():
    opt = _mkopt(_specs(4))
    with pytest.raises(ValueError):
        opt.sweep_budgets([])


def test_canonical_dumps_drops_only_stats():
    specs = _specs(6)
    plan = _mkopt(specs).optimize()
    assert plan.search_stats is not None
    d = json.loads(plan.canonical_dumps())
    assert "search_stats" not in d
    assert ParallelPlan.from_json(d) == plan
