"""Differential coverage for the decode-time attention paths.

One harness, three cache layouts, one oracle: ``sdpa_ref`` over the full
token history.  ``attention_decode`` (linear cache, ring cache) and
``attention_decode_paged`` / ``attention_prefill_paged`` must reproduce the
oracle's output token-for-token — the serving engine's paged/dense
differential guarantee (tests/test_serving.py) bottoms out in these
per-layer identities.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (attention_decode, attention_decode_paged,
                                    attention_prefill_paged, init_attention,
                                    init_kv_cache, init_page_pool,
                                    _project_qkv, sdpa_ref)
from repro.models.common import ModelConfig

CFG = ModelConfig(name="t", arch_type="dense", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64)


def _setup(B, T, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    p = init_attention(ks[0], CFG)
    xs = jax.random.normal(ks[1], (B, T, CFG.d_model), jnp.float32)
    return p, xs


def _oracle(p, xs, t, *, window=None):
    """Full-history reference output for step t: attend from token t over
    tokens [0, t]."""
    B = xs.shape[0]
    pos = jnp.broadcast_to(jnp.arange(t + 1), (B, t + 1))
    q, k, v = _project_qkv(p, xs[:, :t + 1], CFG, pos)
    out = sdpa_ref(q[:, t:t + 1], k, v, causal=True, window=window,
                   q_offset=t)
    return out.reshape(B, 1, CFG.q_dim) @ p["wo"]


@pytest.mark.parametrize("B,T", [(1, 8), (3, 8)])
def test_linear_cache_decode_matches_full_attention(B, T):
    """attention_decode with a linear cache (C >= T, no wraparound) must
    equal full-context reference attention at every step."""
    p, xs = _setup(B, T)
    cache = init_kv_cache(CFG, B, context=T, dtype=jnp.float32)
    for t in range(T):
        out, cache = attention_decode(p, xs[:, t:t + 1], cache,
                                      jnp.int32(t), CFG)
        ref = _oracle(p, xs, t)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


def test_ring_cache_decode_matches_windowed_attention():
    """With a ring cache of span W and window=W the decode output must
    equal windowed reference attention even after wraparound."""
    B, T, W = 2, 14, 8
    p, xs = _setup(B, T, seed=1)
    cache = init_kv_cache(CFG, B, context=W, dtype=jnp.float32)
    for t in range(T):
        out, cache = attention_decode(p, xs[:, t:t + 1], cache,
                                      jnp.int32(t), CFG, window=W)
        ref = _oracle(p, xs, t, window=W)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


def test_per_lane_cache_index_recycled_slot():
    """Per-lane cache_index: lane 0 restarts a fresh request at position 0
    while lane 1 continues — the recycled lane must see *only* its new
    tokens (no KV leakage from the stale ring content)."""
    B, T = 2, 6
    p, xs = _setup(B, T, seed=2)
    C = 8
    cache = init_kv_cache(CFG, B, context=C, dtype=jnp.float32)
    # warm both lanes with T tokens
    for t in range(T):
        _, cache = attention_decode(p, xs[:, t:t + 1], cache,
                                    jnp.int32(t), CFG)
    # lane 0 recycles: fresh stream ys at positions 0..; lane 1 continues
    ys = jax.random.normal(jax.random.PRNGKey(9), (1, 4, CFG.d_model))
    idx = jnp.array([0, T], jnp.int32)
    for t in range(4):
        x_t = jnp.concatenate([ys[:, t:t + 1], xs[1:2, T % T:T % T + 1]], 0)
        out, cache = attention_decode(p, x_t, cache, idx, CFG)
        # oracle for the recycled lane: attention over ys[:, :t+1] only
        ref0 = _oracle(p, ys, t)
        np.testing.assert_allclose(np.asarray(out[0:1]), np.asarray(ref0),
                                   atol=1e-5, rtol=1e-5)
        idx = idx + 1


@pytest.mark.parametrize("psz", [2, 4])
def test_paged_decode_matches_linear_decode(psz):
    """Same harness, paged layout: attention_decode_paged over scattered
    pool pages must match attention_decode on a linear cache bit-for-bit
    (identical fp32 einsum/softmax over an identical gathered view)."""
    B, T = 2, 8
    P = -(-T // psz)
    p, xs = _setup(B, T, seed=3)
    cache = init_kv_cache(CFG, B, context=P * psz, dtype=jnp.float32)
    pool = init_page_pool(CFG, n_pages=B * P + 3, page_size=psz,
                          dtype=jnp.float32)
    # deliberately non-contiguous, interleaved page assignment
    rows = np.full((B, P), -1, np.int32)
    perm = np.random.default_rng(0).permutation(B * P)
    for i, r in enumerate(perm):
        rows[i % B, i // B] = int(r)
    rows_j = jnp.asarray(rows)
    lengths = jnp.zeros((B,), jnp.int32)
    for t in range(T):
        dense_out, cache = attention_decode(p, xs[:, t:t + 1], cache,
                                            jnp.int32(t), CFG)
        paged_out, pool = attention_decode_paged(p, xs[:, t:t + 1], pool,
                                                 rows_j, lengths, CFG)
        np.testing.assert_allclose(np.asarray(paged_out),
                                   np.asarray(dense_out),
                                   atol=1e-6, rtol=1e-6)
        lengths = lengths + 1


def test_paged_decode_inactive_lane_write_dropped():
    """lengths = -1 marks an inactive lane: its write must be dropped (the
    pool unchanged) and active lanes unaffected."""
    B, psz, P = 2, 4, 2
    p, xs = _setup(B, 4, seed=4)
    pool = init_page_pool(CFG, n_pages=B * P, page_size=psz,
                          dtype=jnp.float32)
    rows = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    lengths = jnp.array([0, -1], jnp.int32)
    _, pool2 = attention_decode_paged(p, xs[:, 0:1], pool, rows, lengths, CFG)
    # lane 1's pages (rows 2, 3) untouched
    np.testing.assert_array_equal(np.asarray(pool2["k"][2:]),
                                  np.asarray(pool["k"][2:]))
    # lane 0's first page slot 0 written
    assert not np.allclose(np.asarray(pool2["k"][0, 0]), 0.0)


def test_paged_prefill_then_decode_matches_dense():
    """Chunked paged prefill (write-then-attend) + paged decode must
    reproduce the dense one-token-at-a-time decode trajectory, including
    ragged prompt lengths and a traced chunk base."""
    B, psz, P, S = 2, 4, 4, 4           # context 16, chunk 4
    T_prompt = jnp.array([6, 3], jnp.int32)          # ragged prompts
    p, xs = _setup(B, 10, seed=5)
    pool = init_page_pool(CFG, n_pages=B * P, page_size=psz,
                          dtype=jnp.float32)
    rows = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)

    prefill = jax.jit(lambda pool, x, base: attention_prefill_paged(
        p, x, pool, rows, base, T_prompt, CFG))
    outs = []
    for base in range(0, 8, S):                      # 2 chunks, one compile
        o, pool = prefill(pool, xs[:, base:base + S], jnp.int32(base))
        outs.append(o)
    # after prefill, decode one more token per lane at its own length
    lengths = T_prompt
    nxt = jax.random.normal(jax.random.PRNGKey(11), (B, 1, CFG.d_model))
    paged_out, pool = attention_decode_paged(p, nxt, pool, rows, lengths, CFG)

    # dense oracle, per lane: feed its prompt then the same next token
    for lane in range(B):
        L = int(T_prompt[lane])
        seq = jnp.concatenate([xs[lane:lane + 1, :L], nxt[lane:lane + 1]], 1)
        ref = _oracle(p, seq, L)
        np.testing.assert_allclose(np.asarray(paged_out[lane:lane + 1]),
                                   np.asarray(ref), atol=1e-5, rtol=1e-5)
        # the prefill chunk outputs match the oracle at prompt positions
        chunk = jnp.concatenate(outs, 1)             # (B, 8, d)
        for t in range(L):
            ref_t = _oracle(p, xs[lane:lane + 1], t)
            np.testing.assert_allclose(
                np.asarray(chunk[lane:lane + 1, t:t + 1]),
                np.asarray(ref_t), atol=1e-5, rtol=1e-5)
