"""ParallelPlan serialization: vpp_degree/schedule round-trip, loading of
PR-1-era plan JSON (no vpp_degree key), search_stats exclusion from
equality, and the micro-batch divisibility validation."""
import json

import pytest

from repro.core import ParallelPlan, Strategy


def _plan(**kw):
    base = dict(n_devices=8, pp_degree=2, partition=[4, 4],
                strategies=[Strategy((("dp", 2), ("tp", 2)), ckpt=True)] * 8,
                global_batch=64, n_micro=8)
    base.update(kw)
    return ParallelPlan(**base)


def test_roundtrip_with_schedule_and_vpp():
    plan = _plan(schedule="1f1b-interleaved", vpp_degree=2,
                 est_iter_time=0.5, est_throughput=128.0,
                 search_stats={"stage_searches": 3.0})
    plan2 = ParallelPlan.loads(plan.dumps())
    assert plan2 == plan
    assert plan2.schedule == "1f1b-interleaved"
    assert plan2.vpp_degree == 2
    assert plan2.search_stats == {"stage_searches": 3.0}
    assert "1f1b-interleaved(V=2)" in plan2.summary()


def test_backward_compat_pr1_json_defaults_vpp_to_1():
    d = _plan().to_json()
    del d["vpp_degree"]               # PR-1-era plan JSON
    del d["search_stats"]
    plan = ParallelPlan.from_json(d)
    assert plan.vpp_degree == 1
    assert plan.schedule == "1f1b"
    # and an old-style dict that never heard of schedule either
    d.pop("schedule")
    assert ParallelPlan.from_json(json.loads(json.dumps(d))).schedule == "1f1b"


def test_format_version_stamp_and_zb_h1_roundtrip():
    from repro.core import PLAN_FORMAT_VERSION

    plan = _plan(schedule="zb-h1")
    d = plan.to_json()
    assert d["format_version"] == PLAN_FORMAT_VERSION == 5
    plan2 = ParallelPlan.loads(plan.dumps())
    assert plan2 == plan and plan2.schedule == "zb-h1"
    # v0/v1 readers' keys are all still present (additive evolution only)
    for key in ("n_devices", "pp_degree", "partition", "strategies",
                "global_batch", "n_micro", "schedule", "vpp_degree"):
        assert key in d, key
    # the canonical byte-oracle includes the stamp on both sides
    assert json.loads(plan.canonical_dumps())["format_version"] == 5


def test_v3_json_without_sp_degree_still_loads():
    d = _plan().to_json()
    del d["sp_degree"]                # v3-era plan JSON has no sp keys
    del d["seq_len"]
    d["format_version"] = 3
    plan = ParallelPlan.from_json(d)
    assert plan.sp_degree == 1
    assert plan.seq_len == 0


def test_sp_degree_roundtrips_and_validates():
    plan = _plan(sp_degree=4, seq_len=65536)
    plan2 = ParallelPlan.loads(plan.dumps())
    assert plan2 == plan
    assert plan2.sp_degree == 4 and plan2.seq_len == 65536
    with pytest.raises(ValueError, match="sp_degree"):
        _plan(sp_degree=0)


def test_v2_json_without_serving_still_loads():
    d = _plan().to_json()
    del d["serving"]                  # v2-era plan JSON has no serving key
    d["format_version"] = 2
    plan = ParallelPlan.from_json(d)
    assert plan.serving is None


def test_search_stats_excluded_from_equality():
    a = _plan()
    b = _plan()
    a.search_stats = {"stage_cache_hits": 10.0}
    b.search_stats = {"stage_cache_hits": 99.0}
    assert a == b
    b.vpp_degree = 2
    assert a != b


def test_micro_batch_divisibility_validated():
    with pytest.raises(ValueError, match="not divisible"):
        _plan(global_batch=10, n_micro=4)
    with pytest.raises(ValueError, match="n_micro"):
        _plan(n_micro=0)
    with pytest.raises(ValueError, match="vpp_degree"):
        _plan(vpp_degree=0)
    # the same validation fires on deserialization
    d = _plan().to_json()
    d["n_micro"] = 3
    with pytest.raises(ValueError, match="not divisible"):
        ParallelPlan.from_json(d)
    assert _plan(global_batch=64, n_micro=8).micro_batch_size == 8
