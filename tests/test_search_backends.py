"""Cluster-scale engine invariants: every execution backend (threads /
processes / vectorized) and the frontier-guided batch-axis pruner must be a
pure speedup — plans byte-identical to the serial oracle, telemetry
consistent, caches auditable."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sampler
    from _hypothesis_compat import given, settings, st

from repro.core import (GalvatronOptimizer, OptimizerConfig, SEARCH_BACKENDS,
                        galvatron_variant, normalize_batch_grid, paper_8gpu)
from repro.core.layerspec import dense_layer

GB = 1024 ** 3


def _specs(n=8, seq=512, d=1024):
    return [dense_layer(f"l{i}", seq, d, 16, 16, 4 * d,
                        store_attn_matrix=True) for i in range(n)]


def _cfg(**kw):
    cfg = galvatron_variant("bmw")
    cfg.batch_grid = [8, 16, 24, 32]
    cfg.n_bins = 128
    cfg.micro_candidates = 2
    cfg.schedules = ("1f1b", "zb-h1")
    for k, v in kw.items():
        setattr(cfg, k, v)
    return cfg


def _sweep(budgets, **kw):
    opt = GalvatronOptimizer(_specs(), paper_8gpu(), _cfg(**kw))
    frontier = opt.sweep_budgets(budgets)
    dumps = [p.plan.canonical_dumps() if p.plan is not None else None
             for p in frontier.points]
    return dumps, dict(opt.stats), opt


BUDGETS = [2.0 * GB, 4.0 * GB, 8.0 * GB]


# ---------------------------------------------------------------------------
# differential: every backend x pruning == serial oracle, byte for byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["threads", "processes", "vectorized"])
@pytest.mark.parametrize("prune", [False, True])
def test_backend_byte_identical_to_serial(backend, prune):
    base, _, _ = _sweep(BUDGETS)
    dumps, stats, _ = _sweep(BUDGETS, search_backend=backend,
                             prune_batch_axis=prune, jobs=2)
    assert dumps == base
    assert any(d is not None for d in base)     # sweep is non-degenerate
    assert stats["stage_cache_hits"] + stats["stage_cache_misses"] \
        == stats["stage_searches"]


def test_serial_pruned_identical_with_skips():
    """Pruning alone (no pool): identical frontier, nonzero skip counts on a
    sweep whose low budget is infeasible for the large batch sizes."""
    budgets = [1.2 * GB, 2.0 * GB, 4.0 * GB]
    base, base_stats, _ = _sweep(budgets, allow_ckpt=False)
    dumps, stats, _ = _sweep(budgets, allow_ckpt=False, prune_batch_axis=True)
    assert dumps == base
    pruned = (stats["bp_pruned_infeasible"] + stats["bp_pruned_dominated"]
              - stats["bp_forced"])
    assert pruned > 0
    # skipping must actually save inner DP work vs the unpruned serial run
    assert stats["stage_searches"] < base_stats["stage_searches"]
    assert stats["bound_evals"] > 0
    assert stats["bp_candidates"] == base_stats["bp_candidates"]


def test_two_oom_stop_trajectory_preserved():
    """Tight budgets where the batch axis hits the two-consecutive-OOM stop:
    the pruner must reproduce the serial stopping point exactly (forced runs
    exist for precisely this bookkeeping)."""
    budgets = [1.0 * GB, 1.6 * GB]
    base, _, _ = _sweep(budgets, allow_ckpt=False,
                        batch_grid=[8, 16, 32, 64, 128, 256])
    for backend in ("serial", "vectorized"):
        dumps, stats, _ = _sweep(budgets, allow_ckpt=False,
                                 batch_grid=[8, 16, 32, 64, 128, 256],
                                 search_backend=backend,
                                 prune_batch_axis=True)
        assert dumps == base
        assert stats["bp_forced"] >= 0


# ---------------------------------------------------------------------------
# property: pruning never drops the argmax-throughput batch size
# ---------------------------------------------------------------------------

@given(st.sampled_from([(8, 16), (8, 16, 24), (8, 16, 32, 48),
                        (8, 24, 40, 56, 72)]),
       st.sampled_from([(1.5, 3.0), (2.0, 4.0, 8.0), (1.2, 1.8, 2.6)]),
       st.booleans())
@settings(max_examples=8, deadline=None)
def test_pruning_keeps_argmax_batch(grid, budgets_gb, allow_ckpt):
    budgets = [b * GB for b in budgets_gb]
    base, _, _ = _sweep(budgets, batch_grid=list(grid),
                        allow_ckpt=allow_ckpt)
    dumps, _, opt = _sweep(budgets, batch_grid=list(grid),
                           allow_ckpt=allow_ckpt,
                           search_backend="vectorized",
                           prune_batch_axis=True)
    # byte-identity subsumes it, but assert the paper-level property
    # directly: per budget, the winning global batch size survives pruning
    frontier = opt.sweep_budgets(budgets)
    for d, p in zip(base, frontier.points):
        if d is None:
            assert p.plan is None
        else:
            assert p.plan is not None
            assert f'"global_batch": {p.plan.global_batch}' in d
    assert dumps == base


# ---------------------------------------------------------------------------
# batch_grid / config validation
# ---------------------------------------------------------------------------

def test_normalize_batch_grid_dedupes_and_sorts():
    assert normalize_batch_grid([32, 8, 16, 8]) == [8, 16, 32]
    assert normalize_batch_grid(None) is None


@pytest.mark.parametrize("bad", [[], [0], [-8], [8.5], [True], ["8"]])
def test_normalize_batch_grid_rejects(bad):
    with pytest.raises(ValueError):
        normalize_batch_grid(bad)


def test_config_normalizes_unsorted_grid():
    cfg = OptimizerConfig(batch_grid=[64, 8, 8, 16])
    assert cfg.batch_grid == [8, 16, 64]


def test_config_rejects_bad_backend():
    with pytest.raises(ValueError, match="search_backend"):
        OptimizerConfig(search_backend="gpu")
    assert "serial" in SEARCH_BACKENDS


def test_config_rejects_vectorized_without_vectorized_cost():
    with pytest.raises(ValueError, match="vectorized"):
        OptimizerConfig(search_backend="vectorized", vectorized_cost=False)


def test_config_rejects_nonpositive_jobs():
    with pytest.raises(ValueError, match="jobs"):
        OptimizerConfig(jobs=0)


# ---------------------------------------------------------------------------
# cache audit: the new caches are registered with clear_cache()
# ---------------------------------------------------------------------------

def test_clear_cache_covers_bound_and_coeff_caches():
    _, _, opt = _sweep([1.5 * GB, 3.0 * GB], prune_batch_axis=True)
    assert opt._bound_cache                     # pruning populated bounds
    opt.cost._group_coeffs("all_reduce", 4)
    assert opt.cost._coeff_cache                # coeff lookups memoized
    opt.clear_cache()
    assert not opt._bound_cache
    assert not opt.cost._coeff_cache
    assert not opt._stage_cache
    assert all(v == 0 for v in opt.stats.values())
    # the instance still searches correctly after the wipe
    base, _, _ = _sweep([1.5 * GB, 3.0 * GB])
    frontier = opt.sweep_budgets([1.5 * GB, 3.0 * GB])
    assert [p.plan.canonical_dumps() if p.plan is not None else None
            for p in frontier.points] == base
