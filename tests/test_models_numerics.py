"""Numerical invariants across the model zoo: SSD chunked==sequential,
MoE dispatch equivalence, decode==prefill consistency, attention paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sampler
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models.attention import sdpa_chunked, sdpa_ref
from repro.models.common import ModelConfig
from repro.models.moe import init_moe, moe_ffn
from repro.models.ssm import ssd_chunked, ssd_step


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=3),
       st.sampled_from([8, 16, 32]),
       st.integers(min_value=1, max_value=3))
@settings(max_examples=10, deadline=None)
def test_ssd_chunked_equals_sequential(B, chunk, H):
    S, P, N = 64, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(B * 100 + chunk + H), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    Cm = jax.random.normal(ks[4], (B, S, H, N))
    y = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    s = jnp.zeros((B, H, P, N))
    outs = []
    for t in range(S):
        s, yt = ssd_step(s, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        outs.append(yt)
    y_seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-4)


def test_ssd_chunk_size_invariance():
    B, S, H, P, N = 1, 64, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    Cm = jax.random.normal(ks[4], (B, S, H, N))
    y16 = ssd_chunked(x, dt, A, Bm, Cm, 16)
    y64 = ssd_chunked(x, dt, A, Bm, Cm, 64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(E=4, k=2, cf=8.0):
    return ModelConfig(name="t", arch_type="moe", n_layers=1, d_model=16,
                       n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                       n_experts=E, top_k=k, capacity_factor=cf,
                       dtype=jnp.float32)


def test_moe_sort_equals_einsum_dispatch():
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 16), jnp.float32)
    o1, a1 = moe_ffn(p, x, cfg, dispatch="sort")
    o2, a2 = moe_ffn(p, x, cfg, dispatch="einsum")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4,
                               rtol=1e-4)
    assert abs(float(a1) - float(a2)) < 1e-5


def test_moe_matches_dense_oracle_when_no_drops():
    """With capacity >= all tokens, routed MoE equals the dense weighted
    combination of expert outputs."""
    cfg = _moe_cfg(cf=100.0)
    p = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 6, 16), jnp.float32)
    out, _ = moe_ffn(p, x, cfg, dispatch="sort")

    # dense oracle: every expert on every token, weighted by router top-k
    from repro.models.layers import swiglu
    xf = x.reshape(-1, 16)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / topv.sum(-1, keepdims=True)
    g = jnp.einsum("td,edf->tef", xf, p["w_gate"])
    u = jnp.einsum("td,edf->tef", xf, p["w_up"])
    y_all = jnp.einsum("tef,efd->ted", swiglu(g, u), p["w_down"])
    w = jnp.zeros((xf.shape[0], cfg.n_experts)).at[
        jnp.arange(xf.shape[0])[:, None], topi].set(topv)
    ref = jnp.einsum("te,ted->td", w, y_all).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(cf=0.1)   # tiny capacity forces drops, must not crash
    p = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 16), jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))


def test_moe_grad_flows_through_router():
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 16), jnp.float32)

    def loss(pp):
        out, aux = moe_ffn(pp, x, cfg)
        return (out ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0.0
    assert float(jnp.abs(g["w_gate"]).sum()) > 0.0


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@given(st.sampled_from([64, 128, 256]), st.booleans(),
       st.sampled_from([None, 32]))
@settings(max_examples=8, deadline=None)
def test_chunked_attention_equals_ref(S, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(ks[0], (2, S, 4, 16))
    k = jax.random.normal(ks[1], (2, S, 2, 16))
    v = jax.random.normal(ks[2], (2, S, 2, 16))
    o = sdpa_chunked(q, k, v, causal=causal, window=window, block_q=32)
    r = sdpa_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5,
                               rtol=3e-5)


# ---------------------------------------------------------------------------
# decode == prefill (cache correctness, incl. ring semantics)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-4b", "mamba2-370m", "zamba2-1.2b"])
def test_decode_matches_prefill(arch):
    from repro.models import (decode_step, init_decode_state, init_lm,
                              lm_forward)
    cfg = get_config(arch).reduced().with_(dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    T = 12
    toks = jax.random.randint(key, (2, T), 0, cfg.vocab_size)
    full_logits, _ = lm_forward(params, toks, cfg)

    state = init_decode_state(cfg, 2, context=32)
    for t in range(T):
        logits, state = decode_step(params, state, toks[:, t], cfg)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            atol=2e-3, rtol=2e-3)


def test_ring_cache_sliding_window_decode():
    """With a window-sized ring cache, decode must equal full prefill with
    the same sliding window — even past the wrap-around point."""
    from repro.models import decode_step, init_decode_state, init_lm, lm_forward
    W = 8
    cfg = (get_config("qwen3-4b").reduced()
           .with_(dtype=jnp.float32, sliding_window=W))
    key = jax.random.PRNGKey(1)
    params = init_lm(key, cfg)
    T = 20                     # > window: cache wraps
    toks = jax.random.randint(key, (1, T), 0, cfg.vocab_size)
    full_logits, _ = lm_forward(params, toks, cfg, window=W)
    state = init_decode_state(cfg, 1, context=W)   # ring of window size
    for t in range(T):
        logits, state = decode_step(params, state, toks[:, t], cfg, window=W)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            atol=2e-3, rtol=2e-3, err_msg=f"t={t}")


def test_whisper_decode_matches_teacher_forcing():
    from repro.models import (encdec_decode_step, init_encdec,
                              init_encdec_decode_state)
    from repro.models.encdec import decode_train, encode
    cfg = get_config("whisper-medium").reduced().with_(dtype=jnp.float32)
    key = jax.random.PRNGKey(2)
    params = init_encdec(key, cfg, max_dec_len=64)
    frames = jax.random.normal(key, (2, cfg.encoder_seq, cfg.d_model))
    T = 6
    toks = jax.random.randint(key, (2, T), 0, cfg.vocab_size)
    enc = encode(params, frames, cfg)
    full = decode_train(params, toks, enc, cfg)
    state = init_encdec_decode_state(params, frames, cfg, context=16)
    for t in range(T):
        logits, state = encdec_decode_step(params, state, toks[:, t], cfg)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   atol=2e-3, rtol=2e-3)
