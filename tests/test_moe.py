"""Differential MoE dispatch harness + routing/capacity property tests.

Single-process tests certify the sort path against the GShard einsum
oracle (token-identical, including capacity drops and the shared /
dense-residual branches); property tests on the hypothesis shim pin the
routing/capacity arithmetic; the expert-parallel (EP) path's
token-identity claim is certified on an 8-fake-device CPU mesh in a
subprocess (slow marker) — the PR's acceptance criterion and the runtime
half of the searched ``ep_degree`` axis (plan format v5).
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.models.common import ModelConfig
from repro.models.moe import (_capacity, _route, expert_axis_usable,
                              init_moe, moe_ffn)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

TOL = 2e-5


def _cfg(E=8, k=2, cf=1.25, **kw):
    return ModelConfig(name="t", arch_type="moe", n_layers=1, d_model=16,
                       n_heads=4, n_kv_heads=4, d_ff=32, vocab_size=64,
                       n_experts=E, top_k=k, capacity_factor=cf,
                       dtype=jnp.float32, **kw)


def _x(shape, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# ---------------------------------------------------------------------------
# satellite 1: sort path vs the einsum oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("top_k", [1, 2])
@pytest.mark.parametrize("cf", [1.25, 0.5])   # ample / overflowing capacity
def test_sort_matches_einsum_oracle(top_k, cf):
    """Token-identical outputs, including which tokens get dropped when
    capacity overflows — both paths rank (token, choice) pairs in the
    same stable order."""
    cfg = _cfg(E=4, k=top_k, cf=cf)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = _x((2, 24, 16))
    o1, a1 = moe_ffn(p, x, cfg, dispatch="sort")
    o2, a2 = moe_ffn(p, x, cfg, dispatch="einsum")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=TOL, rtol=TOL)
    np.testing.assert_allclose(float(a1), float(a2), atol=TOL, rtol=TOL)


def test_sort_matches_einsum_with_shared_and_dense_residual():
    cfg = _cfg(E=4, k=2, shared_expert_ff=24, dense_residual_ff=16)
    p = init_moe(jax.random.PRNGKey(2), cfg, jnp.float32)
    assert "shared" in p and "dense_residual" in p
    x = _x((2, 16, 16))
    o1, _ = moe_ffn(p, x, cfg, dispatch="sort")
    o2, _ = moe_ffn(p, x, cfg, dispatch="einsum")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=TOL, rtol=TOL)


def test_grouped_matches_sort():
    cfg = _cfg(E=4, k=2, cf=0.75)
    p = init_moe(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = _x((4, 16, 16))
    o1, _ = moe_ffn(p, x, cfg, dispatch="sort")
    o2, _ = moe_ffn(p, x, cfg, dispatch="grouped")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=TOL, rtol=TOL)


def test_capacity_overflow_drops_are_deterministic():
    """With cf << 1 most (token, choice) pairs drop; outputs stay finite
    and the two dispatch paths agree on *which* survive."""
    cfg = _cfg(E=4, k=2, cf=0.25)
    p = init_moe(jax.random.PRNGKey(4), cfg, jnp.float32)
    x = _x((2, 32, 16))
    o1, _ = moe_ffn(p, x, cfg, dispatch="sort")
    o2, _ = moe_ffn(p, x, cfg, dispatch="einsum")
    assert np.isfinite(np.asarray(o1)).all()
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=TOL, rtol=TOL)
    # tokens whose every choice dropped contribute exactly zero
    assert (np.abs(np.asarray(o1)) == 0.0).any()


# ---------------------------------------------------------------------------
# satellite 2: routing/capacity properties (hypothesis shim)
# ---------------------------------------------------------------------------

@settings(max_examples=25)
@given(st.integers(1, 128), st.integers(1, 4), st.integers(1, 16),
       st.floats(0.1, 4.0))
def test_capacity_bounds(T, k, E, cf):
    k = min(k, E)
    cfg = _cfg(E=E, k=k, cf=cf)
    C = _capacity(T, cfg)
    assert C >= k                          # floor: top_k slots always exist
    assert C == max(k, math.ceil(T * k / E * cf))   # exact ceil arithmetic
    # capacity covers every token when cf >= E / k (dense limit)
    if cf * k >= E:
        assert C * E >= T * k


@settings(max_examples=10)
@given(st.integers(0, 1 << 16), st.integers(2, 16), st.integers(1, 3))
def test_router_probs_normalized(seed, E, k):
    k = min(k, E)
    cfg = _cfg(E=E, k=k)
    p = init_moe(jax.random.PRNGKey(seed % 97), cfg, jnp.float32)
    xf = jax.random.normal(jax.random.PRNGKey(seed), (32, 16), jnp.float32)
    topv, topi, aux = _route(p, xf, cfg)
    v = np.asarray(topv)
    assert (v >= 0.0).all()
    np.testing.assert_allclose(v.sum(-1), 1.0, atol=1e-6)
    ti = np.asarray(topi)
    assert ((ti >= 0) & (ti < E)).all()
    assert float(aux) >= 0.0               # switch aux loss is nonnegative


@settings(max_examples=10)
@given(st.integers(0, 1 << 16))
def test_aux_loss_invariant_under_token_permutation(seed):
    cfg = _cfg(E=4, k=2)
    p = init_moe(jax.random.PRNGKey(5), cfg, jnp.float32)
    xf = jax.random.normal(jax.random.PRNGKey(seed), (48, 16), jnp.float32)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), 48)
    _, _, aux = _route(p, xf, cfg)
    _, _, aux_p = _route(p, xf[perm], cfg)
    np.testing.assert_allclose(float(aux), float(aux_p), atol=1e-6)


@settings(max_examples=10)
@given(st.integers(0, 1 << 16), st.floats(0.2, 2.0))
def test_no_token_writes_past_capacity(seed, cf):
    """The einsum dispatch tensor — the oracle the sort path is certified
    against — never assigns more than C tokens per expert and never
    double-writes a (expert, slot) cell."""
    cfg = _cfg(E=4, k=2, cf=cf)
    p = init_moe(jax.random.PRNGKey(6), cfg, jnp.float32)
    T, E, k = 32, 4, 2
    xf = jax.random.normal(jax.random.PRNGKey(seed), (T, 16), jnp.float32)
    C = _capacity(T, cfg)
    _, topi, _ = _route(p, xf, cfg)
    # re-derive the dispatch ranks exactly as both paths do
    flat = np.asarray(jax.nn.one_hot(topi, E, dtype=jnp.int32)).reshape(
        T * k, E)
    rank = flat.cumsum(0) - flat
    rank = (rank * flat).sum(-1).reshape(T, k)
    keep = rank < C
    kept_e = np.zeros(E, int)
    seen = set()
    ti = np.asarray(topi)
    for t in range(T):
        for j in range(k):
            if keep[t, j]:
                cell = (int(ti[t, j]), int(rank[t, j]))
                assert cell not in seen      # no slot double-written
                assert cell[1] < C           # no write past capacity
                seen.add(cell)
                kept_e[cell[0]] += 1
    assert (kept_e <= C).all()


# ---------------------------------------------------------------------------
# EP gate (single process)
# ---------------------------------------------------------------------------

def test_expert_axis_usable_gate_table():
    from jax.sharding import Mesh
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh11 = Mesh(dev, ("data", "expert"))
    cfg = _cfg(E=8, k=2)
    assert not expert_axis_usable(cfg, None, 8, None)         # no mesh
    assert not expert_axis_usable(cfg, mesh11, 8, ("data",))  # ep axis = 1
    mesh_noexp = Mesh(dev.reshape(1), ("data",))
    assert not expert_axis_usable(cfg, mesh_noexp, 8, ("data",))


# ---------------------------------------------------------------------------
# tentpole acceptance: EP-sharded forward == single-device sort dispatch
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ep_token_identical_on_8_device_mesh():
    """The EP path (sharded expert weights + all-to-all dispatch/combine)
    must be token-identical — fp32 allclose + exact argmax — to the
    single-device sort dispatch, across top_k, capacity overflow, and the
    shared/dense-residual branches (the PR's acceptance criterion)."""
    run_subprocess("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.models.common import ModelConfig
from repro.models import moe as M
from repro.models import flags

def cfg_(E, k, cf=1.25, **kw):
    return ModelConfig(name="t", arch_type="moe", n_layers=1, d_model=16,
                       n_heads=4, n_kv_heads=4, d_ff=32, vocab_size=64,
                       n_experts=E, top_k=k, capacity_factor=cf,
                       dtype=jnp.float32, **kw)

devs = np.array(jax.devices())
cases = [
    # (cfg, mesh axes/shape, batch axes)
    (cfg_(8, 2),                 devs.reshape(2, 4), ("data", "expert"), ("data",)),
    (cfg_(8, 1),                 devs.reshape(8),    ("expert",),        None),
    (cfg_(8, 2, cf=0.5),         devs.reshape(2, 4), ("data", "expert"), ("data",)),  # drops
    (cfg_(8, 2, shared_expert_ff=24, dense_residual_ff=16),
                                 devs.reshape(2, 4), ("data", "expert"), ("data",)),
    (cfg_(16, 2),                devs.reshape(1, 8), ("data", "expert"), ("data",)),  # E > ep
]
for i, (cfg, dv, axes, bt) in enumerate(cases):
    mesh = Mesh(dv, axes)
    p = M.init_moe(jax.random.PRNGKey(i), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(100 + i), (8, 16, 16),
                          jnp.float32)
    ref, aux_ref = M.moe_ffn(p, x, cfg, dispatch="sort")
    with flags.batch_sharding(bt, mesh=mesh):
        assert M.expert_axis_usable(cfg, mesh, 8, bt), f"case {i} gate"
        out, aux = M.moe_ffn(p, x, cfg, dispatch="sort")
    out, ref = np.asarray(out), np.asarray(ref)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
    assert (np.argmax(out.reshape(-1, 16), -1)
            == np.argmax(ref.reshape(-1, 16), -1)).all(), f"case {i} argmax"
    np.testing.assert_allclose(float(aux), float(aux_ref), atol=2e-5,
                               rtol=2e-5)

# indivisible experts keep the gate closed (falls back, still correct)
cfg_bad = cfg_(6, 2)
mesh = Mesh(devs.reshape(2, 4), ("data", "expert"))
assert not M.expert_axis_usable(cfg_bad, mesh, 8, ("data",))
p = M.init_moe(jax.random.PRNGKey(9), cfg_bad, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(10), (8, 16, 16), jnp.float32)
ref, _ = M.moe_ffn(p, x, cfg_bad, dispatch="sort")
with flags.batch_sharding(("data",), mesh=mesh):
    out, _ = M.moe_ffn(p, x, cfg_bad, dispatch="sort")
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=2e-5, rtol=2e-5)
print("EP-IDENTITY-OK")
""", devices=8)


@pytest.mark.slow
def test_ep_policy_shards_batch_and_experts_on_mesh():
    """runtime side of a v5 plan: make_expert_mesh carries the "expert"
    axis, ShardPolicy(ep_degree>1) co-shards the batch dim over it and
    puts stacked expert weights on it."""
    run_subprocess("""
import jax, numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_expert_mesh
from repro.runtime.sharding import ShardPolicy, batch_shardings, param_shardings

mesh = make_expert_mesh(4, n_data=2)
assert dict(zip(mesh.axis_names, mesh.devices.shape)) == {"data": 2,
                                                          "expert": 4}
pol = ShardPolicy(tp=False, zero=False, ep_degree=4, expert_axis="expert")
bs = batch_shardings({"x": jax.ShapeDtypeStruct((8, 16, 32), jnp.float32)},
                     mesh, pol)["x"]
assert "expert" in str(bs.spec), bs.spec
bs1 = batch_shardings({"x": jax.ShapeDtypeStruct((8, 16, 32), jnp.float32)},
                      mesh, ShardPolicy(tp=False, zero=False))["x"]
assert "expert" not in str(bs1.spec), bs1.spec

# stacked expert weights (L, E, d, f) shard the E dim over "expert"
params = {"w_gate": jax.ShapeDtypeStruct((2, 8, 16, 32), jnp.float32),
          "router": jax.ShapeDtypeStruct((16, 8), jnp.float32)}
sh = param_shardings(params, mesh, pol)
assert "expert" in str(sh["w_gate"].spec), sh["w_gate"].spec
assert str(sh["router"].spec) == "PartitionSpec()"
print("EP-POLICY-OK")
""", devices=8)
