"""Pipeline-schedule subsystem: program-table invariants (pure NumPy) and
a single-device executed-equivalence check of the generic tick loop.

The multi-stage executed equivalence (P=4, all three schedules vs the
non-pipelined reference) lives in test_distributed.py (slow, subprocess
with fake devices)."""
import numpy as np
import pytest

from repro.runtime.schedules import (PHASE_B, PHASE_F, PHASE_W,
                                     SCHEDULE_NAMES, ScheduleProgram,
                                     compile_schedule, zb_w_pending_max)


# ---------------------------------------------------------------------------
# program-table invariants
# ---------------------------------------------------------------------------

def test_gpipe_table_is_the_diagonal_schedule():
    P, m = 4, 6
    pr = compile_schedule("gpipe", P, m)
    assert (pr.n_chunks, pr.n_ticks, pr.remat) == (1, m + P - 1, False)
    for t in range(pr.n_ticks):
        for i in range(P):
            mb = t - i
            assert pr.valid[t, i] == (0 <= mb < m)
            if pr.valid[t, i]:
                assert pr.mb_index[t, i] == mb
                assert pr.chunk_index[t, i] == 0
    # loss only on the last stage, once the pipe is full
    assert pr.loss_valid.sum(axis=0)[:-1].sum() == 0


def test_1f1b_same_order_as_gpipe_but_remat():
    g = compile_schedule("gpipe", 4, 8)
    f = compile_schedule("1f1b", 4, 8)
    assert f.remat and not g.remat
    np.testing.assert_array_equal(g.mb_index, f.mb_index)
    np.testing.assert_array_equal(g.valid, f.valid)


@pytest.mark.parametrize("P,m,V", [(4, 8, 2), (4, 6, 2), (3, 5, 3),
                                   (1, 4, 2), (2, 2, 4), (4, 7, 1)])
def test_handoff_consistency_and_loss_coverage(P, m, V):
    """Every valid slot's producer one tick earlier is valid with the same
    micro-batch and the previous virtual stage — the invariant that makes
    bubble-slot garbage unreachable from any counted value.

    The canonical statement of these invariants now lives in the static
    verifier (repro.analysis.schedule_lint, exercised via
    ``compile_schedule(validate=...)`` in tests/test_schedule_lint.py);
    the explicit loop here stays as an independent spot-check of the same
    property."""
    name = "1f1b-interleaved" if V > 1 else "gpipe"
    pr = compile_schedule(name, P, m, V if V > 1 else None)
    losses = np.zeros(m, int)
    for t in range(pr.n_ticks):
        for i in range(P):
            if not pr.valid[t, i]:
                continue
            s = pr.chunk_index[t, i] * P + i
            mb = pr.mb_index[t, i]
            if s > 0:
                ip = (i - 1) % P
                assert pr.valid[t - 1, ip]
                assert pr.mb_index[t - 1, ip] == mb
                assert pr.chunk_index[t - 1, ip] * P + ip == s - 1
            if pr.loss_valid[t, i]:
                assert (i, pr.chunk_index[t, i]) == (P - 1, V - 1)
                losses[mb] += 1
    np.testing.assert_array_equal(losses, 1)   # each micro-batch exactly once


def test_one_chunk_per_device_tick():
    pr = compile_schedule("1f1b-interleaved", 4, 12, 3)
    # table shape itself guarantees it, but assert the mapping inverts:
    # (t, i) -> (chunk, mb) is a function, and every (virtual stage, mb)
    # pair appears exactly once
    seen = set()
    for t in range(pr.n_ticks):
        for i in range(pr.n_stages):
            if pr.valid[t, i]:
                key = (int(pr.chunk_index[t, i]) * 4 + i,
                       int(pr.mb_index[t, i]))
                assert key not in seen
                seen.add(key)
    assert len(seen) == 4 * 3 * 12      # P*V virtual stages x m micro-batches


def test_tick_counts_and_bubble():
    # V=1: T = m + P - 1; m % P == 0: T = m*V + P - 1
    assert compile_schedule("gpipe", 4, 6).n_ticks == 9
    assert compile_schedule("1f1b-interleaved", 4, 8, 2).n_ticks == 19
    assert compile_schedule("1f1b-interleaved", 4, 8, 2).bubble_ticks == 3
    # bubble never grows with V when m % P == 0
    for V in (2, 3, 4):
        assert compile_schedule("1f1b-interleaved", 4, 8, V).bubble_ticks == 3


def test_bad_args_raise():
    with pytest.raises(ValueError):
        compile_schedule("nope", 4, 8)
    with pytest.raises(ValueError):
        compile_schedule("gpipe", 4, 8, n_chunks=2)      # single-chunk
    with pytest.raises(ValueError):
        compile_schedule("1f1b-interleaved", 4, 8, 1)    # that's plain 1f1b
    with pytest.raises(ValueError):
        compile_schedule("gpipe", 4, 0)
    with pytest.raises(ValueError):
        compile_schedule("zb-h1", 4, 8, n_chunks=2)      # single-chunk
    assert set(SCHEDULE_NAMES) == {"gpipe", "1f1b", "1f1b-interleaved",
                                   "zb-h1"}


# ---------------------------------------------------------------------------
# zero-bubble (ZB-H1) three-phase tables
# ---------------------------------------------------------------------------

def _zb_phase_ticks(pr):
    """(f, b, w) tick matrices shaped (P, m) from a three-phase table."""
    P, m = pr.n_stages, pr.n_micro
    ticks = {ph: np.full((P, m), -1, np.int64)
             for ph in (PHASE_F, PHASE_B, PHASE_W)}
    for t in range(pr.n_ticks):
        for i in range(P):
            if pr.valid[t, i]:
                ticks[int(pr.phase[t, i])][i, int(pr.mb_index[t, i])] = t
    return ticks[PHASE_F], ticks[PHASE_B], ticks[PHASE_W]


def _max_overlap(starts, ends):
    """Peak number of [start, end) intervals alive at once."""
    ev = sorted([(t, 1) for t in starts] + [(t, -1) for t in ends])
    c = mx = 0
    for _, d in ev:
        c += d
        mx = max(mx, c)
    return mx


@pytest.mark.parametrize("P,m", [(1, 4), (2, 2), (2, 8), (3, 5), (4, 8),
                                 (8, 8), (8, 32)])
def test_zb_h1_three_phase_dependencies_and_coverage(P, m):
    """Every (stage, micro-batch) runs exactly one F, one B and one W, in
    dependency order: F follows the upstream F, B follows this stage's F
    and the downstream B, W follows this stage's B.

    The verifier certifies the same happens-before edges (and more) as a
    compiler post-condition (``validate=True``); the explicit loop here
    stays as an independent spot-check of the same property."""
    pr = compile_schedule("zb-h1", P, m, validate=True)
    assert pr.is_three_phase and pr.remat and pr.n_chunks == 1
    ft, bt, wt = _zb_phase_ticks(pr)
    assert (ft >= 0).all() and (bt >= 0).all() and (wt >= 0).all()
    for i in range(P):
        for mb in range(m):
            if i > 0:
                assert ft[i, mb] > ft[i - 1, mb]
            assert bt[i, mb] > ft[i, mb]
            if i < P - 1:
                assert bt[i, mb] > bt[i + 1, mb]
            assert wt[i, mb] > bt[i, mb]
    # loss once per micro-batch, on the last stage's F slot
    assert pr.loss_valid[:, :P - 1].sum() == 0
    assert pr.loss_valid.sum() == m


@pytest.mark.parametrize("P,m", [(2, 8), (4, 4), (4, 16), (8, 8)])
def test_zb_h1_memory_profile(P, m):
    """The forward-activation stash never exceeds the 1F1B profile
    (min(P-i, m) in flight), and the deferred weight-grad pile matches
    zb_w_pending_max exactly — the modeled memory price of the W split."""
    pr = compile_schedule("zb-h1", P, m)
    ft, bt, wt = _zb_phase_ticks(pr)
    for i in range(P):
        assert _max_overlap(ft[i], bt[i]) <= min(P - i, m)
        assert _max_overlap(bt[i], wt[i]) == zb_w_pending_max(i, P, m)


@pytest.mark.parametrize("P,m", [(1, 4), (2, 2), (2, 8), (4, 8), (4, 16),
                                 (8, 8), (8, 32)])
def test_zb_h1_bubble_is_one_third_of_1f1b(P, m):
    """m >= P: the compiled bubble is exactly P-1 three-phase unit ticks —
    one third of 1F1B's 3(P-1) equivalent (only the warm-up fill remains;
    deferred W ticks absorb the rest)."""
    pr = compile_schedule("zb-h1", P, m)
    assert pr.work_ticks_per_stage == 3 * m
    assert pr.n_ticks == 3 * m + (P - 1)
    assert pr.bubble_ticks == P - 1
    assert pr.bubble_ticks <= 3 * (P - 1)     # 1f1b-equivalent unit bubble


def test_zb_h1_forward_program_is_the_flush_diagonal():
    P, m = 4, 8
    pr = compile_schedule("zb-h1", P, m)
    fwd = pr.forward_program()
    ref = compile_schedule("1f1b", P, m)
    assert (fwd.name, fwd.remat, fwd.is_three_phase) == ("zb-h1", True, False)
    assert fwd.n_ticks == m + P - 1
    np.testing.assert_array_equal(fwd.mb_index, ref.mb_index)
    np.testing.assert_array_equal(fwd.valid, ref.valid)
    np.testing.assert_array_equal(fwd.loss_valid, ref.loss_valid)
    # single-phase programs are their own projection
    assert ref.forward_program() is ref


# ---------------------------------------------------------------------------
# executed equivalence on the in-process 1-device mesh (P=1 exercises the
# chunk walk + wrap hand-off of the interleaved schedule)
# ---------------------------------------------------------------------------

def test_single_stage_interleaved_matches_reference():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import init_lm, lm_loss
    from repro.runtime import make_pipeline_loss, stage_split_params

    mesh = jax.make_mesh((1, 1), ("pipe", "data"))
    cfg = get_config("qwen3-4b").reduced(n_layers=2, d_model=64).with_(
        dtype=jnp.float32)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    m, Bm, S = 3, 2, 8
    k = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(k, (m, Bm, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(k, (m, Bm, S), 0, cfg.vocab_size)}
    flat = {k2: v.reshape(m * Bm, S) for k2, v in batch.items()}
    ref = float(lm_loss(params, flat, cfg))
    rg = jax.grad(lambda p: lm_loss(p, flat, cfg))(params)
    with mesh:
        for sched, V in [("gpipe", 1), ("1f1b-interleaved", 2), ("zb-h1", 1)]:
            ps = stage_split_params(params, 1, V)
            loss, grads = jax.jit(make_pipeline_loss(
                cfg, mesh, m, schedule=sched, n_chunks=V))(ps, batch)
            assert abs(float(loss) - ref) < 1e-5, sched
            g = np.asarray(grads["stacks"][0]["attn"]["wq"],
                           np.float32).reshape(cfg.n_layers, -1)
            r = np.asarray(rg["stacks"][0]["attn"]["wq"],
                           np.float32).reshape(cfg.n_layers, -1)
            assert np.abs(g - r).max() < 1e-4 * max(1.0, np.abs(r).max()), sched


def test_stage_split_params_chunk_layout():
    """Chunk v on device i must hold virtual stage v*P + i's layers."""
    import jax.numpy as jnp

    from repro.runtime import stage_split_params

    L, P, V = 8, 2, 2
    params = {"stacks": [{"w": jnp.arange(L)}], "embed": jnp.zeros((3, 2))}
    out = stage_split_params(params, P, V)
    w = np.asarray(out["stacks"][0]["w"])           # (P, V, L/(P*V))
    assert w.shape == (P, V, L // (P * V))
    for i in range(P):
        for v in range(V):
            s = v * P + i
            np.testing.assert_array_equal(
                w[i, v], np.arange(s * 2, (s + 1) * 2))
    with pytest.raises(AssertionError):
        stage_split_params(params, 3)               # 8 % 3 != 0
