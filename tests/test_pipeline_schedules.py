"""Pipeline-schedule subsystem: program-table invariants (pure NumPy) and
a single-device executed-equivalence check of the generic tick loop.

The multi-stage executed equivalence (P=4, all three schedules vs the
non-pipelined reference) lives in test_distributed.py (slow, subprocess
with fake devices)."""
import numpy as np
import pytest

from repro.runtime.schedules import (SCHEDULE_NAMES, ScheduleProgram,
                                     compile_schedule)


# ---------------------------------------------------------------------------
# program-table invariants
# ---------------------------------------------------------------------------

def test_gpipe_table_is_the_diagonal_schedule():
    P, m = 4, 6
    pr = compile_schedule("gpipe", P, m)
    assert (pr.n_chunks, pr.n_ticks, pr.remat) == (1, m + P - 1, False)
    for t in range(pr.n_ticks):
        for i in range(P):
            mb = t - i
            assert pr.valid[t, i] == (0 <= mb < m)
            if pr.valid[t, i]:
                assert pr.mb_index[t, i] == mb
                assert pr.chunk_index[t, i] == 0
    # loss only on the last stage, once the pipe is full
    assert pr.loss_valid.sum(axis=0)[:-1].sum() == 0


def test_1f1b_same_order_as_gpipe_but_remat():
    g = compile_schedule("gpipe", 4, 8)
    f = compile_schedule("1f1b", 4, 8)
    assert f.remat and not g.remat
    np.testing.assert_array_equal(g.mb_index, f.mb_index)
    np.testing.assert_array_equal(g.valid, f.valid)


@pytest.mark.parametrize("P,m,V", [(4, 8, 2), (4, 6, 2), (3, 5, 3),
                                   (1, 4, 2), (2, 2, 4), (4, 7, 1)])
def test_handoff_consistency_and_loss_coverage(P, m, V):
    """Every valid slot's producer one tick earlier is valid with the same
    micro-batch and the previous virtual stage — the invariant that makes
    bubble-slot garbage unreachable from any counted value."""
    name = "1f1b-interleaved" if V > 1 else "gpipe"
    pr = compile_schedule(name, P, m, V if V > 1 else None)
    losses = np.zeros(m, int)
    for t in range(pr.n_ticks):
        for i in range(P):
            if not pr.valid[t, i]:
                continue
            s = pr.chunk_index[t, i] * P + i
            mb = pr.mb_index[t, i]
            if s > 0:
                ip = (i - 1) % P
                assert pr.valid[t - 1, ip]
                assert pr.mb_index[t - 1, ip] == mb
                assert pr.chunk_index[t - 1, ip] * P + ip == s - 1
            if pr.loss_valid[t, i]:
                assert (i, pr.chunk_index[t, i]) == (P - 1, V - 1)
                losses[mb] += 1
    np.testing.assert_array_equal(losses, 1)   # each micro-batch exactly once


def test_one_chunk_per_device_tick():
    pr = compile_schedule("1f1b-interleaved", 4, 12, 3)
    # table shape itself guarantees it, but assert the mapping inverts:
    # (t, i) -> (chunk, mb) is a function, and every (virtual stage, mb)
    # pair appears exactly once
    seen = set()
    for t in range(pr.n_ticks):
        for i in range(pr.n_stages):
            if pr.valid[t, i]:
                key = (int(pr.chunk_index[t, i]) * 4 + i,
                       int(pr.mb_index[t, i]))
                assert key not in seen
                seen.add(key)
    assert len(seen) == 4 * 3 * 12      # P*V virtual stages x m micro-batches


def test_tick_counts_and_bubble():
    # V=1: T = m + P - 1; m % P == 0: T = m*V + P - 1
    assert compile_schedule("gpipe", 4, 6).n_ticks == 9
    assert compile_schedule("1f1b-interleaved", 4, 8, 2).n_ticks == 19
    assert compile_schedule("1f1b-interleaved", 4, 8, 2).bubble_ticks == 3
    # bubble never grows with V when m % P == 0
    for V in (2, 3, 4):
        assert compile_schedule("1f1b-interleaved", 4, 8, V).bubble_ticks == 3


def test_bad_args_raise():
    with pytest.raises(ValueError):
        compile_schedule("nope", 4, 8)
    with pytest.raises(ValueError):
        compile_schedule("gpipe", 4, 8, n_chunks=2)      # single-chunk
    with pytest.raises(ValueError):
        compile_schedule("1f1b-interleaved", 4, 8, 1)    # that's plain 1f1b
    with pytest.raises(ValueError):
        compile_schedule("gpipe", 4, 0)
    assert set(SCHEDULE_NAMES) == {"gpipe", "1f1b", "1f1b-interleaved"}


# ---------------------------------------------------------------------------
# executed equivalence on the in-process 1-device mesh (P=1 exercises the
# chunk walk + wrap hand-off of the interleaved schedule)
# ---------------------------------------------------------------------------

def test_single_stage_interleaved_matches_reference():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import init_lm, lm_loss
    from repro.runtime import make_pipeline_loss, stage_split_params

    mesh = jax.make_mesh((1, 1), ("pipe", "data"))
    cfg = get_config("qwen3-4b").reduced(n_layers=2, d_model=64).with_(
        dtype=jnp.float32)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    m, Bm, S = 3, 2, 8
    k = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(k, (m, Bm, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(k, (m, Bm, S), 0, cfg.vocab_size)}
    flat = {k2: v.reshape(m * Bm, S) for k2, v in batch.items()}
    ref = float(lm_loss(params, flat, cfg))
    rg = jax.grad(lambda p: lm_loss(p, flat, cfg))(params)
    with mesh:
        for sched, V in [("gpipe", 1), ("1f1b-interleaved", 2)]:
            ps = stage_split_params(params, 1, V)
            loss, grads = jax.jit(make_pipeline_loss(
                cfg, mesh, m, schedule=sched, n_chunks=V))(ps, batch)
            assert abs(float(loss) - ref) < 1e-5, sched
            g = np.asarray(grads["stacks"][0]["attn"]["wq"],
                           np.float32).reshape(cfg.n_layers, -1)
            r = np.asarray(rg["stacks"][0]["attn"]["wq"],
                           np.float32).reshape(cfg.n_layers, -1)
            assert np.abs(g - r).max() < 1e-4 * max(1.0, np.abs(r).max()), sched


def test_stage_split_params_chunk_layout():
    """Chunk v on device i must hold virtual stage v*P + i's layers."""
    import jax.numpy as jnp

    from repro.runtime import stage_split_params

    L, P, V = 8, 2, 2
    params = {"stacks": [{"w": jnp.arange(L)}], "embed": jnp.zeros((3, 2))}
    out = stage_split_params(params, P, V)
    w = np.asarray(out["stacks"][0]["w"])           # (P, V, L/(P*V))
    assert w.shape == (P, V, L // (P * V))
    for i in range(P):
        for v in range(V):
            s = v * P + i
            np.testing.assert_array_equal(
                w[i, v], np.arange(s * 2, (s + 1) * 2))
    with pytest.raises(AssertionError):
        stage_split_params(params, 3)               # 8 % 3 != 0
